"""Table II analogue: PARALLEL-DOMINATING-SET scaling (same methodology as
table1; DS instances are nxm.ds-style random graphs)."""

from __future__ import annotations

from benchmarks.common import write_csv
from repro.core.serial import ParallelRBSimulator, serial_rb
from repro.problems import (gnp_graph, make_dominating_set,
                            make_dominating_set_py)
from repro.solver import Solver, SolverConfig

CORES = [1, 2, 4, 8, 16, 32]
LANES = [1, 4, 16]

INSTANCES = [
    ("26x90.ds", lambda: gnp_graph(26, 0.27, seed=11)),
    ("30x60.ds", lambda: gnp_graph(30, 0.14, seed=5)),
]


def run(quick: bool = False) -> list:
    rows = []
    cores = CORES[:4] if quick else CORES
    for name, gf in INSTANCES:
        g = gf()
        serial_best, serial_nodes, _ = serial_rb(make_dominating_set_py(g))
        base = None
        for c in cores:
            sim = ParallelRBSimulator(make_dominating_set_py(g), c=c).run()
            assert sim.best == serial_best, (name, c)
            base = base or sim.makespan
            rows.append({
                "instance": name, "impl": "parallel-rb-sim", "workers": c,
                "makespan": sim.makespan, "nodes": sim.total_nodes,
                "t_s": round(sim.avg_t_s, 1), "t_r": round(sim.avg_t_r, 1),
                "speedup": round(base / sim.makespan, 2),
            })
        prob = make_dominating_set(g)
        base_r = None
        for w in (LANES[:2] if quick else LANES):
            stats = Solver(SolverConfig(
                lanes=w, steps_per_round=64, bootstrap_rounds=3,
                bootstrap_steps=8)).solve(prob).stats
            assert stats.best == serial_best, (name, w)
            base_r = base_r or stats.rounds
            rows.append({
                "instance": name, "impl": "bsp-engine", "workers": w,
                "makespan": stats.rounds, "nodes": stats.nodes,
                "t_s": round(stats.t_s / w, 1),
                "t_r": round(stats.t_r / w, 1),
                "speedup": round(base_r / max(stats.rounds, 1), 2),
            })
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    path = write_csv("table2_dominating_set.csv", rows,
                     ["instance", "impl", "workers", "makespan", "nodes",
                      "t_s", "t_r", "speedup"])
    for r in rows:
        print("table2,%s,%s,%s,%s,%s,%s,%s" % (
            r["instance"], r["impl"], r["workers"], r["makespan"],
            r["nodes"], r["t_s"], r["t_r"]))
    print(f"table2 -> {path}")


if __name__ == "__main__":
    main()
