"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import csv
import os
import time
from typing import Dict, Iterable, List

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def write_csv(name: str, rows: List[Dict], field_order: Iterable[str]):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(field_order))
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)                     # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out
