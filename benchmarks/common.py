"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import csv
import datetime
import os
import platform
import subprocess
import time
from typing import Dict, Iterable, List

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def bench_meta() -> Dict[str, str]:
    """Provenance stamp for BENCH_*.json rows: when, what code, what stack.

    A benchmark number without its commit and library versions cannot be
    compared across runs; every suite attaches this block under ``meta``.
    """
    import jax
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    return {
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": sha or "unknown",
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def write_csv(name: str, rows: List[Dict], field_order: Iterable[str]):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(field_order))
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)                     # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out
