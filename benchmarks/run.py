"""Benchmark harness entry point: one module per paper table/figure.

  table1  — PARALLEL-VERTEX-COVER scaling (paper Table I)
  table2  — PARALLEL-DOMINATING-SET scaling (paper Table II)
  fig10   — T_S/T_R steal-traffic gap growth (paper Fig. 10)
  kernels — Pallas kernel micro (shapes, ref timings, interpret deltas)
  roofline— aggregated dry-run roofline table (EXPERIMENTS.md §Roofline)
  service — continuous-batching throughput vs sequential solves
  latency — scheduling policies on a Poisson trace (p50/p95, deadlines)

``python -m benchmarks.run [--quick] [--only NAME]``
CSV artifacts land in benchmarks/artifacts/.
"""

from __future__ import annotations

import argparse
import glob
import os
import subprocess
import sys
import time

from benchmarks import (fig10_steal_traffic, kernel_micro, roofline_table,
                        service_latency, service_throughput,
                        table1_vertex_cover, table2_dominating_set)
from benchmarks.common import ART_DIR

SUITES = [
    ("table1", table1_vertex_cover.main),
    ("table2", table2_dominating_set.main),
    ("fig10", fig10_steal_traffic.main),
    ("kernels", kernel_micro.main),
    ("roofline", roofline_table.main),
    ("service", service_throughput.main),
    ("latency", service_latency.main),
]


def trace_reports() -> None:
    """Summarize every trace a suite left behind (DESIGN.md §8).

    Suites that run with telemetry write JSONL traces under
    ``artifacts/traces/``; each one gets a sibling ``.report.txt`` from
    ``tools/trace_report.py`` — the standard load-balance artifact.  A
    schema violation (exit 2) fails the whole harness run.
    """
    tool = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "trace_report.py")
    for trace in sorted(glob.glob(os.path.join(ART_DIR, "traces",
                                               "*.jsonl"))):
        proc = subprocess.run([sys.executable, tool, trace],
                              capture_output=True, text=True)
        if proc.returncode != 0:
            raise SystemExit(
                f"trace_report failed on {trace}:\n{proc.stderr}")
        report = trace[:-len(".jsonl")] + ".report.txt"
        with open(report, "w") as f:
            f.write(proc.stdout)
        print(f"trace report -> {report}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced worker counts / shapes")
    ap.add_argument("--only", default=None)
    # Anything after `--` is forwarded to the selected suite's own CLI,
    # e.g. `python -m benchmarks.run --only service -- --devices 1,2,4`.
    args, extra = ap.parse_known_args()
    if extra and extra[0] == "--":
        extra = extra[1:]
    if extra and not args.only:
        raise SystemExit("suite args (`-- ...`) require --only NAME")
    for name, fn in SUITES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        if extra:
            mod = sys.modules[fn.__module__]
            if not hasattr(mod, "cli"):
                raise SystemExit(f"suite {name} takes no extra args")
            mod.cli(extra + (["--quick"] if args.quick else []))
        else:
            fn(quick=args.quick)
        print(f"== {name} done in {time.time()-t0:.1f}s ==", flush=True)
    trace_reports()


if __name__ == "__main__":
    main()
