"""Table I analogue: PARALLEL-VERTEX-COVER scaling.

Two measurements per (instance, core count):

1. *Paper-faithful protocol* — ParallelRBSimulator (PARALLEL-RB, Fig. 7
   verbatim: GETPARENT topology, GETHEAVIESTTASKINDEX responses, passes>2
   termination).  Makespan is in *ticks* (one node visit per active core
   per tick) — the machine-independent time unit; T_S / T_R per core match
   the paper's table semantics.

2. *BSP/JAX engine* — the repro.solver.Solver facade with W lanes; the
   makespan analogue is engine rounds x R + steal phases.  Optima are
   asserted equal to SERIAL-RB.

Instances are scaled-down analogues of the paper's set (CPU container):
a p_hat-style random graph, a 4-regular 60-cell-style graph (regularity
defeats pruning — the paper's hard case), and a denser frb-style graph.
"""

from __future__ import annotations

import time

from benchmarks.common import write_csv
from repro.core.serial import ParallelRBSimulator, serial_rb
from repro.problems import (gnp_graph, make_vertex_cover,
                            make_vertex_cover_py, random_regularish_graph)
from repro.solver import Solver, SolverConfig

CORES = [1, 2, 4, 8, 16, 32]
LANES = [1, 4, 16, 64]

INSTANCES = [
    ("p_hat-an", lambda: gnp_graph(36, 0.14, seed=7)),
    ("60cell-an", lambda: random_regularish_graph(44, 4, seed=1)),
    ("frb-an", lambda: gnp_graph(30, 0.25, seed=3)),
]


def run(quick: bool = False) -> list:
    rows = []
    cores = CORES[:4] if quick else CORES
    lanes = LANES[:3] if quick else LANES
    for name, gf in INSTANCES:
        g = gf()
        prob_py = make_vertex_cover_py(g)
        serial_best, serial_nodes, _ = serial_rb(prob_py)
        base_ticks = None
        for c in cores:
            sim = ParallelRBSimulator(make_vertex_cover_py(g), c=c).run()
            assert sim.best == serial_best, (name, c)
            if base_ticks is None:
                base_ticks = sim.makespan
            rows.append({
                "instance": name, "impl": "parallel-rb-sim", "workers": c,
                "makespan": sim.makespan, "nodes": sim.total_nodes,
                "t_s": round(sim.avg_t_s, 1), "t_r": round(sim.avg_t_r, 1),
                "speedup": round(base_ticks / sim.makespan, 2),
            })
        prob = make_vertex_cover(g)
        base_rounds = None
        for w in lanes:
            stats = Solver(SolverConfig(
                lanes=w, steps_per_round=64, bootstrap_rounds=3,
                bootstrap_steps=8)).solve(prob).stats
            assert stats.best == serial_best, (name, w)
            if base_rounds is None:
                base_rounds = stats.rounds
            rows.append({
                "instance": name, "impl": "bsp-engine", "workers": w,
                "makespan": stats.rounds, "nodes": stats.nodes,
                "t_s": round(stats.t_s / w, 1),
                "t_r": round(stats.t_r / w, 1),
                "speedup": round(base_rounds / max(stats.rounds, 1), 2),
            })
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    path = write_csv("table1_vertex_cover.csv", rows,
                     ["instance", "impl", "workers", "makespan", "nodes",
                      "t_s", "t_r", "speedup"])
    for r in rows:
        print("table1,%s,%s,%s,%s,%s,%s,%s" % (
            r["instance"], r["impl"], r["workers"], r["makespan"],
            r["nodes"], r["t_s"], r["t_r"]))
    print(f"table1 -> {path}")


if __name__ == "__main__":
    main()
