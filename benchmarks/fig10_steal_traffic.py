"""Fig. 10 analogue: growth of the T_S / T_R gap with worker count.

The paper's central load-balancing diagnostic: as |C| grows, requests
(T_R) outpace received tasks (T_S); an efficient strategy keeps the gap's
growth controlled.  Emitted for both the faithful simulator and the BSP
engine, plus the incumbent-sharing ablation (instant vs delayed bound
broadcast — the mechanism behind the paper's super-linear speedups).
"""

from __future__ import annotations

from benchmarks.common import write_csv
from repro.core.serial import ParallelRBSimulator, serial_rb
from repro.problems import make_vertex_cover_py, random_regularish_graph

CORES = [2, 4, 8, 16, 32, 64]


def run(quick: bool = False) -> list:
    g = random_regularish_graph(40, 4, seed=1)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    rows = []
    for c in (CORES[:4] if quick else CORES):
        for share, label in ((True, "instant-bound"),
                             (False, "delayed-bound")):
            sim = ParallelRBSimulator(make_vertex_cover_py(g), c=c,
                                      instant_bound_share=share).run()
            assert sim.best == serial_best
            rows.append({
                "workers": c, "bound_sharing": label,
                "makespan": sim.makespan,
                "t_s": round(sim.avg_t_s, 2), "t_r": round(sim.avg_t_r, 2),
                "gap": round(sim.avg_t_r - sim.avg_t_s, 2),
                "nodes": sim.total_nodes,
            })
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    path = write_csv("fig10_steal_traffic.csv", rows,
                     ["workers", "bound_sharing", "makespan", "t_s", "t_r",
                      "gap", "nodes"])
    for r in rows:
        print("fig10,%s,%s,%s,%s,%s,%s" % (
            r["workers"], r["bound_sharing"], r["makespan"], r["t_s"],
            r["t_r"], r["gap"]))
    print(f"fig10 -> {path}")


if __name__ == "__main__":
    main()
