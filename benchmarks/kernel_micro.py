"""Kernel microbenchmarks (CPU wall time of the jnp reference path +
interpret-mode correctness deltas for the Pallas bodies).

Absolute CPU µs are not TPU predictions; the table documents (a) the
shapes each kernel is exercised at, (b) ref-vs-kernel max abs error, and
(c) the ref path's CPU throughput as a regression canary.

``run_node_eval`` additionally measures the solver's actual unit of work
— fused ``Problem.evaluate`` nodes/sec, batched over lanes — for BOTH
kernel-layer problem families (DESIGN.md §5.4): vertex cover (legacy
three-callback adapter vs fused jnp vs fused+Pallas) and dominating set
(fused jnp vs fused+Pallas), and records the trajectory in
``BENCH_node_eval.json`` at the repo root (DESIGN.md §3/§5).  Each
variant row carries its execution metadata — ``mode`` ("jnp" vs the
Pallas path's "interpret"/"compiled") and, for Pallas variants, the
autotuned ``tile``/``stages`` (DESIGN.md §5.6) — so a recorded number is
attributable to the configuration that produced it.  On CPU the Pallas
variants run the kernel bodies in interpret mode, so their absolute
numbers are correctness canaries, not speed claims.

``--quick`` measures a smaller shape and records it under the ``"quick"``
subtree of the JSON (the full-size trajectory stays at top level);
``--gate`` compares the fresh numbers against the committed baseline of
the SAME subtree and exits non-zero on a >20% nodes/sec regression for
any (family, variant) pair — the CI bench-smoke regression gate.  A
failed gate does not overwrite the baseline.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_meta, timed, write_csv
from repro import registry
from repro.core.api import INF_VALUE
from repro.kernels import bitset_ops, ref
from repro.kernels.bitset_degree import degree_argmax
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.problems.dominating_set import DSState
from repro.problems.graphs import gnp_graph, full_mask, num_words
from repro.problems.vertex_cover import VCState, make_vertex_cover_callbacks

#: Gate threshold: fail on a >20% nodes/sec drop vs the committed baseline.
GATE_REGRESSION = 0.20

BENCH_JSON = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_node_eval.json"))


def run(quick: bool = False) -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention
    for (b, s, h, g, hd) in [(1, 512, 8, 2, 64)] + \
            ([] if quick else [(2, 1024, 8, 8, 128)]):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (b, s, g, hd), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (b, s, g, hd), jnp.float32) * 0.5
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        t, out_ref = timed(lambda: np.asarray(fn(q, k, v)))
        out_pl = flash_attention(q, k, v, interpret=True)
        err = float(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "flash_attention",
                     "shape": f"b{b}_s{s}_h{h}_g{g}_d{hd}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": f"{err:.2e}"})

    # ssd scan
    for (b, s, h, p, n, chunk) in [(1, 256, 4, 64, 64, 64)] + \
            ([] if quick else [(2, 512, 8, 64, 128, 128)]):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bb = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
        cc = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
        d = jnp.ones((h,), jnp.float32)
        fn = jax.jit(lambda *args: ref.ssd_scan_ref(*args, chunk=chunk)[0])
        t, out_ref = timed(lambda: np.asarray(fn(x, dt, a, bb, cc, d)))
        out_pl, _ = ssd_scan(x, dt, a, bb, cc, d, chunk=chunk,
                             interpret=True)
        err = float(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "ssd_scan",
                     "shape": f"b{b}_s{s}_h{h}_p{p}_n{n}_c{chunk}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": f"{err:.2e}"})

    # bitset degree/argmax
    for (n, pr, lanes) in [(300, 0.05, 16)] + ([] if quick else
                                               [(512, 0.1, 64)]):
        g = gnp_graph(n, pr, seed=n)
        adj = jnp.asarray(g.adj)
        alive = jnp.tile(jnp.asarray(full_mask(n))[None, :], (lanes, 1))
        fn = jax.jit(lambda a, m: ref.degree_argmax_ref(a, m))
        t, out_ref = timed(lambda: np.asarray(fn(adj, alive)))
        out_pl = degree_argmax(adj, alive, interpret=True)
        err = int(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "bitset_degree",
                     "shape": f"n{n}_L{lanes}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": str(err)})

    # stacked bitset stats (the service's batched kernel, DESIGN.md §5.3)
    for (k, n, lanes) in [(4, 128, 16)] + ([] if quick else [(8, 256, 64)]):
        key2 = jax.random.PRNGKey(k)
        w = (n + 31) // 32
        kt, km, kv, ki = jax.random.split(key2, 4)

        def bits(kk, shape):
            return jax.random.randint(kk, shape, 0, jnp.iinfo(jnp.int32).max,
                                      jnp.int32).astype(jnp.uint32)

        tables = bits(kt, (k, n, w))
        mask = bits(km, (lanes, w))
        valid = bits(kv, (lanes, w))
        inst = jax.random.randint(ki, (lanes,), 0, k, jnp.int32)
        fn = jax.jit(lambda t_, i, m, v: ref.stacked_count_stats_ref(
            t_, i, m, v))
        t, out_ref = timed(lambda: np.asarray(fn(tables, inst, mask, valid)))
        out_pl = bitset_ops.stacked_count_stats(tables, inst, mask, valid,
                                                interpret=True)
        err = int(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "bitset_stacked",
                     "shape": f"k{k}_n{n}_L{lanes}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": str(err)})
    return rows


def _lane_states(graph, lanes: int) -> VCState:
    """Batch of distinct mid-search states (varied alive masks) so the
    evaluate benchmark sees realistic, non-constant-foldable inputs."""
    key = jax.random.PRNGKey(0)
    w = graph.words
    keep = jax.random.bernoulli(key, 0.8, (lanes, graph.n))
    masks = np.zeros((lanes, w), np.uint32)
    kp = np.asarray(keep)
    for l in range(lanes):
        for v in range(graph.n):
            if kp[l, v]:
                masks[l, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    full = np.asarray(full_mask(graph.n))
    return VCState(alive=jnp.asarray(masks),
                   cover=jnp.asarray((~masks) & full[None, :]),
                   size=jnp.asarray(np.bitwise_count(
                       (~masks) & full[None, :]).sum(axis=1).astype(np.int32)))


def _ds_lane_states(graph, lanes: int) -> DSState:
    """Batch of distinct mid-search dominating-set states (varied dominated
    and candidate masks) mirroring ``_lane_states``."""
    key = jax.random.PRNGKey(1)
    w = graph.words
    kd, kc = jax.random.split(key)
    dom = np.asarray(jax.random.bernoulli(kd, 0.3, (lanes, graph.n)))
    cnd = np.asarray(jax.random.bernoulli(kc, 0.7, (lanes, graph.n)))
    dominated = np.zeros((lanes, w), np.uint32)
    cand = np.zeros((lanes, w), np.uint32)
    for l in range(lanes):
        for v in range(graph.n):
            if dom[l, v]:
                dominated[l, v // 32] |= np.uint32(1) << np.uint32(v % 32)
            if cnd[l, v]:
                cand[l, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    full = np.asarray(full_mask(graph.n))
    chosen = (~cand) & full[None, :]
    return DSState(dominated=jnp.asarray(dominated), cand=jnp.asarray(cand),
                   chosen=jnp.asarray(chosen),
                   size=jnp.asarray(np.bitwise_count(chosen).sum(
                       axis=1).astype(np.int32)))


def _time_variants(variants, states, lanes, n):
    """Time each (name, BinaryProblem) at the engine's unit of work.

    Variants carrying ``evaluate_batch`` (the Pallas problems) are timed
    through it — one kernel launch for all lanes, exactly what the fused
    round executes (DESIGN.md §5.5) — and annotated with the autotuned
    ``tile``/``stages`` their launch resolves to plus the execution
    ``mode``; plain variants go through ``vmap(evaluate)``.
    """
    from repro.kernels import autotune
    pallas_mode = ("compiled" if jax.default_backend() == "tpu"
                   else "interpret")
    choice = autotune.choose(n, num_words(n), lanes=lanes)
    best = jnp.full((lanes,), INF_VALUE, jnp.int32)
    out = {}
    for name, prob in variants:
        batched = prob.evaluate_batch is not None
        if batched:
            fn = jax.jit(lambda s, eb=prob.evaluate_batch: eb(s, best))
        else:
            fn = jax.jit(jax.vmap(
                lambda s, ev=prob.evaluate: ev(s, INF_VALUE)))
        # One batch is ~100µs — best-of-many keeps the regression gate
        # from tripping on OS scheduling noise.
        t, _ = timed(lambda: jax.block_until_ready(fn(states)), repeat=50)
        entry = {
            "sec_per_batch": round(t, 6),
            "nodes_per_sec": round(lanes / t, 1),
            "mode": pallas_mode if batched else "jnp",
        }
        if batched:
            entry["tile"], entry["stages"] = choice.tile, choice.stages
        out[name] = entry
    return out


def run_node_eval(quick: bool = False) -> dict:
    """Fused ``evaluate`` throughput per kernel-layer problem family:
    vc (legacy adapter / fused jnp / fused+Pallas) and ds (fused jnp /
    fused+Pallas) — the DESIGN.md §5.4 bindings measured at the solver's
    actual unit of work."""
    n, p, lanes = (60, 0.15, 16) if quick else (128, 0.1, 64)
    g = gnp_graph(n, p, seed=7)
    out = {"lanes": lanes,
           "unit": "node evaluations / second (CPU; pallas = interpret)"}
    # Problems are built through the registry's capability-checked front
    # door (ISSUE 4); only the pre-fusion baseline bypasses it, since the
    # legacy adapter is deliberately not a registered family.
    vc, ds = registry.get("vc"), registry.get("ds")
    out["vc"] = {
        "instance": f"gnp:{n}:{int(p * 100)}:7",
        "variants": _time_variants([
            ("legacy_callbacks", make_vertex_cover_callbacks(g)),
            ("fused_jnp", vc.build(g)),
            ("fused_pallas", vc.build(g, backend="pallas")),
        ], _lane_states(g, lanes), lanes, n)}
    out["ds"] = {
        "instance": f"gnp:{n}:{int(p * 100)}:7",
        "variants": _time_variants([
            ("fused_jnp", ds.build(g)),
            ("fused_pallas", ds.build(g, backend="pallas")),
        ], _ds_lane_states(g, lanes), lanes, n)}
    return out


def _gate_failures(baseline: dict, fresh: dict,
                   threshold: float = GATE_REGRESSION) -> list:
    """(family, variant) pairs whose fresh nodes/sec regressed more than
    ``threshold`` vs the committed baseline.  Pairs absent from the
    baseline (new variants, first run) pass vacuously."""
    fails = []
    for fam in ("vc", "ds"):
        base_vars = (baseline.get(fam) or {}).get("variants") or {}
        new_vars = (fresh.get(fam) or {}).get("variants") or {}
        for name, new in new_vars.items():
            old = base_vars.get(name) or {}
            old_nps = float(old.get("nodes_per_sec") or 0.0)
            new_nps = float(new["nodes_per_sec"])
            if old_nps > 0 and new_nps < (1.0 - threshold) * old_nps:
                fails.append(
                    f"{fam}/{name}: {new_nps:.0f} nodes/s is "
                    f"{100 * (1 - new_nps / old_nps):.1f}% below the "
                    f"baseline {old_nps:.0f}")
    return fails


def main(quick: bool = False, gate: bool = False) -> None:
    rows = run(quick)
    path = write_csv("kernel_micro.csv", rows,
                     ["kernel", "shape", "ref_ms", "max_abs_err"])
    for r in rows:
        print("kernels,%s,%s,%s,%s" % (r["kernel"], r["shape"],
                                       r["ref_ms"], r["max_abs_err"]))
    print(f"kernel_micro -> {path}")

    node_eval = run_node_eval(quick)
    # Merge-write: keep any per-family entries a previous run recorded that
    # this invocation did not re-measure (mirrors BENCH_service.json).
    # Quick runs live under their own "quick" subtree so the full-size
    # trajectory and the CI smoke shape never overwrite each other.
    merged = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                merged = json.load(f)
        except ValueError:
            merged = {}

    if gate:
        baseline = (merged.get("quick") or {}) if quick else merged
        fails = _gate_failures(baseline, node_eval)
        if fails:
            for msg in fails:
                print(f"GATE FAIL node_eval,{msg}")
            print(f"bench gate: {len(fails)} regression(s) > "
                  f"{int(GATE_REGRESSION * 100)}% — baseline NOT updated")
            sys.exit(1)

    node_eval["meta"] = bench_meta()
    if quick:
        sub = dict(merged.get("quick") or {})
        sub.update(node_eval)
        merged["quick"] = sub
    else:
        merged.update(node_eval)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    for fam in ("vc", "ds"):
        for name, v in node_eval[fam]["variants"].items():
            print("node_eval,%s,%s,%s,%s,%s" % (
                fam, name, v["sec_per_batch"], v["nodes_per_sec"],
                v["mode"]))
    print(f"node_eval -> {BENCH_JSON}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes; results under the 'quick' subtree")
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) on a >20%% nodes/sec regression "
                         "vs the committed baseline")
    args = ap.parse_args()
    main(quick=args.quick, gate=args.gate)
