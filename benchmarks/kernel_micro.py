"""Kernel microbenchmarks (CPU wall time of the jnp reference path +
interpret-mode correctness deltas for the Pallas bodies).

Absolute CPU µs are not TPU predictions; the table documents (a) the
shapes each kernel is exercised at, (b) ref-vs-kernel max abs error, and
(c) the ref path's CPU throughput as a regression canary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed, write_csv
from repro.kernels import ref
from repro.kernels.bitset_degree import degree_argmax
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.problems.graphs import gnp_graph, full_mask


def run(quick: bool = False) -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention
    for (b, s, h, g, hd) in [(1, 512, 8, 2, 64)] + \
            ([] if quick else [(2, 1024, 8, 8, 128)]):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (b, s, g, hd), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (b, s, g, hd), jnp.float32) * 0.5
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        t, out_ref = timed(lambda: np.asarray(fn(q, k, v)))
        out_pl = flash_attention(q, k, v, interpret=True)
        err = float(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "flash_attention",
                     "shape": f"b{b}_s{s}_h{h}_g{g}_d{hd}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": f"{err:.2e}"})

    # ssd scan
    for (b, s, h, p, n, chunk) in [(1, 256, 4, 64, 64, 64)] + \
            ([] if quick else [(2, 512, 8, 64, 128, 128)]):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bb = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
        cc = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
        d = jnp.ones((h,), jnp.float32)
        fn = jax.jit(lambda *args: ref.ssd_scan_ref(*args, chunk=chunk)[0])
        t, out_ref = timed(lambda: np.asarray(fn(x, dt, a, bb, cc, d)))
        out_pl, _ = ssd_scan(x, dt, a, bb, cc, d, chunk=chunk,
                             interpret=True)
        err = float(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "ssd_scan",
                     "shape": f"b{b}_s{s}_h{h}_p{p}_n{n}_c{chunk}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": f"{err:.2e}"})

    # bitset degree/argmax
    for (n, pr, lanes) in [(300, 0.05, 16)] + ([] if quick else
                                               [(512, 0.1, 64)]):
        g = gnp_graph(n, pr, seed=n)
        adj = jnp.asarray(g.adj)
        alive = jnp.tile(jnp.asarray(full_mask(n))[None, :], (lanes, 1))
        fn = jax.jit(lambda a, m: ref.degree_argmax_ref(a, m))
        t, out_ref = timed(lambda: np.asarray(fn(adj, alive)))
        out_pl = degree_argmax(adj, alive, interpret=True)
        err = int(jnp.max(jnp.abs(out_pl - out_ref)))
        rows.append({"kernel": "bitset_degree",
                     "shape": f"n{n}_L{lanes}",
                     "ref_ms": round(t * 1e3, 2),
                     "max_abs_err": str(err)})
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    path = write_csv("kernel_micro.csv", rows,
                     ["kernel", "shape", "ref_ms", "max_abs_err"])
    for r in rows:
        print("kernels,%s,%s,%s,%s" % (r["kernel"], r["shape"],
                                       r["ref_ms"], r["max_abs_err"]))
    print(f"kernel_micro -> {path}")


if __name__ == "__main__":
    main()
