"""Service throughput: continuous batching vs sequential per-instance solves.

The headline claim of the solver service (DESIGN.md §Solver service): K
mixed instances multiplexed over ONE lane pool finish faster than K
dedicated ``solve`` calls run back-to-back with the same lane count.  Two
effects compound:

  * compilation amortization — the stacked tables are jit *arguments*, so
    the service compiles one round for the whole stream, while each
    sequential ``solve`` retraces its instance-specific closures;
  * tail packing — a draining instance's idle lanes are immediately
    retargeted to other tenants instead of spinning until the slowest
    lane finishes.

Writes ``BENCH_service.json`` at the repo root and a CSV artifact; every
optimum is asserted against the serial oracle before timing is reported.
Both legs run through the ``repro.solver.Solver`` facade (ISSUE 4), so
this benchmark doubles as the proof that the session layer adds no
measurable overhead over the pre-facade drivers.

``--backend`` selects the stacked shared-evaluate kernel (DESIGN.md §5.3):
``jnp`` (default), ``pallas`` or ``both``.  The Pallas leg runs the kernel
body in interpret mode on CPU, so its number is a correctness/regression
canary, not a speed claim; on TPU it is the compiled kernel.  The JSON is
merged on write, so recording one backend preserves the other's entry.

``--devices 1,2,4`` adds the mesh-sharding axis (DESIGN.md §9): the same
instance mix is drained by a service sharded over N forced host devices
(``--lanes`` stays PER DEVICE, so the total lane pool grows with N).  On
a CPU host the forced devices share the same cores, so wall-clock cannot
scale; the hardware-neutral scaling metric is ROUNDS-TO-DRAIN, which
falls as the lane pool widens.  The legs run in one subprocess (jax
locks the device count at first init); the 1-device leg is the plain
``jit`` path and must reproduce the in-process service leg's round count
exactly — the sharding infrastructure is proven overhead-free where it
is off.  Results merge-write under the ``device_axis`` key.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import ART_DIR, bench_meta, write_csv
from repro import registry
from repro.problems import gnp_graph, random_regularish_graph
from repro.service import SolveRequest
from repro.solver import Solver, SolverConfig

OUT = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_service.json"))

LANES = 32
SLOTS = 4
STEPS = 64


def instance_mix(quick: bool):
    """K = 8 mixed vc + ds instances of varied sizes (K = 4 quick)."""
    mix = [
        ("vc", gnp_graph(18, 0.30, seed=7)),
        ("ds", gnp_graph(14, 0.25, seed=2)),
        ("vc", random_regularish_graph(20, 4, seed=3)),
        ("ds", gnp_graph(12, 0.30, seed=9)),
        ("vc", gnp_graph(16, 0.35, seed=5)),
        ("ds", gnp_graph(13, 0.30, seed=4)),
        ("vc", gnp_graph(20, 0.25, seed=11)),
        ("ds", gnp_graph(15, 0.25, seed=6)),
    ]
    return mix[:4] if quick else mix


def oracle(family: str, graph) -> int:
    return Solver().oracle(registry.problem(family, graph)).best


def run_sequential(mix, oracles) -> float:
    """Timed region covers ONLY the solves (oracle checks run outside).

    Facade-driven (ISSUE 4): one Solver session, K sequential solves —
    the session layer must add no measurable overhead over the old
    ``core.distributed.solve`` loop it replaced.
    """
    solver = Solver(SolverConfig(lanes=LANES, steps_per_round=STEPS,
                                 bootstrap_rounds=2, bootstrap_steps=4))
    t0 = time.perf_counter()
    best = []
    for family, graph in mix:
        res = solver.solve(registry.problem(family, graph))
        best.append(res.stats.best)
    wall = time.perf_counter() - t0
    for (family, graph), got, want in zip(mix, best, oracles):
        assert got == want, (graph.name, got, want)
    return wall


def run_service(mix, oracles, backend: str = "jnp",
                trace_path: str = None, metrics: bool = False,
                mesh=None, lanes: int = LANES, steps: int = STEPS):
    """Drain the mix through one service; -> (wall_s, rounds_to_drain)."""
    max_n = max(g.n for _, g in mix)
    svc = Solver(SolverConfig(lanes=lanes, steps_per_round=steps,
                              backend=backend, trace_path=trace_path,
                              metrics=metrics, mesh=mesh)).serve(
        max_n=max_n, slots=SLOTS)
    reqs = [SolveRequest(rid=i, graph=g, family=fam)
            for i, (fam, g) in enumerate(mix)]
    t0 = time.perf_counter()
    for r in reqs:
        svc.submit(r)
    results = svc.drain()
    wall = time.perf_counter() - t0
    for i, ((family, graph), want) in enumerate(zip(mix, oracles)):
        assert results[i].optimum == want, (graph.name, results[i].optimum)
    return wall, svc.rounds


def run(quick: bool = False, backend: str = "jnp") -> dict:
    backends = ("jnp", "pallas") if backend == "both" else (backend,)
    mix = instance_mix(quick)
    k = len(mix)
    oracles = [oracle(fam, g) for fam, g in mix]
    seq = run_sequential(mix, oracles)
    out = {
        "workload": [f"{fam}:{g.name}" for fam, g in mix],
        "k_instances": k,
        "lanes": LANES,
        "slots": SLOTS,
        "steps_per_round": STEPS,
        "unit": "instances / second (CPU; end-to-end incl. compilation; "
                "pallas = interpret-mode kernel, a correctness canary)",
        "sequential": {"wall_s": round(seq, 3),
                       "instances_per_sec": round(k / seq, 3)},
    }
    for b in backends:
        svc, svc_rounds = run_service(mix, oracles, backend=b)
        key = "service" if b == "jnp" else f"service_{b}"
        out[key] = {"wall_s": round(svc, 3),
                    "instances_per_sec": round(k / svc, 3),
                    "rounds": svc_rounds}
        out["speedup" if b == "jnp" else f"speedup_{b}"] = round(seq / svc, 2)
        if b == "jnp":
            # Telemetry-overhead leg (DESIGN.md §8): same drain with the
            # metrics registry + JSONL trace on — the acceptance bar is
            # < 5% regression over the plain service leg.  The trace
            # doubles as the standard report artifact for this suite
            # (tools/trace_report.py, wired by benchmarks/run.py).
            trace_dir = os.path.join(ART_DIR, "traces")
            os.makedirs(trace_dir, exist_ok=True)
            trace_path = os.path.join(trace_dir, "service_throughput.jsonl")
            tele, _ = run_service(mix, oracles, backend=b,
                                  trace_path=trace_path, metrics=True)
            out["service_telemetry"] = {
                "wall_s": round(tele, 3),
                "instances_per_sec": round(k / tele, 3),
                "overhead_vs_service": round(tele / svc - 1.0, 4),
                "trace": os.path.relpath(trace_path,
                                         os.path.dirname(ART_DIR)),
            }
    out["meta"] = bench_meta()
    return out


# -- mesh device axis (DESIGN.md §9) -----------------------------------------

#: Axis legs run a deliberately SMALL per-device pool: with 8 lanes x 8
#: steps the 1-device drain takes many rounds and lane-pool width is the
#: binding resource, so adding devices (lanes stay per-device) must cut
#: rounds-to-drain.  The main LANES x STEPS config drains the mix in a
#: couple of rounds — no scaling headroom to measure there.
AX_LANES = 8
AX_STEPS = 8


def _axis_child(devices, quick: bool) -> None:
    """Subprocess body: run every device leg under forced host devices.

    The parent sets XLA_FLAGS before spawning us; jax locks the device
    count at first init, so all legs share one process and one mix.  A
    ``pre_shard`` leg at the MAIN config with mesh=None (the plain jit
    path) is emitted alongside: it is the identical deterministic
    computation to the parent's in-process service leg and gates on it.
    """
    import jax
    mix = instance_mix(quick)
    oracles = [oracle(fam, g) for fam, g in mix]
    k = len(mix)
    wall0, rounds0 = run_service(mix, oracles)
    legs = {}
    for d in devices:
        assert d <= len(jax.devices()), (d, jax.devices())
        mesh = (jax.make_mesh((d,), ("workers",),
                              devices=jax.devices()[:d])
                if d > 1 else None)
        wall, rounds = run_service(mix, oracles, mesh=mesh,
                                   lanes=AX_LANES, steps=AX_STEPS)
        legs[str(d)] = {"devices": d, "lanes_per_device": AX_LANES,
                        "total_lanes": AX_LANES * d, "rounds": rounds,
                        "wall_s": round(wall, 3),
                        "instances_per_sec": round(k / wall, 3)}
    print("DEVICES_RESULT " + json.dumps(
        {"pre_shard": {"rounds": rounds0, "wall_s": round(wall0, 3)},
         "legs": legs}))


def run_devices(devices, quick: bool, baseline: dict = None) -> dict:
    """Spawn the device-axis subprocess, check scaling, -> merged section.

    Scaling is asserted on rounds-to-drain (forced host devices share the
    same CPU cores, so wall-clock is context, not a claim): every d > 1
    leg must drain the mix in FEWER rounds than the 1-device leg.  The
    1-device leg is additionally pinned to the in-process service leg's
    round count — same deterministic computation, so sharding-off must be
    exactly the pre-shard service.
    """
    devices = sorted(set(devices))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        f"{max(devices + [2])}")
    cmd = [sys.executable, "-m", "benchmarks.service_throughput",
           "--_axis-child", ",".join(str(d) for d in devices)]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("DEVICES_RESULT ")][-1]
    res = json.loads(line[len("DEVICES_RESULT "):])
    legs, pre = res["legs"], res["pre_shard"]
    axis = {
        "unit": "rounds-to-drain (hardware-neutral; forced host devices "
                "share CPU cores, wall_s is context only)",
        "lanes_per_device": AX_LANES, "steps_per_round": AX_STEPS,
        "slots": SLOTS,
        "pre_shard": pre,
        "legs": legs,
        "meta": bench_meta(),
    }
    if baseline is not None:
        # Pre-shard noise gate: mesh=None at the main config is the plain
        # jit path — the identical deterministic search, so the round
        # count must REPRODUCE the in-process service leg exactly; the
        # wall band is lenient (fresh-process compile, shared cores).
        assert pre["rounds"] == baseline["rounds"], (
            "pre-shard leg diverged from the in-process service leg",
            pre, baseline)
        assert pre["wall_s"] < 3.0 * baseline["wall_s"] + 1.0, (
            "pre-shard leg wall-clock outside the noise band",
            pre, baseline)
        axis["pre_shard_matches_service"] = True
    if "1" in legs:
        base = legs["1"]
        for d in devices:
            leg = legs[str(d)]
            leg["scaling_rounds"] = round(base["rounds"] / leg["rounds"], 2)
            if d > 1:
                assert leg["rounds"] < base["rounds"], (
                    "no rounds-to-drain scaling", d, legs)
    return axis


def main(quick: bool = False, backend: str = "jnp",
         devices=None) -> None:
    out = run(quick, backend)
    if devices:
        out["device_axis"] = run_devices(list(devices), quick,
                                         baseline=out.get("service"))
    modes = [m for m in ("sequential", "service", "service_telemetry",
                         "service_pallas") if m in out]
    rows = [{"mode": m, "wall_s": out[m]["wall_s"],
             "instances_per_sec": out[m]["instances_per_sec"]}
            for m in modes]
    path = write_csv("service_throughput.csv", rows,
                     ["mode", "wall_s", "instances_per_sec"])
    print(json.dumps(out, indent=1))
    if not quick:
        # Merge-write so recording one backend keeps the other's service
        # entry.  Retained speedups are recomputed against THIS run's
        # sequential baseline (the merged file must stay internally
        # consistent: speedup_* == sequential.wall_s / service_*.wall_s);
        # a retained entry whose wall time came from a different machine
        # is still the previous run's measurement, only its ratio moves.
        merged = {}
        if os.path.exists(OUT):
            try:
                with open(OUT) as f:
                    merged = json.load(f)
            except ValueError:
                merged = {}
        merged.update(out)
        seq_wall = merged["sequential"]["wall_s"]
        for svc_key, sp_key in (("service", "speedup"),
                                ("service_pallas", "speedup_pallas")):
            if svc_key in merged:
                merged[sp_key] = round(seq_wall / merged[svc_key]["wall_s"],
                                       2)
        with open(OUT, "w") as f:
            json.dump(merged, f, indent=1)
            f.write("\n")
        print(f"service -> {OUT}")
    print(f"service -> {path}")


def cli(argv=None) -> None:
    """Module CLI; also the pass-through target for
    ``python -m benchmarks.run --only service -- <args>``."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", choices=["jnp", "pallas", "both"],
                    default="jnp",
                    help="stacked shared-evaluate kernel backend(s) to "
                         "measure (DESIGN.md §5.3)")
    ap.add_argument("--devices", default=None,
                    help="comma list of device counts for the mesh "
                         "sharding axis, e.g. 1,2,4 (DESIGN.md §9; runs "
                         "in a subprocess with forced host devices)")
    ap.add_argument("--_axis-child", dest="axis_child", default=None,
                    help=argparse.SUPPRESS)
    a = ap.parse_args(argv)
    if a.axis_child:
        _axis_child([int(x) for x in a.axis_child.split(",")], a.quick)
        return
    devices = ([int(x) for x in a.devices.split(",")]
               if a.devices else None)
    main(a.quick, a.backend, devices=devices)


if __name__ == "__main__":
    cli()
