"""Service throughput: continuous batching vs sequential per-instance solves.

The headline claim of the solver service (DESIGN.md §Solver service): K
mixed instances multiplexed over ONE lane pool finish faster than K
dedicated ``solve`` calls run back-to-back with the same lane count.  Two
effects compound:

  * compilation amortization — the stacked tables are jit *arguments*, so
    the service compiles one round for the whole stream, while each
    sequential ``solve`` retraces its instance-specific closures;
  * tail packing — a draining instance's idle lanes are immediately
    retargeted to other tenants instead of spinning until the slowest
    lane finishes.

Writes ``BENCH_service.json`` at the repo root and a CSV artifact; every
optimum is asserted against the serial oracle before timing is reported.
Both legs run through the ``repro.solver.Solver`` facade (ISSUE 4), so
this benchmark doubles as the proof that the session layer adds no
measurable overhead over the pre-facade drivers.

``--backend`` selects the stacked shared-evaluate kernel (DESIGN.md §5.3):
``jnp`` (default), ``pallas`` or ``both``.  The Pallas leg runs the kernel
body in interpret mode on CPU, so its number is a correctness/regression
canary, not a speed claim; on TPU it is the compiled kernel.  The JSON is
merged on write, so recording one backend preserves the other's entry.
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.common import ART_DIR, bench_meta, write_csv
from repro import registry
from repro.problems import gnp_graph, random_regularish_graph
from repro.service import SolveRequest
from repro.solver import Solver, SolverConfig

OUT = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_service.json"))

LANES = 32
SLOTS = 4
STEPS = 64


def instance_mix(quick: bool):
    """K = 8 mixed vc + ds instances of varied sizes (K = 4 quick)."""
    mix = [
        ("vc", gnp_graph(18, 0.30, seed=7)),
        ("ds", gnp_graph(14, 0.25, seed=2)),
        ("vc", random_regularish_graph(20, 4, seed=3)),
        ("ds", gnp_graph(12, 0.30, seed=9)),
        ("vc", gnp_graph(16, 0.35, seed=5)),
        ("ds", gnp_graph(13, 0.30, seed=4)),
        ("vc", gnp_graph(20, 0.25, seed=11)),
        ("ds", gnp_graph(15, 0.25, seed=6)),
    ]
    return mix[:4] if quick else mix


def oracle(family: str, graph) -> int:
    return Solver().oracle(registry.problem(family, graph)).best


def run_sequential(mix, oracles) -> float:
    """Timed region covers ONLY the solves (oracle checks run outside).

    Facade-driven (ISSUE 4): one Solver session, K sequential solves —
    the session layer must add no measurable overhead over the old
    ``core.distributed.solve`` loop it replaced.
    """
    solver = Solver(SolverConfig(lanes=LANES, steps_per_round=STEPS,
                                 bootstrap_rounds=2, bootstrap_steps=4))
    t0 = time.perf_counter()
    best = []
    for family, graph in mix:
        res = solver.solve(registry.problem(family, graph))
        best.append(res.stats.best)
    wall = time.perf_counter() - t0
    for (family, graph), got, want in zip(mix, best, oracles):
        assert got == want, (graph.name, got, want)
    return wall


def run_service(mix, oracles, backend: str = "jnp",
                trace_path: str = None, metrics: bool = False) -> float:
    max_n = max(g.n for _, g in mix)
    svc = Solver(SolverConfig(lanes=LANES, steps_per_round=STEPS,
                              backend=backend, trace_path=trace_path,
                              metrics=metrics)).serve(max_n=max_n,
                                                      slots=SLOTS)
    reqs = [SolveRequest(rid=i, graph=g, family=fam)
            for i, (fam, g) in enumerate(mix)]
    t0 = time.perf_counter()
    for r in reqs:
        svc.submit(r)
    results = svc.drain()
    wall = time.perf_counter() - t0
    for i, ((family, graph), want) in enumerate(zip(mix, oracles)):
        assert results[i].optimum == want, (graph.name, results[i].optimum)
    return wall


def run(quick: bool = False, backend: str = "jnp") -> dict:
    backends = ("jnp", "pallas") if backend == "both" else (backend,)
    mix = instance_mix(quick)
    k = len(mix)
    oracles = [oracle(fam, g) for fam, g in mix]
    seq = run_sequential(mix, oracles)
    out = {
        "workload": [f"{fam}:{g.name}" for fam, g in mix],
        "k_instances": k,
        "lanes": LANES,
        "slots": SLOTS,
        "steps_per_round": STEPS,
        "unit": "instances / second (CPU; end-to-end incl. compilation; "
                "pallas = interpret-mode kernel, a correctness canary)",
        "sequential": {"wall_s": round(seq, 3),
                       "instances_per_sec": round(k / seq, 3)},
    }
    for b in backends:
        svc = run_service(mix, oracles, backend=b)
        key = "service" if b == "jnp" else f"service_{b}"
        out[key] = {"wall_s": round(svc, 3),
                    "instances_per_sec": round(k / svc, 3)}
        out["speedup" if b == "jnp" else f"speedup_{b}"] = round(seq / svc, 2)
        if b == "jnp":
            # Telemetry-overhead leg (DESIGN.md §8): same drain with the
            # metrics registry + JSONL trace on — the acceptance bar is
            # < 5% regression over the plain service leg.  The trace
            # doubles as the standard report artifact for this suite
            # (tools/trace_report.py, wired by benchmarks/run.py).
            trace_dir = os.path.join(ART_DIR, "traces")
            os.makedirs(trace_dir, exist_ok=True)
            trace_path = os.path.join(trace_dir, "service_throughput.jsonl")
            tele = run_service(mix, oracles, backend=b,
                               trace_path=trace_path, metrics=True)
            out["service_telemetry"] = {
                "wall_s": round(tele, 3),
                "instances_per_sec": round(k / tele, 3),
                "overhead_vs_service": round(tele / svc - 1.0, 4),
                "trace": os.path.relpath(trace_path,
                                         os.path.dirname(ART_DIR)),
            }
    out["meta"] = bench_meta()
    return out


def main(quick: bool = False, backend: str = "jnp") -> None:
    out = run(quick, backend)
    modes = [m for m in ("sequential", "service", "service_telemetry",
                         "service_pallas") if m in out]
    rows = [{"mode": m, "wall_s": out[m]["wall_s"],
             "instances_per_sec": out[m]["instances_per_sec"]}
            for m in modes]
    path = write_csv("service_throughput.csv", rows,
                     ["mode", "wall_s", "instances_per_sec"])
    print(json.dumps(out, indent=1))
    if not quick:
        # Merge-write so recording one backend keeps the other's service
        # entry.  Retained speedups are recomputed against THIS run's
        # sequential baseline (the merged file must stay internally
        # consistent: speedup_* == sequential.wall_s / service_*.wall_s);
        # a retained entry whose wall time came from a different machine
        # is still the previous run's measurement, only its ratio moves.
        merged = {}
        if os.path.exists(OUT):
            try:
                with open(OUT) as f:
                    merged = json.load(f)
            except ValueError:
                merged = {}
        merged.update(out)
        seq_wall = merged["sequential"]["wall_s"]
        for svc_key, sp_key in (("service", "speedup"),
                                ("service_pallas", "speedup_pallas")):
            if svc_key in merged:
                merged[sp_key] = round(seq_wall / merged[svc_key]["wall_s"],
                                       2)
        with open(OUT, "w") as f:
            json.dump(merged, f, indent=1)
            f.write("\n")
        print(f"service -> {OUT}")
    print(f"service -> {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", choices=["jnp", "pallas", "both"],
                    default="jnp",
                    help="stacked shared-evaluate kernel backend(s) to "
                         "measure (DESIGN.md §5.3)")
    a = ap.parse_args()
    main(a.quick, a.backend)
