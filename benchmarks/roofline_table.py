"""Roofline table: aggregate the dry-run artifacts into the EXPERIMENTS.md
§Roofline table (single-pod baselines; multi-pod rows prove the pod axis)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART_DIR, write_csv

DRY_DIR = os.path.join(ART_DIR, "dryrun")

FIELDS = ["arch", "shape", "mesh", "kind", "peak_GB", "tpu_peak_GB", "fits",
          "compute_s", "memory_s", "collective_s", "dominant",
          "useful_flops_ratio", "roofline_fraction"]


def rows_from_artifacts(tag: str = "") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if (tag and not base.endswith("__" + tag)) or \
                (not tag and len(parts) != 3):
            continue
        with open(path) as f:
            d = json.load(f)
        if d.get("skipped") or "roofline" not in d:
            continue               # solver-round artifacts use another schema
        r = d["roofline"]
        m = d["memory"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "kind": d["kind"],
            "peak_GB": round(m["peak_bytes"] / 2 ** 30, 2),
            "tpu_peak_GB": round(
                m.get("peak_bytes_tpu_modeled", m["peak_bytes"]) / 2 ** 30,
                2),
            "fits": m["fits_16GB"],
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "dominant": r["dominant"].replace("_s", ""),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "roofline_fraction": round(r["roofline_fraction"], 4),
        })
    return rows


def main(quick: bool = False) -> None:
    rows = rows_from_artifacts()
    if not rows:
        print("roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all)")
        return
    path = write_csv("roofline_table.csv", rows, FIELDS)
    for r in rows:
        print("roofline,%s,%s,%s,%s,%s,%s,%s,%s" % (
            r["arch"], r["shape"], r["mesh"], r["dominant"],
            r["compute_s"], r["memory_s"], r["collective_s"],
            r["roofline_fraction"]))
    print(f"roofline -> {path}")


if __name__ == "__main__":
    main()
