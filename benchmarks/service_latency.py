"""Request latency under load: scheduling policies on a Poisson trace.

The ticketed service (DESIGN.md §7) exists so a deployment can express
request lifecycles — priorities, deadlines, eviction — instead of batch
drains.  This benchmark measures what that buys: a Poisson arrival trace
of mixed HARD (large, minutes-of-rounds) and EASY (small,
latency-sensitive, deadline-carrying) requests is replayed against the
same service under each scheduling policy (``fifo`` — the pre-ticket
baseline, ``priority``, ``sjf``), and we record per-request latency
(submission → resolution, in service rounds and wall seconds) and the
deadline-hit rate.

The claim under test: with slots scarce, FIFO lets early-arriving hard
requests head-of-line-block the easy deadline traffic into expiry, while
priority scheduling admits the easy requests first and meets their
deadlines — priority must be >= fifo on deadline-hit rate (asserted).

Writes ``BENCH_service.json`` (merge-write, key ``latency``) and a CSV
artifact; every DONE optimum is asserted against the serial oracle.  The
trace is deterministic (seeded) so latencies in rounds are reproducible;
wall-clock numbers are environment-dependent context.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_meta, write_csv
from repro import registry
from repro.problems import gnp_graph
from repro.service import SolveRequest, TicketStatus
from repro.solver import Solver, SolverConfig

OUT = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_service.json"))

LANES = 16
SLOTS = 2
STEPS = 4
POLICIES = ("fifo", "priority", "sjf")
EASY_PRIORITY = 5
EASY_DEADLINE = 80            # rounds from submission
MEAN_GAP = 2.0                # Poisson arrivals: mean inter-arrival rounds


def poisson_trace(quick: bool):
    """[(arrival_round, SolveRequest)] — hard requests front-loaded, easy
    deadline-carrying requests arriving into the resulting contention.

    Hard jobs are dominating set on SPARSE graphs (weak coverage bound →
    thousands of search nodes, hundreds of service rounds); one of the
    early hard jobs is medium-sized so a slot frees inside the easy
    requests' deadline window — that freed slot is exactly where the
    scheduling policy decides who lives: FIFO hands it to the next queued
    hard job, priority/sjf to the deadline traffic.
    """
    if quick:
        hard = [("ds", gnp_graph(24, 0.12, seed=100)),
                ("ds", gnp_graph(20, 0.20, seed=101))]
        n_easy = 3
    else:
        hard = [("ds", gnp_graph(30, 0.10, seed=100)),   # long
                ("ds", gnp_graph(22, 0.15, seed=101)),   # medium: frees slot
                ("ds", gnp_graph(30, 0.10, seed=102)),   # long
                ("ds", gnp_graph(28, 0.10, seed=103))]   # long
        n_easy = 6
    easy = [("vc" if i % 2 else "ds", gnp_graph(12 + i % 3, 0.30, seed=i))
            for i in range(n_easy)]
    rng = np.random.default_rng(7)
    gaps = rng.exponential(scale=MEAN_GAP, size=len(hard) + n_easy)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    trace = []
    for i, (fam, g) in enumerate(hard + easy):
        is_easy = i >= len(hard)
        trace.append((int(arrivals[i]), SolveRequest(
            rid=i, graph=g, family=fam,
            priority=EASY_PRIORITY if is_easy else 0,
            deadline_rounds=EASY_DEADLINE if is_easy else None)))
    return trace


def replay(trace, scheduler: str, oracles) -> dict:
    svc = Solver(SolverConfig(lanes=LANES, steps_per_round=STEPS,
                              scheduler=scheduler)).serve(
        max_n=max(r.graph.n for _, r in trace), slots=SLOTS)
    pending = sorted(trace, key=lambda a: a[0])
    tickets, t_submit, t_finish = {}, {}, {}
    while pending or svc._has_work():
        while pending and pending[0][0] <= svc.rounds:
            _, req = pending.pop(0)
            tickets[req.rid] = svc.submit(req)
            t_submit[req.rid] = time.perf_counter()
        svc.step_round()
        for rid, t in tickets.items():
            if rid not in t_finish and t.done():
                t_finish[rid] = time.perf_counter()

    lat_rounds, lat_wall, with_deadline, hits = [], [], 0, 0
    for arrival, req in trace:
        t = tickets[req.rid]
        if t.status is TicketStatus.DONE:
            assert svc.results[req.rid].optimum == oracles[req.rid], req.rid
            lat_rounds.append(t.finished_round - arrival)
            lat_wall.append(t_finish[req.rid] - t_submit[req.rid])
        if req.deadline_rounds is not None:
            with_deadline += 1
            hits += t.status is TicketStatus.DONE
    pct = (lambda xs, q: round(float(np.percentile(xs, q)), 3)
           if xs else None)
    return {
        "completed": len(lat_rounds),
        "expired": sum(t.status is TicketStatus.EXPIRED
                       for t in tickets.values()),
        "p50_latency_rounds": pct(lat_rounds, 50),
        "p95_latency_rounds": pct(lat_rounds, 95),
        "p50_latency_s": pct(lat_wall, 50),
        "p95_latency_s": pct(lat_wall, 95),
        "deadline_hit_rate": round(hits / with_deadline, 3),
        "total_rounds": svc.rounds,
    }


def run(quick: bool = False) -> dict:
    trace = poisson_trace(quick)
    oracles = {r.rid: Solver().oracle(registry.problem(r.family,
                                                       r.graph)).best
               for _, r in trace}
    n_deadline = sum(r.deadline_rounds is not None for _, r in trace)
    out = {
        "workload": {
            "requests": len(trace),
            "with_deadline": n_deadline,
            "deadline_rounds": EASY_DEADLINE,
            "mean_arrival_gap_rounds": MEAN_GAP,
            "lanes": LANES, "slots": SLOTS, "steps_per_round": STEPS,
        },
        "unit": "request latency submission->resolution (service rounds; "
                "wall seconds are CPU context)",
    }
    for policy in POLICIES:
        out[policy] = replay(trace, policy, oracles)
    # The headline claim: priority scheduling keeps deadline traffic alive
    # that FIFO head-of-line-blocks into expiry.
    assert out["priority"]["deadline_hit_rate"] >= \
        out["fifo"]["deadline_hit_rate"], out
    return out


def main(quick: bool = False) -> None:
    out = run(quick)
    rows = [{"policy": p, **{k: v for k, v in out[p].items()}}
            for p in POLICIES]
    path = write_csv("service_latency.csv", rows,
                     ["policy"] + [k for k in rows[0] if k != "policy"])
    print(json.dumps(out, indent=1))
    if not quick:
        merged = {}
        if os.path.exists(OUT):
            try:
                with open(OUT) as f:
                    merged = json.load(f)
            except ValueError:
                merged = {}
        out["meta"] = bench_meta()
        merged["latency"] = out
        with open(OUT, "w") as f:
            json.dump(merged, f, indent=1)
            f.write("\n")
        print(f"service latency -> {OUT}")
    print(f"service latency -> {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    main(a.quick)
