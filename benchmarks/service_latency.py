"""Request latency under load: scheduling policies on a Poisson trace.

The ticketed service (DESIGN.md §7) exists so a deployment can express
request lifecycles — priorities, deadlines, eviction — instead of batch
drains.  This benchmark measures what that buys: a Poisson arrival trace
of mixed HARD (large, minutes-of-rounds) and EASY (small,
latency-sensitive, deadline-carrying) requests is replayed against the
same service under each scheduling policy (``fifo`` — the pre-ticket
baseline, ``priority``, ``sjf``), and we record per-request latency
(submission → resolution, in service rounds and wall seconds) and the
deadline-hit rate.

The claim under test: with slots scarce, FIFO lets early-arriving hard
requests head-of-line-block the easy deadline traffic into expiry, while
priority scheduling admits the easy requests first and meets their
deadlines — priority must be >= fifo on deadline-hit rate (asserted).

Writes ``BENCH_service.json`` (merge-write, key ``latency``) and a CSV
artifact; every DONE optimum is asserted against the serial oracle.  The
trace is deterministic (seeded) so latencies in rounds are reproducible;
wall-clock numbers are environment-dependent context.

``--devices 1,2,4`` adds the mesh-sharding axis (DESIGN.md §9): the same
trace replays under the ``priority`` policy with the lane pool sharded
over N forced host devices (``LANES`` is per device).  Wider pools drain
the hard head-of-line jobs in fewer rounds, so total rounds and the
latency percentiles (in rounds — the hardware-neutral unit) must fall;
the legs run in one subprocess, same pattern as service_throughput.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import bench_meta, write_csv
from repro import registry
from repro.problems import gnp_graph
from repro.service import SolveRequest, TicketStatus
from repro.solver import Solver, SolverConfig

OUT = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_service.json"))

LANES = 16
SLOTS = 2
STEPS = 4
POLICIES = ("fifo", "priority", "sjf")
EASY_PRIORITY = 5
EASY_DEADLINE = 80            # rounds from submission
MEAN_GAP = 2.0                # Poisson arrivals: mean inter-arrival rounds


def poisson_trace(quick: bool):
    """[(arrival_round, SolveRequest)] — hard requests front-loaded, easy
    deadline-carrying requests arriving into the resulting contention.

    Hard jobs are dominating set on SPARSE graphs (weak coverage bound →
    thousands of search nodes, hundreds of service rounds); one of the
    early hard jobs is medium-sized so a slot frees inside the easy
    requests' deadline window — that freed slot is exactly where the
    scheduling policy decides who lives: FIFO hands it to the next queued
    hard job, priority/sjf to the deadline traffic.
    """
    if quick:
        hard = [("ds", gnp_graph(24, 0.12, seed=100)),
                ("ds", gnp_graph(20, 0.20, seed=101))]
        n_easy = 3
    else:
        hard = [("ds", gnp_graph(30, 0.10, seed=100)),   # long
                ("ds", gnp_graph(22, 0.15, seed=101)),   # medium: frees slot
                ("ds", gnp_graph(30, 0.10, seed=102)),   # long
                ("ds", gnp_graph(28, 0.10, seed=103))]   # long
        n_easy = 6
    easy = [("vc" if i % 2 else "ds", gnp_graph(12 + i % 3, 0.30, seed=i))
            for i in range(n_easy)]
    rng = np.random.default_rng(7)
    gaps = rng.exponential(scale=MEAN_GAP, size=len(hard) + n_easy)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    trace = []
    for i, (fam, g) in enumerate(hard + easy):
        is_easy = i >= len(hard)
        trace.append((int(arrivals[i]), SolveRequest(
            rid=i, graph=g, family=fam,
            priority=EASY_PRIORITY if is_easy else 0,
            deadline_rounds=EASY_DEADLINE if is_easy else None)))
    return trace


def replay(trace, scheduler: str, oracles, mesh=None) -> dict:
    svc = Solver(SolverConfig(lanes=LANES, steps_per_round=STEPS,
                              scheduler=scheduler, mesh=mesh)).serve(
        max_n=max(r.graph.n for _, r in trace), slots=SLOTS)
    pending = sorted(trace, key=lambda a: a[0])
    tickets, t_submit, t_finish = {}, {}, {}
    while pending or svc._has_work():
        while pending and pending[0][0] <= svc.rounds:
            _, req = pending.pop(0)
            tickets[req.rid] = svc.submit(req)
            t_submit[req.rid] = time.perf_counter()
        svc.step_round()
        for rid, t in tickets.items():
            if rid not in t_finish and t.done():
                t_finish[rid] = time.perf_counter()

    lat_rounds, lat_wall, with_deadline, hits = [], [], 0, 0
    for arrival, req in trace:
        t = tickets[req.rid]
        if t.status is TicketStatus.DONE:
            assert svc.results[req.rid].optimum == oracles[req.rid], req.rid
            lat_rounds.append(t.finished_round - arrival)
            lat_wall.append(t_finish[req.rid] - t_submit[req.rid])
        if req.deadline_rounds is not None:
            with_deadline += 1
            hits += t.status is TicketStatus.DONE
    pct = (lambda xs, q: round(float(np.percentile(xs, q)), 3)
           if xs else None)
    return {
        "completed": len(lat_rounds),
        "expired": sum(t.status is TicketStatus.EXPIRED
                       for t in tickets.values()),
        "p50_latency_rounds": pct(lat_rounds, 50),
        "p95_latency_rounds": pct(lat_rounds, 95),
        "p50_latency_s": pct(lat_wall, 50),
        "p95_latency_s": pct(lat_wall, 95),
        "deadline_hit_rate": round(hits / with_deadline, 3),
        "total_rounds": svc.rounds,
    }


def run(quick: bool = False) -> dict:
    trace = poisson_trace(quick)
    oracles = {r.rid: Solver().oracle(registry.problem(r.family,
                                                       r.graph)).best
               for _, r in trace}
    n_deadline = sum(r.deadline_rounds is not None for _, r in trace)
    out = {
        "workload": {
            "requests": len(trace),
            "with_deadline": n_deadline,
            "deadline_rounds": EASY_DEADLINE,
            "mean_arrival_gap_rounds": MEAN_GAP,
            "lanes": LANES, "slots": SLOTS, "steps_per_round": STEPS,
        },
        "unit": "request latency submission->resolution (service rounds; "
                "wall seconds are CPU context)",
    }
    for policy in POLICIES:
        out[policy] = replay(trace, policy, oracles)
    # The headline claim: priority scheduling keeps deadline traffic alive
    # that FIFO head-of-line-blocks into expiry.
    assert out["priority"]["deadline_hit_rate"] >= \
        out["fifo"]["deadline_hit_rate"], out
    return out


# -- mesh device axis (DESIGN.md §9) -----------------------------------------

def _axis_child(devices, quick: bool) -> None:
    """Subprocess body: replay the trace per device count (priority
    policy); the parent forced the host device count before spawning."""
    import jax
    trace = poisson_trace(quick)
    oracles = {r.rid: Solver().oracle(registry.problem(r.family,
                                                       r.graph)).best
               for _, r in trace}
    legs = {}
    for d in devices:
        assert d <= len(jax.devices()), (d, jax.devices())
        mesh = (jax.make_mesh((d,), ("workers",),
                              devices=jax.devices()[:d])
                if d > 1 else None)
        rep = replay(trace, "priority", oracles, mesh=mesh)
        legs[str(d)] = {"devices": d, "lanes_per_device": LANES,
                        "total_lanes": LANES * d,
                        "total_rounds": rep["total_rounds"],
                        "p50_latency_rounds": rep["p50_latency_rounds"],
                        "p95_latency_rounds": rep["p95_latency_rounds"],
                        "deadline_hit_rate": rep["deadline_hit_rate"],
                        "completed": rep["completed"]}
    print("DEVICES_RESULT " + json.dumps(legs))


def run_devices(devices, quick: bool) -> dict:
    """Spawn the device-axis subprocess; scaling asserted on the total
    rounds-to-drain of the priority replay (latency percentiles are
    recorded context — the easy traffic is already near the floor)."""
    devices = sorted(set(devices))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        f"{max(devices + [2])}")
    cmd = [sys.executable, "-m", "benchmarks.service_latency",
           "--_axis-child", ",".join(str(d) for d in devices)]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("DEVICES_RESULT ")][-1]
    legs = json.loads(line[len("DEVICES_RESULT "):])
    axis = {
        "unit": "priority-policy replay; rounds are hardware-neutral, "
                "wider pools must drain in fewer total rounds",
        "policy": "priority", "lanes_per_device": LANES,
        "legs": legs,
        "meta": bench_meta(),
    }
    if "1" in legs:
        base = legs["1"]
        for d in devices:
            leg = legs[str(d)]
            leg["scaling_rounds"] = round(
                base["total_rounds"] / leg["total_rounds"], 2)
            if d > 1:
                assert leg["total_rounds"] < base["total_rounds"], (
                    "no rounds-to-drain scaling", d, legs)
                assert leg["deadline_hit_rate"] >= \
                    base["deadline_hit_rate"], (d, legs)
    return axis


def main(quick: bool = False, devices=None) -> None:
    out = run(quick)
    if devices:
        out["device_axis"] = run_devices(list(devices), quick)
    rows = [{"policy": p, **{k: v for k, v in out[p].items()}}
            for p in POLICIES]
    path = write_csv("service_latency.csv", rows,
                     ["policy"] + [k for k in rows[0] if k != "policy"])
    print(json.dumps(out, indent=1))
    if not quick:
        merged = {}
        if os.path.exists(OUT):
            try:
                with open(OUT) as f:
                    merged = json.load(f)
            except ValueError:
                merged = {}
        out["meta"] = bench_meta()
        merged["latency"] = out
        with open(OUT, "w") as f:
            json.dump(merged, f, indent=1)
            f.write("\n")
        print(f"service latency -> {OUT}")
    print(f"service latency -> {path}")


def cli(argv=None) -> None:
    """Module CLI; also the pass-through target for
    ``python -m benchmarks.run --only latency -- <args>``."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", default=None,
                    help="comma list of device counts for the mesh "
                         "sharding axis, e.g. 1,2,4 (DESIGN.md §9)")
    ap.add_argument("--_axis-child", dest="axis_child", default=None,
                    help=argparse.SUPPRESS)
    a = ap.parse_args(argv)
    if a.axis_child:
        _axis_child([int(x) for x in a.axis_child.split(",")], a.quick)
        return
    main(a.quick, devices=[int(x) for x in a.devices.split(",")]
         if a.devices else None)


if __name__ == "__main__":
    cli()
