"""Summarize a JSONL solve/service trace (``repro.obs``, DESIGN.md §8).

Reads a trace written by ``SolverConfig(trace_path=...)`` (either driver),
re-validates every record against the shared schema tables, cross-checks
the internal accounting (per-instance node counts must sum to the engine
total, which must equal the per-lane sum) and prints the load-balance
story the paper cares about:

  * lane utilization: mean active fraction per round + idle percentage;
  * balance: Gini coefficient over per-lane node totals (0 = perfectly
    even exploration, 1 = one lane did everything);
  * steal efficiency: received / requested, split intra- vs cross-device,
    plus shipped-subtree root-depth stats (shallow = heavy tasks — the
    paper's weight heuristic working as intended);
  * tree shape: nodes, steps, kernel dispatches, per-instance node totals;
  * service runs additionally get the request ledger (admit/retire/expire/
    cancel/reject counts, wait/run round stats, peak queue depth).

Usage:

  python tools/trace_report.py TRACE.jsonl [--json]

Exit status: 0 on a clean report, 2 on a schema violation or an internal
inconsistency (``TraceError``) — the CI ``trace-smoke`` step gates on
this.  Import :func:`analyze` for programmatic use (the benchmark harness
and tests do).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.trace import (TRACE_SCHEMA_VERSION, TraceError,  # noqa: E402
                             read_trace)

#: The engine's "no solution yet" sentinel (repro.core.api.INF_VALUE);
#: duplicated here so report generation never imports jax.
_INF_VALUE = 1 << 30


def gini(values: List[int]) -> float:
    """Gini coefficient of a non-negative sample (0 = even, →1 = skewed)."""
    vals = sorted(float(v) for v in values)
    n = len(vals)
    total = sum(vals)
    if n == 0 or total == 0:
        return 0.0
    # Standard rank formula: G = (2·Σ i·x_i)/(n·Σ x) − (n+1)/n, i 1-based.
    weighted = sum(i * v for i, v in enumerate(vals, 1))
    return 2.0 * weighted / (n * total) - (n + 1.0) / n


def _stats(sample: List[float]) -> dict:
    if not sample:
        return {"count": 0, "mean": 0.0, "min": 0, "max": 0}
    return {"count": len(sample), "mean": sum(sample) / len(sample),
            "min": min(sample), "max": max(sample)}


def analyze(records: List[dict]) -> dict:
    """Trace records -> report dict; raises TraceError on inconsistency."""
    if not records:
        raise TraceError("empty trace: no records")
    meta = records[0]
    if meta["t"] != "meta":
        raise TraceError(
            f"first record must be 'meta', got {meta['t']!r}")
    if meta["schema"] != TRACE_SCHEMA_VERSION:
        raise TraceError(
            f"trace schema {meta['schema']} != reader schema "
            f"{TRACE_SCHEMA_VERSION}")
    summaries = [r for r in records if r["t"] == "summary"]
    if not summaries:
        raise TraceError("trace has no 'summary' record (run incomplete?)")
    summary = summaries[-1]          # a re-drained service appends; use last
    rounds = [r for r in records if r["t"] == "round"]
    lanes = int(meta["lanes"])

    lane_nodes = summary["lane_nodes"]
    inst_nodes = summary["inst_nodes"]
    nodes = int(summary["nodes"])
    if sum(lane_nodes) != nodes:
        raise TraceError(
            f"per-lane node totals sum to {sum(lane_nodes)} but summary "
            f"says {nodes}")
    if sum(inst_nodes) != nodes:
        raise TraceError(
            f"per-instance node totals sum to {sum(inst_nodes)} but "
            f"summary says {nodes}")

    util = [r["active"] / lanes for r in rounds] if lanes else []
    ship = [d for r in rounds for d in r.get("ship_depths", [])]
    recv = sum(r["steal_recv"] for r in rounds)
    req = sum(r["steal_req"] for r in rounds)
    cross = sum(r.get("steal_recv_cross", 0) for r in rounds)

    lifecycle = {}
    for kind in ("admit", "retire", "expire", "cancel", "reject"):
        lifecycle[kind] = sum(1 for r in records if r["t"] == kind)
    waits = [r["waited"] for r in records
             if r["t"] == "admit" and r.get("waited") is not None]
    runs = [r["ran"] for r in records
            if r["t"] in ("retire", "expire", "cancel")
            and r.get("ran") is not None]

    report = {
        "mode": meta["mode"],
        "schema": meta["schema"],
        "lanes": lanes,
        "slots": int(meta["slots"]),
        "rounds": int(summary["rounds"]),
        "nodes": nodes,
        "steps": summary.get("steps"),
        "dispatches": summary.get("dispatches"),
        "best": [b for b in (summary.get("best") or [])
                 if b < _INF_VALUE] or summary.get("best"),
        "lane_nodes": lane_nodes,
        "inst_nodes": inst_nodes,
        "gini_lane_nodes": gini(lane_nodes),
        "mean_utilization": (sum(util) / len(util)) if util else 0.0,
        "idle_pct": 100.0 * (1.0 - (sum(util) / len(util))) if util else 0.0,
        "steal_requests": req,
        "steal_received": recv,
        "steal_received_cross": cross,
        "steal_success_rate": (recv / req) if req else 0.0,
        "ship_depth": _stats([float(d) for d in ship]),
        "incumbent_updates": sum(1 for r in records if r["t"] == "incumbent"),
        "max_queue_depth": max(
            (r.get("queue_depth", 0) for r in rounds), default=0),
        "lifecycle": lifecycle,
        "wait_rounds": _stats([float(w) for w in waits]),
        "run_rounds": _stats([float(x) for x in runs]),
    }
    return report


def render(report: dict) -> str:
    out = []
    out.append(f"trace report — mode={report['mode']} "
               f"lanes={report['lanes']} slots={report['slots']} "
               f"(schema v{report['schema']})")
    out.append(f"  rounds={report['rounds']} nodes={report['nodes']} "
               f"steps={report['steps']} dispatches={report['dispatches']}")
    out.append(f"  load balance: gini={report['gini_lane_nodes']:.3f} "
               f"mean util={report['mean_utilization']:.3f} "
               f"idle={report['idle_pct']:.1f}%")
    rate = report["steal_success_rate"]
    intra = report["steal_received"] - report["steal_received_cross"]
    out.append(f"  stealing: requests={report['steal_requests']} "
               f"received={report['steal_received']} "
               f"(intra={intra} cross={report['steal_received_cross']}) "
               f"success={rate:.1%}")
    ship = report["ship_depth"]
    if ship["count"]:
        out.append(f"  shipped subtrees: {ship['count']} "
                   f"root depth mean={ship['mean']:.1f} "
                   f"min={ship['min']:.0f} max={ship['max']:.0f}")
    out.append(f"  incumbents: {report['incumbent_updates']} updates; "
               f"best={report['best']}")
    out.append("  per-instance nodes: "
               + " ".join(str(n) for n in report["inst_nodes"]))
    if report["mode"] == "service":
        lc = report["lifecycle"]
        out.append("  requests: " + " ".join(
            f"{k}={lc[k]}" for k in
            ("admit", "retire", "expire", "cancel", "reject")))
        wait, run = report["wait_rounds"], report["run_rounds"]
        out.append(f"  latency (rounds): wait mean={wait['mean']:.1f} "
                   f"max={wait['max']:.0f}; run mean={run['mean']:.1f} "
                   f"max={run['max']:.0f}; "
                   f"peak queue={report['max_queue_depth']}")
    return "\n".join(out)


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace path "
                                  "(SolverConfig.trace_path / --trace)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)
    try:
        records = read_trace(args.trace)
        report = analyze(records)
    except (OSError, TraceError) as e:
        print(f"trace_report: {e}", file=sys.stderr)
        return 2
    print(json.dumps(report, indent=2) if args.json else render(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())
