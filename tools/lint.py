"""repro-lint CLI — run the repo's static analysis pass (DESIGN.md §10).

  python tools/lint.py                 # lint src (the default surface)
  python tools/lint.py src tools       # explicit paths (files or dirs)
  python tools/lint.py --json out.json # machine-readable findings (CI)
  python tools/lint.py --list-rules    # rule catalogue
  python tools/lint.py --rule trace-safety src   # one rule only

Exit status: 0 when no error-severity findings, 1 otherwise.  The pass
is stdlib-only (no jax import), so this runs anywhere — including the
dependency-free CI ``lint`` job.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import all_rules, lint_paths  # noqa: E402


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint "
                         "(default: src, resolved against the repo root)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write findings as JSON (use '-' for "
                         "stdout); consumed by the CI artifact upload")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only this rule (repeatable)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for name in sorted(rules):
            cls = rules[name]
            print(f"{name:18s} [{cls.severity}] {cls.description}")
        return 0

    for r in (args.rule or []):
        if r not in rules:
            print(f"lint: unknown rule {r!r} (known: {sorted(rules)})",
                  file=sys.stderr)
            return 2

    result = lint_paths(args.paths or ["src"], root=ROOT, rules=args.rule)

    for f in result.findings:
        print(f.format())
    errors = result.errors
    warnings = [f for f in result.findings if f.severity != "error"]
    print(f"lint: {result.files} files, {len(errors)} error(s), "
          f"{len(warnings)} warning(s), "
          f"{len(result.skipped)} allowlisted file(s) skipped")

    if args.json:
        payload = {
            "files": result.files,
            "errors": len(errors),
            "warnings": len(warnings),
            "skipped": result.skipped,
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "severity": f.severity, "message": f.message}
                for f in result.findings
            ],
        }
        text = json.dumps(payload, indent=2)
        if args.json == "-":
            print(text)
        else:
            pathlib.Path(args.json).write_text(text + "\n",
                                               encoding="utf-8")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
