"""Execute the README's ``bash`` command blocks so documented invocations
can never rot (CI job ``docs-smoke``).

Extraction rules, kept deliberately dumb so the README stays plain
markdown:

  * only fenced blocks whose info string is exactly ``bash`` run;
  * backslash continuations are joined into one command;
  * ``#`` end-of-line comments are allowed (stripped by bash itself);
  * commands matching ``--skip`` (default: ``pytest``, because the tier-1
    suite is its own CI job) are reported and not executed.

The public-API surface check (``tools/api_surface.py``, ISSUE 4) is
appended to the command list so the docs-smoke CI job also fails on
unreviewed ``repro.registry``/``repro.solver`` surface changes
(``--no-api-surface`` opts out).

Usage:

  python tools/docs_smoke.py [--readme README.md] [--list] [--skip REGEX]

Each command runs through ``bash -c`` from the repo root with the
inherited environment; the first failure aborts with its exit code.
"""

from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys
import time

FENCE_RE = re.compile(r"^```(\w*)\s*$")


def extract_bash_commands(text: str) -> list:
    """-> list of commands from ``bash`` fenced blocks, continuations
    joined."""
    commands, in_bash, pending = [], False, ""
    for line in text.splitlines():
        m = FENCE_RE.match(line)
        if m:
            if in_bash and pending:
                commands.append(pending.strip())
                pending = ""
            in_bash = not in_bash and m.group(1) == "bash"
            continue
        if not in_bash:
            continue
        if line.rstrip().endswith("\\"):
            pending += line.rstrip()[:-1] + " "
            continue
        pending += line
        if pending.strip() and not pending.lstrip().startswith("#"):
            commands.append(pending.strip())
        pending = ""
    return commands


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--readme", default="README.md")
    ap.add_argument("--skip", default="pytest",
                    help="regex of commands to report but not execute")
    ap.add_argument("--list", action="store_true",
                    help="print the extracted commands and exit")
    ap.add_argument("--no-api-surface", action="store_true",
                    help="do not append the tools/api_surface.py check")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.readme).resolve().parent
    commands = extract_bash_commands(
        pathlib.Path(args.readme).read_text(encoding="utf-8"))
    if not commands:
        print(f"docs-smoke: no bash commands found in {args.readme}",
              file=sys.stderr)
        return 1
    if not args.no_api_surface:
        commands.append(f"{sys.executable} tools/api_surface.py")

    skip = re.compile(args.skip) if args.skip else None
    if args.list:
        for cmd in commands:
            mark = "SKIP " if skip and skip.search(cmd) else "RUN  "
            print(mark + cmd)
        return 0

    failures = 0
    for i, cmd in enumerate(commands, 1):
        if skip and skip.search(cmd):
            print(f"[{i}/{len(commands)}] SKIP {cmd}", flush=True)
            continue
        print(f"[{i}/{len(commands)}] RUN  {cmd}", flush=True)
        t0 = time.time()
        proc = subprocess.run(["bash", "-c", cmd], cwd=root)
        print(f"[{i}/{len(commands)}] exit={proc.returncode} "
              f"({time.time() - t0:.1f}s)", flush=True)
        if proc.returncode != 0:
            failures = proc.returncode
            break
    if failures:
        print("docs-smoke: FAILED", file=sys.stderr)
        return failures
    print("docs-smoke: all documented commands ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
