"""Public-API surface snapshot for the front-door modules (ISSUE 4/5).

``repro.registry``, ``repro.solver``, ``repro.service`` (the ticketed
request-lifecycle surface: Ticket, SchedulingPolicy, SolverService) and
``repro.obs`` (the telemetry registry + trace schema) are
THE public API: every launcher, benchmark and downstream user goes
through them, so their surface must never change silently.  This tool renders each module's
``__all__`` — dataclass fields, NamedTuple fields, class methods and
function signatures — into a canonical text form and compares it against
the checked-in snapshot ``tools/api_surface.txt``:

  python tools/api_surface.py            # check (exit 1 + diff on drift)
  python tools/api_surface.py --update   # rewrite the snapshot

Run by the docs-smoke CI job (wired through ``tools/docs_smoke.py``) and
by ``tests/test_api_surface.py``, so an unreviewed surface change fails
CI until the snapshot is updated in the same commit — which is exactly
the review hook the snapshot exists to force.
"""

from __future__ import annotations

import argparse
import dataclasses
import difflib
import importlib
import inspect
import os
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

MODULES = ("repro.registry", "repro.solver", "repro.service", "repro.obs",
           "repro.analysis")
SNAPSHOT = pathlib.Path(__file__).resolve().parent / "api_surface.txt"


def _signature(obj) -> str:
    try:
        sig = str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"
    # Callable defaults repr with a memory address; canonicalize so the
    # snapshot is deterministic across processes.
    return re.sub(r"<(function|bound method) ([^ ]+) at 0x[0-9a-f]+>",
                  r"<\1 \2>", sig)


def _const_repr(obj) -> str:
    # Set/dict iteration order varies per process (hash randomization);
    # sort so the snapshot is stable.
    if isinstance(obj, (set, frozenset)):
        body = ", ".join(repr(x) for x in sorted(obj, key=repr))
        return f"{type(obj).__name__}({{{body}}})"
    if isinstance(obj, dict):
        body = ", ".join(f"{k!r}: {_const_repr(v)}"
                         for k, v in sorted(obj.items(), key=lambda kv: repr(kv[0])))
        return f"{{{body}}}"
    return repr(obj)


def _describe_class(name: str, obj: type) -> list:
    lines = []
    if dataclasses.is_dataclass(obj):
        fields = ", ".join(
            f"{f.name}: {getattr(f.type, '__name__', f.type)}"
            for f in dataclasses.fields(obj))
        lines.append(f"  dataclass {name}({fields})")
    elif issubclass(obj, tuple) and hasattr(obj, "_fields"):
        lines.append(f"  namedtuple {name}({', '.join(obj._fields)})")
    else:
        bases = ", ".join(b.__name__ for b in obj.__bases__)
        lines.append(f"  class {name}({bases})")
    for mname, member in sorted(vars(obj).items()):
        if mname.startswith("_") and mname != "__init__":
            continue
        if isinstance(member, property):
            lines.append(f"    property {mname}")
        elif isinstance(member, (classmethod, staticmethod)):
            lines.append(f"    {type(member).__name__} {mname}"
                         f"{_signature(member.__func__)}")
        elif callable(member):
            lines.append(f"    def {mname}{_signature(member)}")
    return lines


def render() -> str:
    out = []
    for modname in MODULES:
        mod = importlib.import_module(modname)
        out.append(f"module {modname}")
        for name in sorted(mod.__all__):
            obj = getattr(mod, name)
            if isinstance(obj, type):
                out.extend(_describe_class(name, obj))
            elif callable(obj):
                out.append(f"  def {name}{_signature(obj)}")
            else:
                out.append(f"  const {name} = {_const_repr(obj)}")
        out.append("")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the snapshot instead of checking")
    ap.add_argument("--check", action="store_true",
                    help="explicit check mode (the default)")
    args = ap.parse_args(argv)

    current = render()
    if args.update:
        SNAPSHOT.write_text(current, encoding="utf-8")
        print(f"api-surface: snapshot updated -> "
              f"{os.path.relpath(SNAPSHOT)}")
        return 0

    if not SNAPSHOT.exists():
        print("api-surface: snapshot missing; run with --update",
              file=sys.stderr)
        return 1
    want = SNAPSHOT.read_text(encoding="utf-8")
    if current == want:
        print(f"api-surface: {', '.join(MODULES)} match the snapshot")
        return 0
    sys.stderr.write(
        "api-surface: PUBLIC API CHANGED — review the diff, then rerun "
        "with --update to accept:\n")
    sys.stderr.writelines(difflib.unified_diff(
        want.splitlines(keepends=True), current.splitlines(keepends=True),
        fromfile="api_surface.txt", tofile="current"))
    return 1


if __name__ == "__main__":
    sys.exit(main())
