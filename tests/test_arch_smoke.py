"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each assigned architecture: instantiate the reduced config, run one
forward and one train step, assert output shapes and no NaNs; then check
prefill+decode agreement with the full forward (the serving path computes
the same function incrementally).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.models.model import Shardings, make_ctx
from repro.train.optim import adamw_init, adamw_update

ARCHS = configs.ARCH_IDS



pytestmark = pytest.mark.slow      # LM-architecture smoke matrix: full CI on main only
def make_batch(cfg, b, s, key):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.n_codebooks:
        toks = jax.random.randint(k1, (b, s, cfg.n_codebooks), 0, cfg.vocab)
        labels = jax.random.randint(k2, (b, s, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(k1, (b, s), 0, cfg.vocab)
        labels = jax.random.randint(k2, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": labels}
    if cfg.vision_tokens:
        batch["vision"] = jax.random.normal(
            k3, (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = configs.smoke(arch)
    params = model.init(cfg, jax.random.PRNGKey(0))
    ctx = make_ctx(cfg, "train", Shardings(None), block_q=16, block_k=16)
    b, s = 2, 32
    batch = make_batch(cfg, b, s, jax.random.PRNGKey(1))
    logits = model.forward(cfg, params, batch, ctx)
    if cfg.n_codebooks:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = configs.smoke(arch)
    params = model.init(cfg, jax.random.PRNGKey(0))
    ctx = make_ctx(cfg, "train", Shardings(None), block_q=16, block_k=16)
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))

    def loss(p):
        if cfg.n_codebooks:
            logits = model.forward(cfg, p, batch, ctx)
            return model.xent(logits, batch["labels"], cfg.vocab)
        return model.loss_fn(cfg, p, batch, ctx)

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    opt = adamw_init(params)
    params2, opt2 = adamw_update(params, grads, opt, step=jnp.int32(1),
                                 lr=1e-3)
    l1 = float(loss(params2))
    assert np.isfinite(l1)
    assert l1 < float(l0) + 1.0         # no explosion after one step


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits(prefill then N decode steps) == logits(full forward)."""
    cfg = configs.smoke(arch)
    params = model.init(cfg, jax.random.PRNGKey(0))
    sh = Shardings(None)
    b, s_pre, n_dec = 2, 16, 4
    s_total = s_pre + n_dec
    batch = make_batch(cfg, b, s_total, jax.random.PRNGKey(1))

    # Reference: full forward over the whole sequence.
    ctx_f = make_ctx(cfg, "train", sh, block_q=8, block_k=8)
    ref = model.forward(cfg, params, batch, ctx_f).astype(jnp.float32)

    # Prefill on the first s_pre tokens.
    pre_batch = {k: (v[:, :s_pre] if v.ndim >= 2 and v.shape[1] == s_total
                     else v) for k, v in batch.items()}
    if "vision" in batch:
        pre_batch["vision"] = batch["vision"]
    ctx_p = make_ctx(cfg, "prefill", sh, block_q=8, block_k=8)
    logits_p, cache = model.prefill(cfg, params, pre_batch, ctx_p)
    cache = model.pad_cache(cfg, cache, s_total)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(ref[:, s_pre - 1]),
        rtol=0.08, atol=0.08)

    # Decode the next tokens one at a time.
    for i in range(n_dec - 1):
        pos = jnp.int32(s_pre + i)
        tok = batch["tokens"][:, s_pre + i:s_pre + i + 1]
        ctx_d = make_ctx(cfg, "decode", sh, pos=pos)
        logits_d, cache = model.decode_step(cfg, params, cache, tok, pos,
                                            ctx_d)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(ref[:, s_pre + i]), rtol=0.08, atol=0.08,
            err_msg=f"{arch} decode step {i}")


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates_abstractly(arch):
    """The FULL config must build abstract params (no allocation) and the
    declared parameter count must match the analytic formula."""
    cfg = configs.get(arch)
    ab = model.abstract(cfg)
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(ab))
    assert total == cfg.param_count(), (total, cfg.param_count())


def test_param_counts_plausible():
    """Sanity: named sizes are in the advertised ballpark."""
    expect = {
        "qwen1.5-32b": (30e9, 36e9),
        "qwen2-7b": (6e9, 9e9),
        "gemma2-27b": (24e9, 30e9),
        "glm4-9b": (8e9, 11e9),
        "internvl2-76b": (66e9, 80e9),   # LM backbone (ViT is a stub)
        "mamba2-130m": (0.1e9, 0.17e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # total (16 experts)
        "mixtral-8x22b": (130e9, 150e9),
        "zamba2-2.7b": (2.2e9, 3.2e9),
        "musicgen-large": (1.5e9, 2.6e9),
    }
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        lo, hi = expect[cfg.name]
        n = cfg.param_count()
        assert lo <= n <= hi, f"{cfg.name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
