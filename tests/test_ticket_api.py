"""ISSUE 5 acceptance tests: the ticketed request-lifecycle API.

Covers (a) the Ticket future surface (status machine, ``result(timeout=)``,
``cancel()`` freeing the slot within one round), (b) the pluggable
scheduling policies (PriorityFifo admission order, ShortestJobFirst keyed
on registered ``size()``, Fifo baseline), (c) deadline / node-budget
eviction with anytime results, (d) the new ProgressEvent kinds
(``incumbent``, ``reject``, ``cancel``, ``expire``), and (e) save/restore
round-tripping an un-drained service: queued (never-admitted) requests and
ticket states — including a cancelled ticket — must match after restore.
"""

import numpy as np
import pytest

from repro import registry
from repro.problems import gnp_graph
from repro.service import (AdmissionError, Fifo, PriorityFifo, SolveRequest,
                           SolverService, Ticket, TicketCancelled,
                           TicketStatus, make_policy)
from repro.service.scheduler import QueueItem
from repro.solver import ConfigError, Solver, SolverConfig

HARD = gnp_graph(18, 0.30, seed=7)            # needs many rounds at small R
EASY = [gnp_graph(12, 0.30, seed=9), gnp_graph(13, 0.30, seed=4),
        gnp_graph(14, 0.25, seed=2)]


def oracle(family, graph):
    return Solver().oracle(registry.problem(family, graph)).best


def serve(slots=1, steps=4, lanes=8, scheduler="priority", on_event=None):
    solver = Solver(SolverConfig(lanes=lanes, steps_per_round=steps,
                                 scheduler=scheduler), on_event=on_event)
    return solver.serve(max_n=18, slots=slots)


# -- the Ticket future --------------------------------------------------------


def test_submit_returns_resolving_ticket():
    svc = serve(steps=16)
    t = svc.submit(SolveRequest(rid=0, graph=EASY[0], family="vc"))
    assert isinstance(t, Ticket)
    assert t.status is TicketStatus.QUEUED and not t.done()
    res = t.result()
    assert t.status is TicketStatus.DONE and t.done()
    assert res.status == "done"
    assert res.optimum == oracle("vc", EASY[0])
    assert t.admitted_round is not None and t.finished_round is not None
    assert res.rid == 0


def test_result_timeout_raises():
    svc = serve(steps=1, lanes=4)
    t = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc"))
    with pytest.raises(TimeoutError, match="unresolved"):
        t.result(timeout=0.0)
    assert t.status in (TicketStatus.QUEUED, TicketStatus.RUNNING)
    assert t.result().optimum == oracle("vc", HARD)   # still resolvable


def test_cancel_queued_ticket():
    svc = serve(slots=1)
    running = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc",
                                      priority=9))
    queued = svc.submit(SolveRequest(rid=1, graph=EASY[0], family="vc"))
    svc.step_round()
    assert queued.status is TicketStatus.QUEUED
    assert queued.cancel()
    assert queued.status is TicketStatus.CANCELLED
    assert not svc.queue                       # removed from the policy heap
    assert not queued.cancel()                 # already terminal: no-op
    with pytest.raises(TicketCancelled):
        queued.result()
    assert running.result().optimum == oracle("vc", HARD)
    assert 1 not in svc.results                # never ran: no anytime result


def test_cancelled_queued_requests_compact_from_the_heap():
    """Dead heap entries (cancelled while queued, never popped under a
    priority policy) must not accumulate — the policy compacts once they
    dominate."""
    svc = serve(slots=1)
    svc.submit(SolveRequest(rid=0, graph=HARD, family="vc", priority=9))
    tickets = [svc.submit(SolveRequest(rid=i, graph=EASY[i % 3],
                                       family="vc"))
               for i in range(1, 20)]
    svc.step_round()
    for t in tickets[:15]:
        assert t.cancel()
    live = [r.rid for r in svc.queue]
    assert live == [16, 17, 18, 19]
    # Dead entries are compacted away once they dominate (small heaps are
    # left alone): the heap stays O(live), not O(everything ever queued).
    assert len(svc.sched.policy._heap) <= 8
    results = svc.drain()
    for rid in live:
        assert results[rid].status == "done"


def test_cancel_running_frees_slot_within_one_round():
    svc = serve(slots=1)
    t = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc"))
    svc.step_round()
    assert t.status is TicketStatus.RUNNING and svc.slot_rid == [0]
    assert t.cancel()
    # The slot and its lanes are reclaimed immediately, not at some later
    # drain: no extra round needed.
    assert t.status is TicketStatus.CANCELLED
    assert svc.slot_rid == [-1]
    assert not np.asarray(svc.lanes.active).any()
    assert (np.asarray(svc.lanes.inst) == -1).all()
    # Best-so-far is recorded as an anytime result; result() still raises.
    assert svc.results[0].status == "cancelled"
    with pytest.raises(TicketCancelled):
        t.result()
    # The freed slot serves the next request exactly.
    nxt = svc.submit(SolveRequest(rid=1, graph=EASY[0], family="vc"))
    assert nxt.result().optimum == oracle("vc", EASY[0])


def test_deadline_eviction_frees_slot_and_keeps_anytime():
    svc = serve(slots=1, steps=2, lanes=4)
    t = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc",
                                deadline_rounds=2))
    svc.step_round()
    assert t.status is TicketStatus.RUNNING
    svc.step_round()                 # the deadline round: evicted at its end
    assert t.status is TicketStatus.EXPIRED
    assert svc.slot_rid == [-1]      # freed within the deadline round itself
    assert not np.asarray(svc.lanes.active).any()
    res = t.result()                 # EXPIRED returns the anytime result
    assert res.status == "expired" and res.retired_round == 2


def test_queued_request_expires_without_running():
    svc = serve(slots=1, steps=2, lanes=4)
    svc.submit(SolveRequest(rid=0, graph=HARD, family="vc", priority=9))
    starved = svc.submit(SolveRequest(rid=1, graph=EASY[0], family="vc",
                                      deadline_rounds=2))
    svc.step_round()
    svc.step_round()
    assert starved.status is TicketStatus.EXPIRED
    res = starved.result()
    assert res.admitted_round == -1 and res.status == "expired"


def test_node_budget_eviction():
    svc = serve(slots=1, steps=2, lanes=4)
    t = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc",
                                node_budget=3))
    svc.step_round()
    svc.step_round()
    assert t.nodes_used >= 3
    assert t.status is TicketStatus.EXPIRED and svc.slot_rid == [-1]
    assert t.result().status == "expired"


# -- scheduling policies ------------------------------------------------------


def admit_order(scheduler, requests):
    events = []
    svc = serve(slots=1, steps=16, scheduler=scheduler,
                on_event=events.append)
    for r in requests:
        svc.submit(r)
    svc.drain()
    return [e.rid for e in events if e.kind == "admit"]


def test_priority_fifo_admission_order():
    reqs = [SolveRequest(rid=0, graph=EASY[0], family="vc", priority=0),
            SolveRequest(rid=1, graph=EASY[1], family="vc", priority=5),
            SolveRequest(rid=2, graph=EASY[2], family="ds", priority=5)]
    # Highest priority first; equal priorities keep submission (FIFO) order.
    assert admit_order("priority", reqs) == [1, 2, 0]


def test_fifo_policy_ignores_priority():
    reqs = [SolveRequest(rid=0, graph=EASY[0], family="vc", priority=0),
            SolveRequest(rid=1, graph=EASY[1], family="vc", priority=5)]
    assert admit_order("fifo", reqs) == [0, 1]


def test_shortest_job_first_keyed_on_registered_size():
    reqs = [SolveRequest(rid=0, graph=HARD, family="vc"),
            SolveRequest(rid=1, graph=EASY[0], family="vc"),
            SolveRequest(rid=2, graph=EASY[2], family="ds")]
    sizes = [registry.instance_size(r.family, r.graph) for r in reqs]
    assert sizes == [18, 12, 14]
    assert admit_order("sjf", reqs) == [1, 2, 0]


def test_policies_are_pluggable_without_the_driver():
    """Any SchedulingPolicy instance plugs into the engine directly — the
    protocol is the whole contract (here: a custom strictly-LIFO policy)."""
    class Lifo(Fifo):
        name = "lifo"

        def key(self, request):
            return ()

        def push(self, item):
            super().push(QueueItem(-item.seq, item.request))

    events = []
    svc = SolverService._create(max_n=18, slots=1, num_lanes=8,
                                steps_per_round=16, scheduler=Lifo(),
                                on_event=events.append)
    for i in range(3):
        svc.submit(SolveRequest(rid=i, graph=EASY[i], family="vc"))
    svc.drain()
    assert [e.rid for e in events if e.kind == "admit"] == [2, 1, 0]


def test_unknown_scheduler_is_config_error():
    with pytest.raises(ConfigError, match="registered policies"):
        Solver(SolverConfig(scheduler="round-robin")).serve(max_n=8, slots=1)
    with pytest.raises(ConfigError, match="policy name"):
        SolverConfig(scheduler="")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("round-robin")


def test_default_policy_matches_legacy_fifo_at_equal_priorities():
    """PriorityFifo at all-default priorities is bitwise the legacy deque:
    same admission order, rounds and optima as the explicit Fifo policy."""
    mix = [("vc", EASY[0]), ("ds", EASY[2]), ("vc", EASY[1]), ("vc", HARD)]
    outcomes = []
    for scheduler in ("priority", "fifo"):
        svc = serve(slots=2, steps=16, scheduler=scheduler)
        for i, (fam, g) in enumerate(mix):
            svc.submit(SolveRequest(rid=i, graph=g, family=fam))
        res = svc.drain()
        outcomes.append([(res[i].optimum, res[i].admitted_round,
                          res[i].retired_round) for i in range(len(mix))])
    assert outcomes[0] == outcomes[1]


# -- the new event kinds ------------------------------------------------------


def test_reject_event_precedes_admission_error():
    events = []
    svc = serve(on_event=events.append)
    with pytest.raises(AdmissionError, match="unknown problem family"):
        svc.submit(SolveRequest(rid=3, graph=EASY[0], family="tsp"))
    with pytest.raises(AdmissionError, match="deadline_rounds"):
        svc.submit(SolveRequest(rid=4, graph=EASY[0], family="vc",
                                deadline_rounds=0))
    svc.submit(SolveRequest(rid=5, graph=EASY[0], family="vc"))
    with pytest.raises(AdmissionError, match="duplicate"):
        svc.submit(SolveRequest(rid=5, graph=EASY[1], family="vc"))
    rejects = [e for e in events if e.kind == "reject"]
    assert [e.rid for e in rejects] == [3, 4, 5]
    assert all(e.reason for e in rejects)


def test_incumbent_stream_is_per_request_and_monotone():
    events = []
    svc = serve(slots=2, steps=4, on_event=events.append)
    a = svc.submit(SolveRequest(rid=0, graph=EASY[0], family="vc"))
    b = svc.submit(SolveRequest(rid=1, graph=EASY[2], family="ds"))
    svc.drain()
    for t, rid in ((a, 0), (b, 1)):
        incs = [e.best for e in events if e.kind == "incumbent"
                and e.rid == rid]
        assert incs, rid
        assert incs == sorted(incs, reverse=True)       # anytime: improving
        assert incs[-1] == svc.results[rid].optimum


def test_cancel_and_expire_events():
    events = []
    svc = serve(slots=2, steps=2, lanes=4, on_event=events.append)
    dead = svc.submit(SolveRequest(rid=0, graph=HARD, family="vc",
                                   deadline_rounds=1))
    gone = svc.submit(SolveRequest(rid=1, graph=HARD, family="vc"))
    svc.step_round()
    gone.cancel()
    assert dead.status is TicketStatus.EXPIRED
    expire = [e for e in events if e.kind == "expire"]
    cancel = [e for e in events if e.kind == "cancel"]
    assert [e.rid for e in expire] == [0]
    assert [e.rid for e in cancel] == [1]


def test_event_order_admit_incumbent_terminal():
    """Per-request event grammar over a mixed drain: every admitted rid's
    event sequence is ``admit`` → ``incumbent``* → exactly ONE terminal
    (``retire`` | ``expire`` | ``cancel``), covering the retirement,
    deadline-eviction and mid-flight-cancellation paths in one trace
    (ISSUE 7 satellite)."""
    events = []
    svc = serve(slots=2, steps=4, lanes=8, on_event=events.append)
    svc.submit(SolveRequest(rid=0, graph=EASY[0], family="vc"))
    svc.submit(SolveRequest(rid=1, graph=HARD, family="vc",
                            deadline_rounds=2))
    gone = svc.submit(SolveRequest(rid=2, graph=HARD, family="ds"))
    for _ in range(50):                 # step until rid 2 holds a slot
        svc.step_round()
        if gone.status is TicketStatus.RUNNING:
            break
    assert gone.status is TicketStatus.RUNNING
    gone.cancel()
    svc.drain()
    for rid, terminal in ((0, "retire"), (1, "expire"), (2, "cancel")):
        seq = [e.kind for e in events if e.rid == rid]
        assert seq and seq[0] == "admit", (rid, seq)
        assert seq[-1] == terminal, (rid, seq)
        assert set(seq[1:-1]) <= {"incumbent"}, (rid, seq)
        assert sum(1 for k in seq
                   if k in ("retire", "expire", "cancel")) == 1, (rid, seq)


# -- checkpointing an un-drained service --------------------------------------


def test_save_restore_roundtrips_queue_and_tickets(tmp_path):
    """Mid-drain save with queued (never-admitted) requests and a cancelled
    ticket: the restored queue must pop in the same order and every ticket
    state must match (ISSUE 5 satellite)."""
    svc = serve(slots=1)
    svc.submit(SolveRequest(rid=0, graph=HARD, family="vc", priority=9))
    svc.submit(SolveRequest(rid=1, graph=EASY[0], family="vc", priority=1))
    svc.submit(SolveRequest(rid=2, graph=EASY[1], family="ds", priority=5,
                            deadline_rounds=400))
    svc.submit(SolveRequest(rid=3, graph=EASY[2], family="vc", priority=3,
                            node_budget=50000))
    svc.step_round()
    svc.tickets[1].cancel()
    assert svc.tickets[0].status is TicketStatus.RUNNING
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)

    svc2 = SolverService.restore(path, num_lanes=16, steps_per_round=8)
    assert svc2.sched.policy.name == "priority"    # policy round-trips
    assert [r.rid for r in svc2.queue] == [r.rid for r in svc.queue] == [2, 3]
    for rid, t in svc.tickets.items():
        r = svc2.tickets[rid]
        assert (r.status, r.priority, r.deadline_round, r.node_budget,
                r.submitted_round, r.admitted_round, r.finished_round) == \
               (t.status, t.priority, t.deadline_round, t.node_budget,
                t.submitted_round, t.admitted_round, t.finished_round), rid
    results = svc2.drain()
    for rid, fam, g in ((0, "vc", HARD), (2, "ds", EASY[1]),
                        (3, "vc", EASY[2])):
        assert results[rid].optimum == oracle(fam, g), rid
    assert 1 not in results
    assert svc2.tickets[1].status is TicketStatus.CANCELLED


def test_save_restore_keeps_terminal_results(tmp_path):
    """DONE results and their payloads survive: a restored ticket's
    result() answers without re-running anything."""
    svc = serve(slots=1, steps=16)
    t = svc.submit(SolveRequest(rid=0, graph=EASY[0], family="vc"))
    res = t.result()
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)
    svc2 = SolverService.restore(path, num_lanes=8)
    assert svc2.tickets[0].status is TicketStatus.DONE
    restored = svc2.tickets[0].result()
    assert restored.optimum == res.optimum
    np.testing.assert_array_equal(restored.payload, res.payload)


def test_restore_can_override_policy(tmp_path):
    svc = serve(slots=1, scheduler="fifo")
    svc.submit(SolveRequest(rid=0, graph=EASY[0], family="vc"))
    svc.submit(SolveRequest(rid=1, graph=EASY[1], family="vc", priority=7))
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)
    svc2 = SolverService.restore(path, num_lanes=8)
    assert svc2.sched.policy.name == "fifo"
    svc3 = SolverService.restore(path, num_lanes=8, scheduler="priority")
    assert [r.rid for r in svc3.queue] == [1, 0]   # re-ranked by new policy
