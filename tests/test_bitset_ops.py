"""Unit tests for the universal bitset-kernel layer (DESIGN.md §5).

Every kernel in ``repro.kernels.bitset_ops`` is swept (interpret=True)
against an independent PURE-NUMPY oracle written here — not against
``ref.py`` — so the kernel, the jnp reference and these oracles form
three independently-derived statements of the §5.2 contract.  ``ref.py``
is additionally cross-checked against the same numpy oracles to keep the
``ops.py`` dispatch honest on both sides.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bitset_ops, ops, ref
from repro.problems.graphs import circulant_graph, full_mask, gnp_graph

# ---------------------------------------------------------------------------
# numpy oracles (independent of ref.py)
# ---------------------------------------------------------------------------


def np_bits(mask: np.ndarray, n: int) -> np.ndarray:
    vid = np.arange(n)
    return ((mask[vid // 32] >> (vid % 32).astype(np.uint32)) & 1) == 1


def np_count_stats(table, mask, valid):
    out = np.zeros((mask.shape[0], 4), np.int32)
    n = table.shape[0]
    for l in range(mask.shape[0]):
        cnts = np.where(
            np_bits(valid[l], n),
            np.bitwise_count(table & mask[l][None, :]).sum(1).astype(np.int64),
            -1)
        best = int(cnts.max())
        out[l] = (best, -1 if best < 0 else int(np.argmax(cnts)),
                  int(np.maximum(cnts, 0).sum()),
                  int(np.bitwise_count(mask[l]).sum()))
    return out


def np_row_reduce(table, select, op):
    n, w = table.shape
    ident = np.uint32(0) if op == "or" else np.uint32(0xFFFFFFFF)
    out = np.full((select.shape[0], w), ident, np.uint32)
    fn = np.bitwise_or if op == "or" else np.bitwise_and
    for l in range(select.shape[0]):
        for v in np.flatnonzero(np_bits(select[l], n)):
            out[l] = fn(out[l], table[v])
    return out


def random_masks(rng, lanes, n):
    w = (n + 31) // 32
    m = rng.integers(0, 2**32, (lanes, w), dtype=np.uint64).astype(np.uint32)
    return m & np.asarray(full_mask(n))[None, :]


# ---------------------------------------------------------------------------
# count_stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p,lanes,tile", [
    (40, 0.2, 4, 16), (200, 0.1, 8, 128), (130, 0.3, 6, 64),
    (33, 0.4, 3, 32),
])
def test_count_stats_matches_numpy(n, p, lanes, tile):
    g = gnp_graph(n, p, seed=n)
    rng = np.random.default_rng(n)
    mask, valid = random_masks(rng, lanes, n), random_masks(rng, lanes, n)
    got = bitset_ops.count_stats(jnp.asarray(g.adj), jnp.asarray(mask),
                                 jnp.asarray(valid), tile=tile)
    np.testing.assert_array_equal(np.asarray(got),
                                  np_count_stats(g.adj, mask, valid))
    # ref.py states the same contract.
    np.testing.assert_array_equal(
        np.asarray(ref.count_stats_ref(jnp.asarray(g.adj),
                                       jnp.asarray(mask),
                                       jnp.asarray(valid))),
        np_count_stats(g.adj, mask, valid))


def test_count_stats_all_invalid_and_tiebreak():
    g = circulant_graph(96, (1, 7))            # 4-regular: every vertex ties
    adj = jnp.asarray(g.adj)
    alive = jnp.asarray(full_mask(g.n))[None, :]
    got = np.asarray(bitset_ops.count_stats(adj, alive, alive, tile=32))[0]
    assert (got[0], got[1]) == (4, 0)          # smallest-id tie-break
    # Nothing valid -> (-1, -1, 0, popcount(mask)).
    zero = jnp.zeros_like(alive)
    got = np.asarray(bitset_ops.count_stats(adj, alive, zero, tile=32))[0]
    assert tuple(got) == (-1, -1, 0, 96)


def test_ops_dispatch_equivalence():
    """Both sides of the ops.py dispatch agree (kernel vs jnp oracle)."""
    g = gnp_graph(50, 0.25, seed=3)
    rng = np.random.default_rng(3)
    mask, valid = random_masks(rng, 5, g.n), random_masks(rng, 5, g.n)
    a = ops.count_stats(jnp.asarray(g.adj), jnp.asarray(mask),
                        jnp.asarray(valid), use_pallas=True, interpret=True)
    b = ops.count_stats(jnp.asarray(g.adj), jnp.asarray(mask),
                        jnp.asarray(valid), use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stacked_count_stats
# ---------------------------------------------------------------------------


def _stacked_tables(k, n, seeds):
    from repro.service.batch_problem import pack_instance
    w = (n + 31) // 32
    tables = np.zeros((k, n, w), np.uint32)
    for i, s in enumerate(seeds):
        g = gnp_graph(n - 3 * i, 0.3, seed=s)  # varied real sizes -> padding
        tables[i] = pack_instance(g, i % 2, n)[0]
    return tables


@pytest.mark.parametrize("lanes,tile", [(6, 16), (9, 64)])
def test_stacked_count_stats_matches_numpy(lanes, tile):
    k, n = 3, 40
    tables = _stacked_tables(k, n, seeds=(1, 2, 3))
    rng = np.random.default_rng(7)
    inst = rng.integers(-1, k, lanes).astype(np.int32)   # includes NO_INSTANCE
    mask, valid = random_masks(rng, lanes, n), random_masks(rng, lanes, n)
    got = bitset_ops.stacked_count_stats(
        jnp.asarray(tables), jnp.asarray(inst), jnp.asarray(mask),
        jnp.asarray(valid), tile=tile)
    # NO_INSTANCE (-1) lanes are PARKED: no table traffic, outputs the
    # empty-pass row (-1, -1, 0, 0) — never instance 0's stats.
    want = np.stack([
        np.array([-1, -1, 0, 0], np.int32) if int(i) < 0
        else np_count_stats(tables[int(i)], mask[l:l + 1],
                            valid[l:l + 1])[0]
        for l, i in enumerate(inst)])
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        np.asarray(ref.stacked_count_stats_ref(
            jnp.asarray(tables), jnp.asarray(inst), jnp.asarray(mask),
            jnp.asarray(valid))), want)


def test_stacked_count_stats_vmap_lifts_lane_axis():
    """vmap over (inst, mask, valid) — the engine's calling convention —
    must agree with the flat grid call, scalar prefetch included."""
    k, n = 2, 24
    tables = jnp.asarray(_stacked_tables(k, n, seeds=(4, 5)))
    rng = np.random.default_rng(11)
    inst = jnp.asarray(rng.integers(0, k, 8).astype(np.int32))
    mask = jnp.asarray(random_masks(rng, 8, n))
    valid = jnp.asarray(random_masks(rng, 8, n))
    flat = bitset_ops.stacked_count_stats(tables, inst, mask, valid, tile=8)
    mapped = jax.jit(jax.vmap(
        lambda i, m, v: bitset_ops.stacked_count_stats(
            tables, i[None], m[None, :], v[None, :], tile=8)[0]))(
        inst, mask, valid)
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(flat))


# ---------------------------------------------------------------------------
# popcount_reduce / masked_row_reduce
# ---------------------------------------------------------------------------


def test_popcount_reduce_matches_numpy():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 2**32, (7, 5), dtype=np.uint64).astype(np.uint32)
    got = bitset_ops.popcount_reduce(jnp.asarray(rows))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.bitwise_count(rows).sum(1))
    np.testing.assert_array_equal(
        np.asarray(ref.popcount_reduce_ref(jnp.asarray(rows))),
        np.bitwise_count(rows).sum(1))


@pytest.mark.parametrize("op", ["or", "and"])
@pytest.mark.parametrize("n,lanes,tile", [(40, 4, 16), (130, 3, 64)])
def test_masked_row_reduce_matches_numpy(op, n, lanes, tile):
    g = gnp_graph(n, 0.2, seed=n + 1)
    rng = np.random.default_rng(n)
    select = random_masks(rng, lanes, n)
    select[0] = 0                                 # empty selection -> identity
    got = bitset_ops.masked_row_reduce(jnp.asarray(g.adj),
                                       jnp.asarray(select), op=op, tile=tile)
    want = np_row_reduce(g.adj, select, op)
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        np.asarray(ref.masked_row_reduce_ref(jnp.asarray(g.adj),
                                             jnp.asarray(select), op=op)),
        want)


def test_masked_row_reduce_rejects_bad_args():
    g = gnp_graph(16, 0.3, seed=0)
    sel = jnp.zeros((1, g.words), jnp.uint32)
    with pytest.raises(ValueError):
        bitset_ops.masked_row_reduce(jnp.asarray(g.adj), sel, op="xor")
    with pytest.raises(ValueError):
        bitset_ops.masked_row_reduce(jnp.asarray(g.adj), sel, tile=24)


# ---------------------------------------------------------------------------
# domination_stats binding
# ---------------------------------------------------------------------------


def test_domination_stats_matches_numpy():
    from repro.problems.dominating_set import _closed_adj
    g = gnp_graph(30, 0.2, seed=9)
    cadj = _closed_adj(g)
    fm = np.asarray(full_mask(g.n))
    rng = np.random.default_rng(9)
    dominated = random_masks(rng, 5, g.n)
    cand = random_masks(rng, 5, g.n)
    got = bitset_ops.domination_stats(
        jnp.asarray(cadj), jnp.asarray(dominated), jnp.asarray(cand),
        jnp.asarray(fm), tile=16)
    want = np_count_stats(cadj, fm[None, :] & ~dominated, cand)[:, [0, 1, 3]]
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        np.asarray(ref.domination_stats_ref(
            jnp.asarray(cadj), jnp.asarray(dominated), jnp.asarray(cand),
            jnp.asarray(fm))), want)
