"""CLI-level tests for tools/trace_report.py: exit 0 on a clean trace,
exit 2 on every reconciliation/schema failure the CI trace-smoke step
gates on.  (test_obs.py covers analyze() programmatically; this file
pins main()'s exit codes and stderr.)"""
import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tools"))

import trace_report  # noqa: E402  (tools/ is not a package)
from repro.obs.trace import TRACE_SCHEMA_VERSION, TraceWriter  # noqa: E402


def _write_trace(path, *, lane_nodes=(6, 4), inst_nodes=(10,), nodes=10,
                 schema=TRACE_SCHEMA_VERSION, summary=True):
    w = TraceWriter(str(path))
    w.write("meta", schema=schema, mode="solve", lanes=len(lane_nodes),
            slots=1)
    w.write("round", round=0, open=3, active=2, nodes=nodes, steal_req=1,
            steal_recv=1, donated=1, inst_nodes=list(inst_nodes))
    if summary:
        w.write("summary", rounds=1, nodes=nodes,
                lane_nodes=list(lane_nodes), inst_nodes=list(inst_nodes))
    w.close()
    return str(path)


def test_clean_trace_exits_zero(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl")
    assert trace_report.main([trace]) == 0
    out = capsys.readouterr().out
    assert "trace report" in out
    assert "nodes=10" in out


def test_clean_trace_json_mode(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl")
    assert trace_report.main([trace, "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["nodes"] == 10
    assert report["lane_nodes"] == [6, 4]


def test_lane_total_mismatch_exits_two(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl", lane_nodes=(6, 5))
    assert trace_report.main([trace]) == 2
    err = capsys.readouterr().err
    assert "per-lane node totals sum to 11" in err


def test_instance_total_mismatch_exits_two(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl", inst_nodes=(9,))
    assert trace_report.main([trace]) == 2
    assert "per-instance node totals sum to 9" in capsys.readouterr().err


def test_missing_summary_exits_two(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl", summary=False)
    assert trace_report.main([trace]) == 2
    assert "no 'summary' record" in capsys.readouterr().err


def test_schema_version_mismatch_exits_two(tmp_path, capsys):
    trace = _write_trace(tmp_path / "t.jsonl",
                         schema=TRACE_SCHEMA_VERSION + 1)
    assert trace_report.main([trace]) == 2
    assert "schema" in capsys.readouterr().err


def test_malformed_record_exits_two(tmp_path, capsys):
    path = tmp_path / "t.jsonl"
    path.write_text('{"t":"warp","round":1}\n')
    assert trace_report.main([str(path)]) == 2
    assert "unknown trace record kind 'warp'" in capsys.readouterr().err


def test_meta_not_first_exits_two(tmp_path, capsys):
    path = tmp_path / "t.jsonl"
    w = TraceWriter(str(path))
    w.write("summary", rounds=0, nodes=0, lane_nodes=[0], inst_nodes=[0])
    w.close()
    assert trace_report.main([str(path)]) == 2
    assert "first record must be 'meta'" in capsys.readouterr().err


def test_empty_trace_exits_two(tmp_path, capsys):
    path = tmp_path / "t.jsonl"
    path.write_text("")
    assert trace_report.main([str(path)]) == 2
    assert "empty trace" in capsys.readouterr().err


def test_missing_file_exits_two(tmp_path, capsys):
    assert trace_report.main([str(tmp_path / "nope.jsonl")]) == 2
    assert capsys.readouterr().err.startswith("trace_report:")


@pytest.mark.parametrize("values,expected", [
    ([5, 5, 5, 5], 0.0),
    ([], 0.0),
    ([0, 0, 0], 0.0),
])
def test_gini_degenerate_cases(values, expected):
    assert trace_report.gini(values) == pytest.approx(expected)
