"""Protocol tests: SERIAL-RB oracle vs the faithful PARALLEL-RB simulator.

Paper validation targets (§VI): identical optima for any core count, no
search-node explored twice and none lost (full coverage), T_S <= T_R, and
the GETPARENT topology of Fig. 6.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.serial import (
    INF, ParallelRBSimulator, PyProblem, get_next_parent, get_parent,
    serial_rb,
)
from repro.problems import (
    gnp_graph, make_dominating_set_py, make_subset_sum_py,
    make_vertex_cover_py, random_regularish_graph,
)


def full_tree_problem(depth: int) -> PyProblem:
    """Complete binary tree of the given depth; every leaf a solution of
    value = leaf position (so the optimum is 0 and pruning never fires).
    Used for exact node-coverage accounting."""

    def root():
        return (0, 0)   # (depth, position)

    def apply(s, b):
        d, p = s
        return (d + 1, p * 2 + b)

    def leaf_value(s):
        d, p = s
        return d == depth, p + 1      # value>0 so best stays comparable

    def lower_bound(s):
        return 0                      # no pruning: exhaustive

    return PyProblem.from_callbacks(
        name=f"full{depth}", max_depth=depth, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound)


# -- GETPARENT topology (Fig. 5 / Fig. 6) -----------------------------------

def test_get_parent_figure6():
    # Fig. 6, c=7: parents are 1->0, 2->0, 3->1, 4->0, 5->1, 6->2.
    assert [get_parent(r, 7) for r in range(7)] == [0, 0, 0, 1, 0, 1, 2]


def test_get_parent_even_odd_alternation():
    # §IV-B: "When C_4 joins ... selects C_0" — powers of two go to 0.
    for x in range(1, 8):
        assert get_parent(2 ** x, 2 ** x + 1) == 0


def test_get_next_parent_counts_passes():
    parent, passes = 0, 0
    seen = []
    r, c = 2, 4
    for _ in range(8):
        parent, passes = get_next_parent(parent, r, c, passes)
        seen.append(parent)
    assert seen[:4] == [1, 3, 0, 1]   # skips r=2
    # 8 probes over the 3-parent cycle {1,3,0} pass rank r at probes 2, 5, 8.
    assert passes == 3


# -- exhaustive coverage: no node twice, none lost ---------------------------

@pytest.mark.parametrize("c", [1, 2, 3, 4, 7, 8])
@pytest.mark.parametrize("depth", [3, 5, 7])
def test_full_tree_coverage(c, depth):
    serial_best, serial_nodes, _ = serial_rb(full_tree_problem(depth))
    sim = ParallelRBSimulator(full_tree_problem(depth), c=c).run()
    assert sim.best == serial_best == 1          # leftmost leaf p=0 -> value 1
    # Exhaustive tree: parallel must visit exactly the serial node count —
    # fewer means lost subtrees, more means double exploration.
    assert sim.total_nodes == serial_nodes == 2 ** (depth + 1) - 1
    assert sum(sim.t_s) >= 1
    assert sum(sim.t_r) >= sum(sim.t_s) - 1      # T_S <= T_R (+root seed)


@pytest.mark.parametrize("c", [2, 5, 8])
def test_optimum_invariant_under_core_count_vc(c):
    g = gnp_graph(16, 0.35, seed=5)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    sim = ParallelRBSimulator(make_vertex_cover_py(g), c=c).run()
    assert sim.best == serial_best


@pytest.mark.parametrize("c", [2, 6])
def test_optimum_invariant_under_core_count_ds(c):
    g = gnp_graph(12, 0.3, seed=9)
    serial_best, _, _ = serial_rb(make_dominating_set_py(g))
    sim = ParallelRBSimulator(make_dominating_set_py(g), c=c).run()
    assert sim.best == serial_best


@given(st.integers(2, 10), st.integers(0, 1000))
@settings(deadline=None, max_examples=20)
def test_subset_sum_sim_matches_serial(c, seed):
    rng = np.random.RandomState(seed)
    vals = rng.randint(1, 20, size=10).tolist()
    target = int(sum(vals[:rng.randint(1, 6)]))
    prob = make_subset_sum_py(vals, target)
    serial_best, _, _ = serial_rb(prob)
    sim = ParallelRBSimulator(make_subset_sum_py(vals, target), c=c).run()
    assert sim.best == serial_best


# -- speedup sanity: parallel makespan shrinks -------------------------------

def test_makespan_decreases_with_cores():
    # 4-regular graphs defeat degree pruning (the paper's 60-cell story,
    # §VI): the ~1.5k-node tree is "sufficiently hard" for real speedup.
    g = random_regularish_graph(40, 4, seed=1)
    spans = {}
    for c in (1, 4, 16):
        sim = ParallelRBSimulator(make_vertex_cover_py(g), c=c).run()
        spans[c] = sim.makespan
    assert spans[4] < spans[1] / 2
    assert spans[16] < spans[4]


def test_delayed_bound_sharing_still_correct():
    g = gnp_graph(14, 0.4, seed=21)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    sim = ParallelRBSimulator(make_vertex_cover_py(g), c=4,
                              instant_bound_share=False).run()
    assert sim.best == serial_best
