"""Property tests for the scheduler policy layer (DESIGN.md §7).

Three invariant families over :mod:`repro.service.scheduler`, randomized
via hypothesis (or the deterministic ``_hypothesis_stub`` replay shim):

  * pop order — every policy drains exactly in its documented key order
    (priority desc / arrival / registered instance size, all ties FIFO);
  * remove() never corrupts pending() — under arbitrary interleavings of
    push/remove/pop (including the lazy-removal heap compaction path),
    the policy tracks a naive sorted-list reference model exactly;
  * overdue() is monotone in the round — with ticket state frozen, a
    request overdue at round r stays overdue at every r' > r, so
    eviction decisions can never flap.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro import registry
from repro.problems import gnp_graph
from repro.service import SolveRequest
from repro.service.scheduler import (Fifo, PriorityFifo, QueueItem,
                                     Scheduler, ShortestJobFirst,
                                     make_policy)
from repro.service.ticket import Ticket, TicketStatus

#: Shared tiny instances; SJF keys on the registered size, so a spread of
#: graph orders exercises non-trivial orderings.
_GRAPHS = {n: gnp_graph(n, 0.3, seed=n) for n in range(4, 13)}


def _req(rid, priority=0, n=6):
    return SolveRequest(rid=rid, graph=_GRAPHS[n], family="vc",
                        priority=priority)


def _drain(policy):
    out = []
    while True:
        item = policy.pop()
        if item is None:
            return out
        out.append(item)


# -- pop order --------------------------------------------------------------

@settings(deadline=None, max_examples=50)
@given(st.lists(st.integers(0, 5), min_size=0, max_size=30))
def test_priority_pop_order(prios):
    policy = PriorityFifo()
    for seq, priority in enumerate(prios):
        policy.push(QueueItem(seq, _req(seq, priority=priority)))
    got = [(item.request.priority, item.seq) for item in _drain(policy)]
    assert got == sorted(got, key=lambda t: (-t[0], t[1]))
    assert len(got) == len(prios) and policy.pop() is None


@settings(deadline=None, max_examples=50)
@given(st.lists(st.integers(0, 5), min_size=0, max_size=30))
def test_fifo_pop_is_arrival_order(prios):
    policy = Fifo()
    for seq, priority in enumerate(prios):
        policy.push(QueueItem(seq, _req(seq, priority=priority)))
    # priorities are carried but must be IGNORED: pure arrival order.
    assert [item.seq for item in _drain(policy)] == list(range(len(prios)))


@settings(deadline=None, max_examples=50)
@given(st.lists(st.integers(4, 12), min_size=0, max_size=30))
def test_sjf_pop_is_size_order(sizes):
    policy = ShortestJobFirst()
    for seq, n in enumerate(sizes):
        policy.push(QueueItem(seq, _req(seq, n=n)))
    got = [(registry.instance_size("vc", item.request.graph), item.seq)
           for item in _drain(policy)]
    assert got == sorted(got)


# -- remove()/pending() integrity -------------------------------------------

@settings(deadline=None, max_examples=60)
@given(st.sampled_from(["fifo", "priority", "sjf"]),
       st.lists(st.integers(0, 299), min_size=0, max_size=60))
def test_remove_never_corrupts_pending(name, codes):
    """Random push/remove/pop interleavings against a sorted-list model:
    pending() snapshots, pop results and len() must match at every step
    (the lazy-removal heap plus its compaction path are the code under
    test — the PR-1 style bug class here is a stale heap entry surviving
    a remove)."""
    policy = make_policy(name)
    model = {}          # rid -> (key, seq)
    next_rid = [0]

    def key_of(request, seq):
        return policy.key(request) + (seq,)

    def model_order():
        return tuple(sorted(model, key=model.get))

    for code in codes:
        op = code % 3
        if op == 0 or not model:            # push a fresh request
            rid = next_rid[0]
            next_rid[0] += 1
            request = _req(rid, priority=code % 5, n=4 + code % 9)
            policy.push(QueueItem(rid, request))
            model[rid] = key_of(request, rid)
        elif op == 1:                       # remove an arbitrary live rid
            rid = sorted(model)[code % len(model)]
            assert policy.remove(rid) is True
            del model[rid]
            assert policy.remove(rid) is False, "double remove must be False"
        else:                               # pop: must be the model's head
            item = policy.pop()
            head = model_order()[0]
            assert item is not None and item.request.rid == head
            del model[head]
        assert len(policy) == len(model)
        assert tuple(item.request.rid
                     for item in policy.pending()) == model_order()
    # drain agrees with the model to the end
    assert [item.request.rid for item in _drain(policy)] == list(model_order())


# -- overdue() monotonicity -------------------------------------------------

@settings(deadline=None, max_examples=60)
@given(st.lists(st.integers(0, 999), min_size=0, max_size=25))
def test_overdue_is_monotone_in_round(codes):
    """With ticket state frozen, overdue(r) ⊆ overdue(r') for r <= r' —
    both the queued and the running eviction sets only ever grow."""
    sched = Scheduler(PriorityFifo())
    for rid, code in enumerate(codes):
        deadline = (code % 40) if code % 3 else None
        budget = (1 + code % 7) if code % 5 else None
        ticket = Ticket(rid=rid, priority=0, deadline_round=deadline,
                        node_budget=budget, submitted_round=0,
                        _service=None)
        ticket.status = (TicketStatus.QUEUED if code % 2
                         else TicketStatus.RUNNING)
        ticket.nodes_used = code % 9
        sched.adopt(ticket)
    prev = set()
    for now_round in range(0, 45, 3):
        queued, running = sched.overdue(now_round)
        assert set(queued).isdisjoint(running)
        current = set(queued) | set(running)
        assert prev <= current, (
            f"overdue set shrank at round {now_round}: {prev - current}")
        prev = current
