"""ISSUE 7 acceptance tests: the search telemetry layer (repro.obs).

Covers (a) the metrics registry primitives (counters/gauges/histograms,
labels, the zero-cost disabled path), (b) the JSONL trace schema — writer
and reader both reject malformed records, (c) the centralized
ProgressEvent emission (unknown kinds raise at construction AND at
emit()), (d) the load-bearing invariant that telemetry is OBSERVATION
only: a traced+metered solve is bit-identical to a bare one, and (e) the
end-to-end pipeline: solve/service traces feed ``tools/trace_report.py``
whose per-instance node counts must sum to the engine total.
"""

import json
import pathlib
import sys

import numpy as np
import pytest

from repro import registry
from repro.obs import (MetricsRegistry, TraceError, TraceWriter, read_trace,
                       validate_record)
from repro.problems import gnp_graph
from repro.service import SolveRequest
from repro.solver import (EVENT_KINDS, ProgressEvent, Solver, SolverConfig,
                          emit)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))
import trace_report  # noqa: E402  (tools/ is not a package)

VC = registry.problem("vc", "gnp:14:30:5")


# -- metrics registry ---------------------------------------------------------


def test_counter_labels_and_values():
    r = MetricsRegistry()
    c = r.counter("reqs", "requests")
    c.inc()
    c.inc(2, scope="cross")
    c.inc(3, scope="cross")
    assert c.value() == 1
    assert c.value(scope="cross") == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_and_histogram():
    r = MetricsRegistry()
    g = r.gauge("depth", "queue depth")
    g.set(4)
    assert g.value() == 4
    h = r.histogram("ship", "depths", buckets=(1, 2, 4))
    for v in (1, 1, 3, 9):
        h.observe(v)
    got = h.value()
    assert got["count"] == 4 and got["sum"] == 14
    assert got["buckets"] == {"1": 2, "2": 0, "4": 1, "+Inf": 1}


def test_registry_idempotent_and_type_checked():
    r = MetricsRegistry()
    a = r.counter("x", "doc")
    assert r.counter("x", "doc") is a        # same instrument back
    with pytest.raises(ValueError, match="x"):
        r.gauge("x", "doc")                  # same name, different type


def test_disabled_registry_is_noop():
    r = MetricsRegistry(enabled=False)
    c = r.counter("x", "doc")
    c.inc(5)
    r.gauge("g", "doc").set(3)
    r.histogram("h", "doc").observe(1)
    snap = r.snapshot()
    assert snap.names() == ()
    assert snap.value("x") == 0              # missing counter reads as 0


def test_snapshot_is_a_frozen_copy():
    r = MetricsRegistry()
    c = r.counter("n", "doc")
    c.inc(2)
    snap = r.snapshot()
    c.inc(10)
    assert snap.value("n") == 2
    assert r.snapshot().value("n") == 12
    assert "n" in snap.to_dict()


# -- trace schema -------------------------------------------------------------


def test_trace_writer_validates_and_reader_roundtrips(tmp_path):
    path = str(tmp_path / "t.jsonl")
    w = TraceWriter(path)
    w.write("meta", schema=1, mode="solve", lanes=4, slots=1)
    w.write("round", round=1, open=3, active=2, nodes=8, steal_req=1,
            steal_recv=1, donated=1, inst_nodes=[8])
    w.write("summary", rounds=1, nodes=8, lane_nodes=[8, 0, 0, 0],
            inst_nodes=[8])
    w.close()
    records = read_trace(path)
    assert [r["t"] for r in records] == ["meta", "round", "summary"]


def test_trace_writer_rejects_unknown_kind_and_missing_fields(tmp_path):
    w = TraceWriter(str(tmp_path / "t.jsonl"))
    with pytest.raises(TraceError, match="unknown"):
        w.write("explosion", round=1)
    with pytest.raises(TraceError, match="missing"):
        w.write("round", round=1)            # lacks nodes/steal_*/...
    with pytest.raises(TraceError):
        validate_record({"round": 1})        # no "t" discriminator
    w.close()


def test_read_trace_reports_line_numbers(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"t":"meta","schema":1,"mode":"solve",'
                    '"lanes":4,"slots":1}\n'
                    '{"t":"nope"}\n')
    with pytest.raises(TraceError, match=":2:"):
        read_trace(str(path))


def test_trace_report_rejects_inconsistent_totals(tmp_path):
    records = [
        {"t": "meta", "schema": 1, "mode": "solve", "lanes": 2, "slots": 1},
        {"t": "summary", "rounds": 1, "nodes": 10, "lane_nodes": [4, 4],
         "inst_nodes": [10]},
    ]
    with pytest.raises(TraceError, match="per-lane"):
        trace_report.analyze(records)


# -- centralized event emission -----------------------------------------------


def test_progress_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown"):
        ProgressEvent(kind="explosion", round=1)
    assert "round" in EVENT_KINDS and "done" in EVENT_KINDS


def test_emit_validates_even_without_listener():
    emit(None, "round", round=1, open_work=0)          # silent but checked
    with pytest.raises(ValueError, match="unknown"):
        emit(None, "explosion", round=1)
    seen = []
    emit(seen.append, "done", round=3, open_work=0, best=7)
    assert len(seen) == 1 and seen[0].kind == "done" and seen[0].best == 7


def test_config_validates_trace_path():
    with pytest.raises(Exception):
        SolverConfig(trace_path="")


# -- telemetry is observation only --------------------------------------------


def test_solve_identical_with_telemetry_on_and_off(tmp_path):
    """The acceptance bar: tracing+metrics must not perturb the search.
    Same rounds, same stats (nodes, steals, incumbent), same payload."""
    base = dict(lanes=4, steps_per_round=16, bootstrap_rounds=2,
                bootstrap_steps=4)
    events_off, events_on = [], []
    off = Solver(SolverConfig(**base),
                 on_event=events_off.append).solve(VC)
    on = Solver(SolverConfig(**base, metrics=True,
                             trace_path=str(tmp_path / "t.jsonl")),
                on_event=events_on.append).solve(VC)
    assert off.stats == on.stats             # full SolveStats equality
    np.testing.assert_array_equal(off.payload, on.payload)
    rounds_off = [(e.round, e.open_work, e.best) for e in events_off
                  if e.kind == "round"]
    rounds_on = [(e.round, e.open_work, e.best) for e in events_on
                 if e.kind == "round"]
    assert rounds_off == rounds_on           # same incumbent trace per round


def test_round_events_carry_metrics_snapshot():
    events = []
    cfg = SolverConfig(lanes=4, steps_per_round=16, bootstrap_rounds=2,
                       bootstrap_steps=4, metrics=True)
    res = Solver(cfg, on_event=events.append).solve(VC)
    rounds = [e for e in events if e.kind == "round"]
    assert rounds and all(e.metrics is not None for e in rounds)
    final = [e for e in events if e.kind == "done"][0]
    assert final.metrics.value("engine_nodes") == res.stats.nodes
    # Without metrics=True the payload stays None (no snapshot cost).
    bare = []
    Solver(SolverConfig(lanes=4, steps_per_round=16, bootstrap_rounds=2,
                        bootstrap_steps=4), on_event=bare.append).solve(VC)
    assert all(e.metrics is None for e in bare)


# -- end-to-end: solve trace -> report ----------------------------------------


def test_solve_trace_report_cross_checks(tmp_path):
    trace = str(tmp_path / "solve.jsonl")
    solver = Solver(SolverConfig(lanes=4, steps_per_round=16,
                                 bootstrap_rounds=2, bootstrap_steps=4,
                                 metrics=True, trace_path=trace))
    res = solver.solve(VC)
    report = trace_report.analyze(read_trace(trace))
    assert report["mode"] == "solve" and report["lanes"] == 4
    assert report["nodes"] == res.stats.nodes
    assert sum(report["inst_nodes"]) == res.stats.nodes
    assert sum(report["lane_nodes"]) == res.stats.nodes
    # stats.t_s counts every task install, including host-side seeding;
    # the trace deliberately counts steals inside jitted rounds only
    # (the collector re-baselines after host-side lane surgery).
    assert report["steal_received"] <= res.stats.t_s
    assert report["steal_requests"] == res.stats.t_r
    assert 0.0 <= report["idle_pct"] <= 100.0
    assert 0.0 <= report["gini_lane_nodes"] <= 1.0
    assert report["best"] == [res.stats.best]
    snap = solver.metrics()
    assert snap.value("engine_nodes") == res.stats.nodes
    assert (snap.value("steal_received", scope="intra")
            + snap.value("steal_received", scope="cross")
            ) == report["steal_received"]
    # render() must produce the human table without raising
    assert "load balance" in trace_report.render(report)


@pytest.mark.slow
def test_service_trace_report_k8_drain(tmp_path):
    """K=8 drain through the service with telemetry: the per-instance node
    counts in the report must sum to the engine total, request lifecycle
    counts must match the drain, and optima stay exact."""
    mix = [("vc", gnp_graph(12 + (i % 4), 0.3, seed=i)) for i in range(8)]
    trace = str(tmp_path / "svc.jsonl")
    svc = Solver(SolverConfig(lanes=16, steps_per_round=16, metrics=True,
                              trace_path=trace)).serve(
        max_n=max(g.n for _, g in mix), slots=4)
    for i, (fam, g) in enumerate(mix):
        svc.submit(SolveRequest(rid=i, graph=g, family=fam))
    results = svc.drain()
    for i, (fam, g) in enumerate(mix):
        want = Solver().oracle(registry.problem(fam, g)).best
        assert results[i].optimum == want, (i, g.name)
    snap = svc.metrics()
    report = trace_report.analyze(read_trace(trace))
    assert report["mode"] == "service" and report["slots"] == 4
    assert sum(report["inst_nodes"]) == report["nodes"]
    assert report["nodes"] == snap.value("engine_nodes")
    assert report["lifecycle"]["admit"] == 8
    assert report["lifecycle"]["retire"] == 8
    assert report["lifecycle"]["expire"] == 0
    assert report["max_queue_depth"] >= 1    # 8 requests over 4 slots
    wait = snap.value("service_wait_rounds")
    assert wait["count"] == 8                # every admit histogram-ed
    assert "requests" in trace_report.render(report)


def test_service_node_accounting_matches_budget_path():
    """With a collector active the driver reuses the collector's
    per-instance delta for node budgets — eviction must still fire."""
    svc = Solver(SolverConfig(lanes=8, steps_per_round=8,
                              metrics=True)).serve(max_n=18, slots=1)
    t = svc.submit(SolveRequest(rid=0, graph=gnp_graph(18, 0.3, seed=7),
                                family="vc", node_budget=5))
    res = t.result()
    assert res.status == "expired"
    assert t.nodes_used >= 5
