"""Cross-device steal quota invariant (DESIGN.md §2, step 2).

Every extracted task is DELEGATED at its donor the moment it is shipped, so
an extracted-but-unclaimed task is a permanently lost subtree.  The quota
rule (Σ donate_i ≤ Σ idle_i, greedy prefix) plus rank-arithmetic claiming
must therefore form a bijection extraction → installation, for ANY
demand/supply skew and ANY scattering of idle lanes across lane ids.

Regression note: the claim step previously indexed task rows by lane id
while ``install_tasks`` consumes them by thief rank; with non-prefix idle
lanes that dropped tasks silently.  The scattered scenario below fails on
that version.

Runs in a subprocess with 8 host devices (same pattern as
test_distributed_solve: jax locks the device count at first init).

The claim step itself (``repro.core.steal.claim_tasks``) is additionally
property-tested IN PROCESS at the bottom of this file: random
(inst, grank) claim matrices — any thief scattering, any instance
assignment, junk values on non-thief lanes — must produce an
instance-scoped bijection from matched thieves onto valid task rows.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
import inspect

from repro.core import distributed as dist
from repro.core.api import BinaryProblem, DELEGATED, LEFT, RIGHT, UNVISITED
from repro.core.engine import Lanes, init_lanes

D, W, DEPTH = 8, 4, 12
assert len(jax.devices()) == 8, jax.devices()


def full_tree(depth):
    def root():
        return (jnp.int32(0), jnp.int32(0))

    def apply(s, b):
        return (s[0] + 1, s[1] * 2 + b.astype(jnp.int32))

    def leaf(s):
        return s[0] == depth, s[1] + 1

    return BinaryProblem.from_callbacks(
        name="full", max_depth=depth, root=root, apply=apply,
        leaf_value=leaf, lower_bound=lambda s: jnp.int32(0),
        solution_payload=lambda s: s[1], payload_zero=lambda: jnp.int32(0))


prob = full_tree(DEPTH)
mesh = jax.make_mesh((D,), ("workers",))


def steal_fn():
    def f(lanes):
        return dist.cross_device_steal(prob, lanes, ("workers",), 16)

    proto = init_lanes(prob, 1, seed_root=False)

    def spec_for(field, leaf):
        return P() if field in ("best", "steps", "best_payload") \
            else P(("workers",))

    specs = Lanes(**{f_: jax.tree_util.tree_map(
        lambda leaf: spec_for(f_, leaf), getattr(proto, f_))
        for f_ in Lanes._fields})
    kw = {"check_vma" if "check_vma" in inspect.signature(shard_map).parameters
          else "check_rep": False}
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(specs,),
                             out_specs=specs, **kw))


STEAL = steal_fn()
LEFTI, RIGHTI, DELI, UNVI = int(LEFT), int(RIGHT), int(DELEGATED), int(UNVISITED)


def build(donor_lanes, idle_lanes, donor_depth=6):
    '''All lanes active-without-supply except the given donor/idle sets.
    Donor lane k: (k % W) leading RIGHTs then LEFTs to depth=donor_depth —
    donors ship tasks at distinct depths, so the extracted/installed
    multiset comparison is discriminating.  Busy lanes: idx[0]=RIGHT
    (nothing stealable).'''
    lanes = init_lanes(prob, D * W, seed_root=False)
    il = lanes.idx.shape[1]
    idx = np.full((D * W, il), UNVI, np.int8)
    depth = np.zeros(D * W, np.int32)
    active = np.zeros(D * W, bool)
    for k in range(D * W):
        if k in idle_lanes:
            continue
        active[k] = True
        if k in donor_lanes:
            lead = k % W
            idx[k, :lead] = RIGHTI
            idx[k, lead:donor_depth] = LEFTI
            depth[k] = donor_depth
        else:
            idx[k, 0] = RIGHTI
            depth[k] = 1
    # Rebuild donor stacks so their state is consistent (not used by the
    # steal itself, but keeps the fixture honest).
    lanes = lanes._replace(idx=jnp.asarray(idx), depth=jnp.asarray(depth),
                           active=jnp.asarray(active))
    from repro.core.checkpoint import rebuild_stacks
    return dist._shard_lanes(rebuild_stacks(prob, lanes), mesh)


def check(name, donor_lanes, idle_lanes):
    lanes0 = build(donor_lanes, idle_lanes)
    lanes1 = jax.tree_util.tree_map(np.asarray, STEAL(lanes0))
    idx0, idx1 = np.asarray(lanes0.idx), lanes1.idx

    total_supply = len(donor_lanes)
    total_demand = len(idle_lanes)
    expect = min(total_supply, total_demand)

    # Extraction side: DELEGATED marks + donated counters.
    new_del = int(((idx1 == DELI) & (idx0 != DELI)).sum())
    donated = int((lanes1.donated - np.asarray(lanes0.donated)).sum())
    # Claim side: installs.
    t_s = int((lanes1.t_s - np.asarray(lanes0.t_s)).sum())
    newly_active = np.flatnonzero(lanes1.active & ~np.asarray(lanes0.active))

    assert new_del == expect, (name, new_del, expect)
    assert donated == expect, (name, donated, expect)
    assert t_s == expect, (name, t_s, expect)          # bijection: no loss
    assert len(newly_active) == expect, (name, newly_active, expect)

    # Every extracted task claimed by EXACTLY ONE thief: the multiset of
    # installed task indices equals the multiset of extracted ones.
    extracted = []
    for k in donor_lanes:
        slots = np.flatnonzero((idx1[k] == DELI) & (idx0[k] != DELI))
        for s in slots:
            bits = list(np.where(idx0[k][:s] < 0, LEFTI, idx0[k][:s]))
            extracted.append(tuple(bits + [RIGHTI]))
    installed = []
    for k in newly_active:
        d = int(lanes1.depth[k])
        assert int(lanes1.base[k]) == d, (name, k)
        installed.append(tuple(int(b) for b in idx1[k][:d]))
        # CONVERTINDEX ran: the replayed state depth matches.
        assert int(lanes1.stack[0][k, d]) == d, (name, k)
    assert sorted(extracted) == sorted(installed), (name,)
    return {"delegated": new_del, "installed": t_s}


out = {}
# Scattered idle lanes (NOT a lane-id prefix), demand > supply.
out["scattered"] = check(
    "scattered", donor_lanes={0, 1, 2, 3},
    idle_lanes={5, 6, 8, 10, 11})
# Supply > demand: only part of the open work ships.
out["surplus"] = check("surplus", donor_lanes={0, 1, 2, 3}, idle_lanes={5})
# Multi-donor-device greedy prefix quota, exact balance.
out["two_donors"] = check(
    "two_donors", donor_lanes={0, 1, 16, 17},
    idle_lanes={6, 9, 11, 26})
# No demand at all: nothing may be extracted.
out["no_demand"] = check("no_demand", donor_lanes={0, 1}, idle_lanes=set())
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def quota_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_scattered_idle_lanes_lose_nothing(quota_result):
    assert quota_result["scattered"] == {"delegated": 4, "installed": 4}


def test_surplus_supply_ships_only_demand(quota_result):
    assert quota_result["surplus"] == {"delegated": 1, "installed": 1}


def test_greedy_prefix_quota_across_devices(quota_result):
    assert quota_result["two_donors"] == {"delegated": 4, "installed": 4}


def test_no_demand_extracts_nothing(quota_result):
    assert quota_result["no_demand"] == {"delegated": 0, "installed": 0}


# -- claim_tasks property test (in process; pure array math) ----------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

_W, _ROWS, _K = 12, 16, 3                 # lanes, payload rows, instances


@settings(deadline=None, max_examples=80)
@given(st.lists(st.integers(0, 10 ** 6), min_size=8, max_size=8),
       st.integers(0, 10 ** 6))
def test_claim_tasks_is_instance_scoped_bijection(codes, salt):
    """For ANY thief/row scattering with (inst, grank) unique among
    thieves and among valid rows — the quota construction's guarantee —
    ``claim_tasks`` claims exactly the thieves whose pair has a valid
    row, each row goes to at most one thief, and no claim ever crosses
    an instance boundary.  Non-thief lanes carry junk (inst, grank)
    values on purpose: uniqueness is only promised among thieves."""
    import numpy as np

    from repro.core.steal import claim_tasks

    rng = __import__("random").Random((tuple(codes), salt).__hash__())
    # A shared pool of unique (inst, grank) pairs, split three ways:
    # thief-only, row-only, and matched (present on both sides).
    pool = [(i % _K, g) for g in range(8) for i in range(_K)]
    rng.shuffle(pool)
    n_thief = rng.randint(0, _W)
    thief_pairs = pool[:n_thief]
    n_matched = rng.randint(0, n_thief)
    extra_rows = rng.randint(0, _ROWS - n_matched)
    row_pairs = thief_pairs[:n_matched] + pool[n_thief:n_thief + extra_rows]
    rng.shuffle(row_pairs)

    thieves = np.zeros((_W,), bool)
    inst = np.array([rng.randint(0, _K - 1) for _ in range(_W)], np.int32)
    grank = np.array([rng.randint(0, 7) for _ in range(_W)], np.int32)
    lanes = list(range(_W))
    rng.shuffle(lanes)
    for lane, (i, g) in zip(lanes, thief_pairs):
        thieves[lane], inst[lane], grank[lane] = True, i, g

    w_valid = np.zeros((_ROWS,), bool)
    w_inst = np.array([rng.randint(0, _K - 1) for _ in range(_ROWS)],
                      np.int32)
    w_grank = np.array([rng.randint(0, 7) for _ in range(_ROWS)], np.int32)
    rows = list(range(_ROWS))
    rng.shuffle(rows)
    for row, (i, g) in zip(rows, row_pairs):
        w_valid[row], w_inst[row], w_grank[row] = True, i, g

    src, claim = (np.asarray(a) for a in claim_tasks(
        thieves, inst, grank, w_inst, w_grank, w_valid))

    row_of = {(int(w_inst[r]), int(w_grank[r])): r
              for r in range(_ROWS) if w_valid[r]}
    for lane in range(_W):
        should = thieves[lane] and (int(inst[lane]),
                                    int(grank[lane])) in row_of
        assert bool(claim[lane]) == should, f"lane {lane}"
        if should:
            r = int(src[lane])
            assert w_valid[r]
            # never cross-instance, never a rank mismatch
            assert int(w_inst[r]) == int(inst[lane])
            assert int(w_grank[r]) == int(grank[lane])
    claimed_rows = [int(src[lane]) for lane in range(_W) if claim[lane]]
    assert len(claimed_rows) == len(set(claimed_rows)), "row double-claimed"
    # surjective onto the matched rows: every valid row with a thief
    # counterpart is consumed (a dropped row is a lost subtree).
    matched = {row_of[p] for p in row_of
               if any(thieves[lane] and (int(inst[lane]),
                                         int(grank[lane])) == p
                      for lane in range(_W))}
    assert set(claimed_rows) == matched
