"""Property tests on the LM substrate's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.models.attention import (blocked_attention, decode_attention,
                                    quantize_kv)
from repro.models.config import MoEConfig
from repro.models.moe import (capacity, moe_ffn, moe_ffn_dense_reference,
                              route)
from repro.models.ssm import causal_conv, causal_conv_step, ssd_chunked


# ---------------------------------------------------------------------------
# Causality: output at position t must not depend on inputs after t.
# ---------------------------------------------------------------------------


pytestmark = pytest.mark.slow      # LM-substrate property tests: full CI on main only
def test_blocked_attention_is_causal():
    key = jax.random.PRNGKey(0)
    b, s, h, g, hd = 1, 64, 4, 2, 16
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, g, hd))
    v = jax.random.normal(ks[2], (b, s, g, hd))
    out = blocked_attention(q, k, v, block_q=16, block_k=16)
    # perturb the future: positions >= t
    t = 20
    k2 = k.at[:, t:].set(jax.random.normal(ks[3], (b, s - t, g, hd)))
    v2 = v.at[:, t:].set(0.0)
    out2 = blocked_attention(q, k2, v2, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out[:, :t]),
                               np.asarray(out2[:, :t]), rtol=1e-5,
                               atol=1e-5)


def test_ssd_is_causal():
    key = jax.random.PRNGKey(1)
    b, s, h, p, n = 1, 64, 2, 16, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
    d = jnp.ones((h,))
    y, _ = ssd_chunked(x, dt, a, bb, cc, d, chunk=16)
    t = 30
    x2 = x.at[:, t:].set(123.0)
    y2, _ = ssd_chunked(x2, dt, a, bb, cc, d, chunk=16)
    np.testing.assert_allclose(np.asarray(y[:, :t]), np.asarray(y2[:, :t]),
                               rtol=1e-5, atol=1e-5)


def test_ssd_no_nan_long_chunk():
    """Regression: masked i<j decay exponents overflowed to inf and
    poisoned chunks with inf*0=NaN at chunk >= 64 (fixed by masking
    inside the exponent)."""
    key = jax.random.PRNGKey(2)
    b, s, h, p, n = 2, 256, 4, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) + 2.0)  # big dt
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, s, 1, n))
    cc = jax.random.normal(ks[4], (b, s, 1, n))
    d = jnp.ones((h,))
    y, st = ssd_chunked(x, dt, a, bb, cc, d, chunk=128)
    assert not bool(jnp.isnan(y).any())
    assert not bool(jnp.isnan(st).any())


def test_causal_conv_step_matches_full():
    key = jax.random.PRNGKey(3)
    b, s, c, kw = 2, 12, 8, 4
    x = jax.random.normal(key, (b, s, c))
    w = jax.random.normal(jax.random.PRNGKey(4), (kw, c))
    full = causal_conv(x, w)
    cache = jnp.zeros((b, kw - 1, c))
    outs = []
    for t in range(s):
        yt, cache = causal_conv_step(cache, x[:, t], w)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SWA window semantics.
# ---------------------------------------------------------------------------

def test_sliding_window_blocks_old_positions():
    key = jax.random.PRNGKey(5)
    b, s, h, g, hd, w = 1, 64, 2, 2, 16, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, g, hd))
    v = jax.random.normal(ks[2], (b, s, g, hd))
    out = blocked_attention(q, k, v, window=w, block_q=16, block_k=16)
    # perturbing positions more than `w` before t must not change out[t]
    t = 40
    k2 = k.at[:, :t - w].set(jax.random.normal(ks[3], (b, t - w, g, hd)))
    out2 = blocked_attention(q, k2, v, window=w, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out[:, t:]),
                               np.asarray(out2[:, t:]), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: capacity dispatch == dense reference when capacity is lossless.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("top_k,shared", [(1, 0), (2, 0), (2, 32)])
def test_moe_matches_dense_reference(top_k, shared):
    cfg = MoEConfig(num_experts=4, top_k=top_k, d_ff=32,
                    capacity_factor=float(4 / top_k),  # C >= T*k/E: lossless
                    shared_expert_ff=shared)
    key = jax.random.PRNGKey(6)
    t, d = 24, 16
    x = jax.random.normal(key, (t, d), jnp.float32) * 0.5
    from repro.models.moe import moe_decls
    from repro.models.params import init_params
    params = init_params(moe_decls(d, cfg), jax.random.PRNGKey(7))
    got = moe_ffn(x, params, cfg)
    want = moe_ffn_dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@given(st.integers(1, 2), st.integers(8, 64))
@settings(deadline=None, max_examples=20)
def test_moe_capacity_bounds(top_k, tokens):
    cfg = MoEConfig(num_experts=4, top_k=top_k, d_ff=8,
                    capacity_factor=1.25)
    c = capacity(tokens, cfg)
    assert c >= 8 and c % 8 == 0
    assert c * cfg.num_experts >= tokens * top_k           # cf >= 1


def test_router_weights_normalized():
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff=8)
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 12))
    router = jax.random.normal(jax.random.PRNGKey(9), (12, 8))
    e, w = route(x, router, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(e.max()) < 8 and int(e.min()) >= 0


# ---------------------------------------------------------------------------
# int8 KV quantization error bound.
# ---------------------------------------------------------------------------

@given(st.integers(0, 1000))
@settings(deadline=None, max_examples=25)
def test_quantize_kv_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 2, 16),
                          jnp.float32)
    q8, scale = quantize_kv(x)
    deq = q8.astype(jnp.float32) * scale
    err = jnp.abs(deq - x)
    # per (token, head) error <= scale/2 (+ rounding epsilon)
    assert bool(jnp.all(err <= scale * 0.5 + 1e-6))


def test_decode_attention_quant_close_to_exact():
    from repro.models.attention import decode_attention_quant
    key = jax.random.PRNGKey(11)
    b, s, h, g, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, g, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, g, hd), jnp.float32)
    pos = jnp.int32(s - 1)
    exact = decode_attention(q, k, v, pos)
    k8, ksc = quantize_kv(k)
    v8, vsc = quantize_kv(v)
    approx = decode_attention_quant(q, k8, v8, ksc, vsc, pos, block=16)
    np.testing.assert_allclose(np.asarray(approx, np.float32),
                               np.asarray(exact, np.float32),
                               rtol=0.05, atol=0.05)
