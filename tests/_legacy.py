"""Deprecated-surface wrappers for tests (shared, not a test module).

The legacy entry points (``repro.core.distributed.solve(...)``, direct
``SolverService(...)`` construction, ``SolverService.run()`` and int-rid
tickets) are DeprecationWarning shims over the facade.  Tests that still
exercise them on purpose go through these wrappers, which

  * assert the shim warns EXACTLY once per call (a shim that stops
    warning — or double-warns through a refactor — is a regression), and
  * swallow the warning so it never leaks into unrelated tests —
    ``pytest.ini`` turns these four specific messages into errors
    everywhere else, so an unwrapped legacy call now fails the suite.
"""

from __future__ import annotations

import warnings


def one_deprecation(fn, match: str):
    """Run ``fn()`` asserting exactly one DeprecationWarning containing
    ``match``; returns ``fn()``'s result with the warning swallowed."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = fn()
    hits = [w for w in caught
            if issubclass(w.category, DeprecationWarning)
            and match in str(w.message)]
    assert len(hits) == 1, (
        f"expected exactly one DeprecationWarning containing {match!r}, "
        f"got {len(hits)} (all warnings: "
        f"{[str(w.message) for w in caught]})")
    return out


def legacy_solve(*args, **kwargs):
    """``repro.core.distributed.solve`` through the exactly-once check."""
    from repro.core.distributed import solve
    return one_deprecation(lambda: solve(*args, **kwargs),
                           "repro.core.distributed.solve")


def legacy_service(**kwargs):
    """Direct ``SolverService(...)`` through the exactly-once check."""
    from repro.service import SolverService
    return one_deprecation(lambda: SolverService(**kwargs),
                           "direct SolverService")
