"""ISSUE 4 acceptance tests: the unified Solver session API.

Covers (a) SolverConfig validation, (b) the deprecation shims (old
``core.distributed.solve`` kwargs and direct ``SolverService(...)``)
staying bitwise-identical to the facade, (c) the typed progress-event
stream shared by both drivers, and (d) registry resolution through
``Solver.solve`` / ``Solver.oracle``.
"""

import numpy as np
import pytest

from repro import registry
from repro.core.distributed import solve as legacy_solve
from repro.problems import gnp_graph, make_vertex_cover
from repro.service import AdmissionError, SolveRequest, SolverService
from repro.solver import (ConfigError, ProgressEvent, Solver, SolverConfig,
                          SolveResult)

VC = registry.problem("vc", "gnp:14:30:5")
CFG = SolverConfig(lanes=8, steps_per_round=16, bootstrap_rounds=2,
                   bootstrap_steps=4)


# -- SolverConfig validation --------------------------------------------------


def test_config_rejects_bad_fields():
    with pytest.raises(ConfigError):
        SolverConfig(lanes=0)
    with pytest.raises(ConfigError):
        SolverConfig(steps_per_round=0)
    with pytest.raises(ConfigError):
        SolverConfig(max_ship=0)
    with pytest.raises(ConfigError):
        SolverConfig(bootstrap_rounds=2, bootstrap_steps=0)


def test_config_checkpoint_every_requires_path():
    with pytest.raises(ConfigError, match="checkpoint_path"):
        SolverConfig(checkpoint_every=5)
    SolverConfig(checkpoint_every=5, checkpoint_path="x.ckpt")  # fine


def test_backend_validated_against_registry_capabilities():
    """ss advertises jnp only: a pallas session must refuse to build it,
    with the capability list in the error."""
    solver = Solver(SolverConfig(lanes=4, backend="pallas"))
    with pytest.raises(ConfigError, match="advertises: jnp"):
        solver.solve(registry.problem("ss", "ss:8:1"))
    with pytest.raises(ConfigError):
        Solver(SolverConfig(backend="cuda")).solve(VC)


def test_resume_from_missing_checkpoint_is_config_error():
    cfg = SolverConfig(lanes=4, resume_from="/does/not/exist.ckpt")
    with pytest.raises(ConfigError, match="not found"):
        Solver(cfg).solve(VC)


def test_resume_from_mismatched_slot_count_is_config_error(tmp_path):
    """A service checkpoint (K=4 incumbent slots) cannot resume a
    single-instance solve: surfaced as ConfigError, not a deep shape
    failure."""
    svc = Solver(SolverConfig(lanes=8, steps_per_round=4)).serve(
        max_n=14, slots=4)
    svc.submit(SolveRequest(rid=0, graph=gnp_graph(12, 0.3, seed=9),
                            family="vc"))
    svc.step_round()
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)
    cfg = SolverConfig(lanes=8, steps_per_round=16, resume_from=path)
    with pytest.raises(ConfigError, match="incompatible"):
        Solver(cfg).solve(VC)


def test_resume_elastic_lane_count_through_facade(tmp_path):
    """Elastic restart is config, not surgery: checkpoint at 4 lanes,
    resume at 16 (and vice versa is covered by engine tests) — optimum
    still matches the oracle."""
    path = str(tmp_path / "run.ckpt")
    cfg = SolverConfig(lanes=4, steps_per_round=8, max_rounds=3,
                       checkpoint_every=1, checkpoint_path=path)
    Solver(cfg).solve(VC)
    res = Solver(SolverConfig(lanes=16, steps_per_round=32,
                              resume_from=path)).solve(VC)
    assert res.stats.best == Solver().oracle(VC).best


# -- deprecation shims: warn, and stay bitwise-identical ----------------------


def _exactly_one(record, match: str) -> None:
    """The shim must warn EXACTLY once per call — not zero (silent
    un-deprecation), not twice (a refactor double-warning)."""
    hits = [w for w in record if issubclass(w.category, DeprecationWarning)
            and match in str(w.message)]
    assert len(hits) == 1, [str(w.message) for w in record]


def test_legacy_solve_warns_and_matches_facade():
    prob = VC.build()
    with pytest.warns(DeprecationWarning, match="repro.solver.Solver") as rec:
        payload, stats, _ = legacy_solve(prob, num_lanes=8,
                                         steps_per_round=16,
                                         bootstrap_rounds=2,
                                         bootstrap_steps=4)
    _exactly_one(rec, "repro.core.distributed.solve")
    res = Solver(CFG).solve(VC)
    assert isinstance(res, SolveResult)
    assert stats == res.stats                     # full SolveStats equality
    np.testing.assert_array_equal(payload, res.payload)


def test_legacy_service_warns_and_matches_facade():
    """The batch-era surface (``run()`` + int-rid tickets) stays a
    DeprecationWarning shim over the ticketed path, bitwise-identical on
    the default policy at equal priorities."""
    mix = [("vc", gnp_graph(12, 0.3, seed=9)),
           ("ds", gnp_graph(14, 0.25, seed=2))]
    reqs = [SolveRequest(rid=i, graph=g, family=f)
            for i, (f, g) in enumerate(mix)]
    with pytest.warns(DeprecationWarning, match="serve") as rec:
        legacy = SolverService(max_n=14, slots=2, num_lanes=8,
                               steps_per_round=16)
    _exactly_one(rec, "direct SolverService")
    with pytest.warns(DeprecationWarning, match="Ticket") as rec:
        old = legacy.run(list(reqs))
    _exactly_one(rec, "SolverService.run")
    svc = Solver(SolverConfig(lanes=8, steps_per_round=16)).serve(
        max_n=14, slots=2)
    tickets = [svc.submit(r) for r in reqs]
    with pytest.warns(DeprecationWarning, match="int rid") as rec:
        assert int(tickets[0]) == reqs[0].rid
    _exactly_one(rec, "treating a Ticket")
    assert [t.rid for t in tickets] == [r.rid for r in reqs]
    new = svc.drain()
    for i in range(len(mix)):
        assert old[i].optimum == new[i].optimum
        np.testing.assert_array_equal(old[i].payload, new[i].payload)
        assert (old[i].admitted_round, old[i].retired_round) == \
               (new[i].admitted_round, new[i].retired_round)
        with pytest.warns(DeprecationWarning, match="int rid") as rec:
            assert new[tickets[i]].optimum == new[i].optimum  # int-rid lookup
        _exactly_one(rec, "treating a Ticket")


def test_legacy_on_round_still_fires_through_event_stream():
    seen = []
    with pytest.warns(DeprecationWarning) as rec:
        legacy_solve(VC.build(), num_lanes=4, steps_per_round=16,
                     on_round=lambda r, lanes, open_work: seen.append(
                         (r, open_work, lanes is not None)))
    _exactly_one(rec, "repro.core.distributed.solve")
    assert seen and all(ok for _, _, ok in seen)
    assert [r for r, _, _ in seen] == sorted(r for r, _, _ in seen)


# -- the typed event stream ---------------------------------------------------


def test_solve_event_stream():
    events = []
    res = Solver(CFG, on_event=events.append).solve(VC)
    assert all(isinstance(e, ProgressEvent) for e in events)
    rounds = [e for e in events if e.kind == "round"]
    assert rounds and rounds[-1].open_work == 0
    assert all(e.lanes is not None for e in rounds)
    done = [e for e in events if e.kind == "done"]
    assert len(done) == 1 and done[0].best == res.stats.best


def test_checkpoint_events_carry_path(tmp_path):
    path = str(tmp_path / "ev.ckpt")
    events = []
    cfg = SolverConfig(lanes=8, steps_per_round=8, checkpoint_every=1,
                       checkpoint_path=path)
    Solver(cfg, on_event=events.append).solve(VC)
    cps = [e for e in events if e.kind == "checkpoint"]
    assert cps and all(e.path == path for e in cps)


def test_service_event_stream_admit_retire():
    events = []
    svc = Solver(SolverConfig(lanes=8, steps_per_round=16),
                 on_event=events.append).serve(max_n=14, slots=2)
    svc.submit(SolveRequest(rid=7, graph=gnp_graph(12, 0.3, seed=9),
                            family="vc"))
    svc.drain()
    kinds = [e.kind for e in events]
    assert "admit" in kinds and "retire" in kinds and "round" in kinds
    retire = [e for e in events if e.kind == "retire"][0]
    assert retire.rid == 7 and retire.best == svc.results[7].optimum
    admit = [e for e in events if e.kind == "admit"][0]
    assert admit.rid == 7 and admit.round <= retire.round


# -- registry resolution ------------------------------------------------------


def test_solver_accepts_raw_binary_problem():
    g = gnp_graph(12, 0.3, seed=9)
    res = Solver(CFG).solve(make_vertex_cover(g))
    assert res.stats.best == Solver().oracle(registry.problem("vc", g)).best


def test_solver_rejects_unknown_problem_type():
    with pytest.raises(TypeError):
        Solver(CFG).solve("vc")


def test_registry_unknown_family():
    with pytest.raises(registry.UnknownProblemError, match="registered"):
        registry.get("tsp")


def test_registry_handle_parses_spec_strings():
    h = registry.problem("vc", "reg:10:2:1")
    assert h.label.startswith("vc:reg_10_2_1")
    assert h.spec.servable
    assert not registry.get("ss").servable


def test_serve_rejects_non_stacked_backend():
    with pytest.raises(ConfigError, match="stacked service"):
        Solver(SolverConfig(backend="tpu-v9")).serve(max_n=8, slots=2)


def test_serve_rejects_config_fields_it_cannot_honor():
    """The service has its own save/restore surface: a config carrying
    solve-only policy must be rejected, not silently ignored."""
    cfg = SolverConfig(lanes=8, checkpoint_every=1,
                       checkpoint_path="svc.ckpt")
    with pytest.raises(ConfigError, match="checkpoint_every"):
        Solver(cfg).serve(max_n=8, slots=2)
    with pytest.raises(ConfigError, match="resume_from"):
        Solver(SolverConfig(resume_from="svc.ckpt")).serve(max_n=8, slots=2)
