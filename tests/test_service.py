"""Solver-service acceptance tests (ISSUE 2).

Determinism/isolation proof: a batched service run over K >= 4 mixed
instances (vc + ds, varied sizes) must return BITWISE-identical optima and
valid payloads vs. K independent SERIAL-RB oracles, for W in {8, 32}
lanes, including under a forced mid-run elastic restore onto a different
lane count.  Plus: the steal path must never pair lanes across instances
(tenant isolation), intra-device and cross-device.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import steal
from repro.core.engine import NO_INSTANCE, init_lanes
from repro.core.serial import serial_rb
from repro.problems import (gnp_graph, make_dominating_set_py,
                            make_vertex_cover_py, random_regularish_graph)
from _legacy import legacy_service
from repro.service import SolveRequest, SolverService
from repro.service.batch_problem import StackedSpec, pack_instance

# K = 4 mixed instances: both families, varied sizes.
MIX = [
    ("vc", gnp_graph(18, 0.3, seed=7)),
    ("vc", random_regularish_graph(16, 4, seed=3)),
    ("ds", gnp_graph(12, 0.3, seed=9)),
    ("ds", gnp_graph(14, 0.25, seed=2)),
]


def oracle(family, graph):
    py = (make_vertex_cover_py(graph) if family == "vc"
          else make_dominating_set_py(graph))
    return serial_rb(py)[0]


ORACLES = [oracle(f, g) for f, g in MIX]


def bits_of(mask: np.ndarray):
    out = set()
    for word_i, word in enumerate(np.asarray(mask, np.uint32)):
        for b in range(32):
            if (int(word) >> b) & 1:
                out.add(word_i * 32 + b)
    return out


def assert_valid_payload(family, graph, payload, optimum):
    """The payload must be an actual optimal solution, not just a size."""
    chosen = bits_of(payload)
    assert len(chosen) == optimum, (family, graph.name, chosen)
    assert all(v < graph.n for v in chosen)
    if family == "vc":
        for u in range(graph.n):
            for v in bits_of(graph.adj[u]):
                assert u in chosen or v in chosen, (graph.name, u, v)
    else:
        dominated = set()
        for v in chosen:
            dominated |= {v} | bits_of(graph.adj[v])
        assert dominated >= set(range(graph.n)), (graph.name, dominated)


def run_requests(svc):
    reqs = [SolveRequest(rid=i, graph=g, family=f)
            for i, (f, g) in enumerate(MIX)]
    for r in reqs:
        svc.submit(r)
    return reqs, svc.drain()


@pytest.mark.parametrize("lanes", [8, 32])
def test_service_matches_serial_oracles(lanes):
    svc = legacy_service(max_n=18, slots=4, num_lanes=lanes,
                        steps_per_round=16)
    _, results = run_requests(svc)
    for i, (family, graph) in enumerate(MIX):
        assert results[i].optimum == ORACLES[i], (i, family, graph.name)
        assert_valid_payload(family, graph, results[i].payload,
                             results[i].optimum)


# -- kernel backend: pallas stacked evaluate == jnp stacked evaluate ----------
# (the stacked-service leg of the DESIGN.md §5.4 backend-equivalence sweep)


def _mixed_tables(n=14):
    spec = StackedSpec(n=n, k=3)
    tables_np = spec.empty_tables()
    mix = [("vc", gnp_graph(14, 0.3, seed=7)), ("ds", gnp_graph(12, 0.3, seed=9)),
           ("vc", gnp_graph(10, 0.4, seed=1))]
    for slot, (fam, g) in enumerate(mix):
        adj, fm, f = pack_instance(g, 0 if fam == "vc" else 1, n)
        tables_np.adj[slot], tables_np.fullm[slot] = adj, fm
        tables_np.family[slot] = f
    return spec, type(tables_np)(*(jnp.asarray(t) for t in tables_np))


def test_stacked_backend_nodeeval_bitwise_identical():
    """Walk both family trees from every slot root: each NodeEval field must
    agree between the jnp and the batched-Pallas stacked evaluate."""
    from repro.core.api import INF_VALUE
    spec, tables = _mixed_tables()
    bj = spec.bind(tables)
    bp = spec.bind(tables, backend="pallas", tile=16)
    frontier = [bj.instance_root(jnp.int32(s)) for s in range(spec.k)]
    seen = 0
    while frontier and seen < 60:
        state = frontier.pop()
        ej = bj.evaluate(state, INF_VALUE)
        ep = bp.evaluate(state, INF_VALUE)
        for a, b in zip(jax.tree_util.tree_leaves(ej),
                        jax.tree_util.tree_leaves(ep)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        seen += 1
        if not bool(ej.is_solution):
            frontier += [ej.left, ej.right]
    assert seen == 60


def test_stacked_bind_rejects_unknown_backend():
    spec, tables = _mixed_tables()
    with pytest.raises(ValueError):
        spec.bind(tables, backend="cuda")


def test_service_pallas_backend_matches_serial_oracles():
    """Full continuous-batching drain through the batched stacked kernel:
    every tenant still lands exactly on its serial optimum."""
    svc = legacy_service(max_n=18, slots=4, num_lanes=8, steps_per_round=16,
                        backend="pallas")
    _, results = run_requests(svc)
    for i, (family, graph) in enumerate(MIX):
        assert results[i].optimum == ORACLES[i], (i, family, graph.name)
        assert_valid_payload(family, graph, results[i].payload,
                             results[i].optimum)


def test_service_backend_crosses_checkpoints(tmp_path):
    """Save under jnp, restore under pallas (backend is an execution choice,
    not checkpoint state — driver docstring): identical results."""
    svc = legacy_service(max_n=18, slots=4, num_lanes=8, steps_per_round=4)
    for i, (f, g) in enumerate(MIX):
        svc.submit(SolveRequest(rid=i, graph=g, family=f))
    svc.step_round()
    svc.step_round()
    assert any(r >= 0 for r in svc.slot_rid)
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)

    svc2 = SolverService.restore(path, num_lanes=8, steps_per_round=16,
                                 backend="pallas")
    results = svc2.drain()
    for i, (family, graph) in enumerate(MIX):
        assert results[i].optimum == ORACLES[i], (i, family, graph.name)


@pytest.mark.parametrize("w_before,w_after", [(8, 32), (32, 7)])
def test_service_elastic_restore_midrun(w_before, w_after, tmp_path):
    """Forced mid-run elastic restore: save with K instances in flight on
    W lanes, restore onto W' != W, drain — every instance still reaches
    its serial optimum and the pending pool empties."""
    svc = legacy_service(max_n=18, slots=4, num_lanes=w_before,
                        steps_per_round=4)
    for i, (f, g) in enumerate(MIX):
        svc.submit(SolveRequest(rid=i, graph=g, family=f))
    svc.step_round()
    svc.step_round()
    assert any(r >= 0 for r in svc.slot_rid)     # genuinely mid-flight
    path = str(tmp_path / "svc.ckpt")
    svc.save(path)

    svc2 = SolverService.restore(path, num_lanes=w_after,
                                 steps_per_round=16)
    results = svc2.drain()
    for i, (family, graph) in enumerate(MIX):
        assert results[i].optimum == ORACLES[i], (i, family, graph.name)
        assert_valid_payload(family, graph, results[i].payload,
                             results[i].optimum)
    assert not svc2.pool                          # pending pool drained


def test_service_continuous_batching_reuses_slots():
    """More requests than slots: retirement must free slots for the queue
    and every backlogged request must still be exact."""
    reqs = [SolveRequest(rid=100 + i, graph=g, family=f)
            for i, (f, g) in enumerate(MIX * 2)]
    svc = legacy_service(max_n=18, slots=2, num_lanes=8, steps_per_round=16)
    for r in reqs:
        svc.submit(r)
    results = svc.drain()
    for i, (family, graph) in enumerate(MIX * 2):
        assert results[100 + i].optimum == ORACLES[i % len(MIX)]


# -- admission: typed errors at submit() time (ISSUE 4 satellite) -------------


def test_submit_rejects_unregistered_family():
    from repro.service import AdmissionError
    svc = legacy_service(max_n=18, slots=2, num_lanes=4)
    with pytest.raises(AdmissionError, match="unknown problem family"):
        svc.submit(SolveRequest(rid=0, graph=MIX[0][1], family="tsp"))
    assert not svc.queue                      # nothing silently enqueued


def test_submit_rejects_unservable_family():
    """subset sum is registered (CLI + oracle) but has no service packing:
    the failure is a typed AdmissionError at submit(), not a crash deep
    inside table packing."""
    from repro.service import AdmissionError
    svc = legacy_service(max_n=18, slots=2, num_lanes=4)
    with pytest.raises(AdmissionError, match="not servable"):
        svc.submit(SolveRequest(rid=0, graph=MIX[0][1], family="ss"))


def test_submit_rejects_oversized_instance():
    from repro.service import AdmissionError
    svc = legacy_service(max_n=14, slots=2, num_lanes=4)
    with pytest.raises(AdmissionError, match="max_n"):
        svc.submit(SolveRequest(rid=0, graph=gnp_graph(20, 0.3, seed=1),
                                family="vc"))


# -- tenant isolation: stealing never crosses instances -----------------------


def _stacked_lanes(num_lanes):
    """A 2-instance stacked problem + idle lane pool for steal surgery."""
    spec = StackedSpec(n=12, k=2)
    tables_np = spec.empty_tables()
    for slot, (f, g) in enumerate([("vc", gnp_graph(12, 0.4, seed=1)),
                                   ("vc", gnp_graph(10, 0.4, seed=2))]):
        adj, fm, fam = pack_instance(g, 0, 12)
        tables_np.adj[slot], tables_np.fullm[slot] = adj, fm
        tables_np.family[slot] = fam
    tables = type(tables_np)(*(jnp.asarray(t) for t in tables_np))
    prob = spec.bind(tables)
    lanes = init_lanes(prob, num_lanes, seed_root=False)
    return prob, lanes


def _with_donor(lanes, lane, inst, depth=4):
    """Make ``lane`` an active donor of ``inst`` with open LEFT slots."""
    idx = np.asarray(lanes.idx).copy()
    idx[lane, :depth] = 0                          # LEFT: open right siblings
    return lanes._replace(
        idx=jnp.asarray(idx),
        depth=lanes.depth.at[lane].set(depth),
        active=lanes.active.at[lane].set(True),
        inst=lanes.inst.at[lane].set(inst))


def test_intra_device_steal_is_instance_scoped():
    prob, lanes = _stacked_lanes(8)
    # Donors only in instance 0; idle lanes unbound except two thieves
    # bound to instance 1.
    lanes = lanes._replace(
        inst=jnp.full_like(lanes.inst, NO_INSTANCE).at[3].set(1).at[5].set(1))
    lanes = _with_donor(lanes, 0, inst=0)
    out = steal.balance_device(prob, lanes)
    # Nothing may move: the global matching would have paired lane 0 -> 3.
    assert int(out.donated.sum()) == 0
    assert not bool(out.active[3]) and not bool(out.active[5])
    np.testing.assert_array_equal(np.asarray(out.inst),
                                  np.asarray(lanes.inst))

    # Now give instance 1 its own donor: only same-instance pairs may form.
    lanes2 = _with_donor(lanes, 1, inst=1, depth=3)
    out2 = steal.balance_device(prob, lanes2)
    assert bool(out2.active[3])                   # thief of inst 1 fed
    assert int(out2.inst[3]) == 1
    donated = np.asarray(out2.donated) - np.asarray(lanes2.donated)
    assert donated[1] == 1 and donated[0] == 0    # inst-0 donor untouched


def test_unbound_lanes_never_steal():
    prob, lanes = _stacked_lanes(4)
    lanes = _with_donor(lanes, 0, inst=0)
    # Remaining idle lanes are unbound (NO_INSTANCE): must stay idle.
    assert int(lanes.inst[1]) == 0
    lanes = lanes._replace(
        inst=lanes.inst.at[1].set(NO_INSTANCE).at[2].set(NO_INSTANCE)
        .at[3].set(NO_INSTANCE))
    out = steal.balance_device(prob, lanes)
    assert int(out.donated.sum()) == 0
    assert int(out.active.sum()) == 1


_CROSS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import distributed as dist
from repro.core.engine import Lanes, init_lanes
from repro.problems import gnp_graph
from repro.service.batch_problem import StackedSpec, pack_instance

D, W = 8, 2
spec = StackedSpec(n=12, k=2)
tables_np = spec.empty_tables()
for slot, g in enumerate([gnp_graph(12, 0.4, seed=1),
                          gnp_graph(10, 0.4, seed=2)]):
    adj, fm, fam = pack_instance(g, 0, 12)
    tables_np.adj[slot], tables_np.fullm[slot] = adj, fm
    tables_np.family[slot] = fam
tables = type(tables_np)(*(jnp.asarray(t) for t in tables_np))
prob = spec.bind(tables)
mesh = jax.make_mesh((D,), ("workers",))


def steal_fn(max_ship):
    def f(lanes):
        return dist.cross_device_steal(prob, lanes, ("workers",), max_ship)

    proto = init_lanes(prob, 1, seed_root=False)
    specs = Lanes(**{f_: jax.tree_util.tree_map(
        lambda leaf: P() if f_ in ("best", "steps", "best_payload")
        else P(("workers",)), getattr(proto, f_))
        for f_ in Lanes._fields})
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check=False))


STEAL = steal_fn(16)

# Device 0 holds donors of instance 0; devices 2-3 hold thieves of
# instance 1 and device 5 a thief of instance 0.  Only the inst-0 thief
# may be fed, by an inst-0 donor.
lanes = init_lanes(prob, D * W, seed_root=False)
idx = np.asarray(lanes.idx).copy()
inst = np.full(D * W, -1, np.int32)
active = np.zeros(D * W, bool)
depth = np.zeros(D * W, np.int32)
for lane in (0, 1):                        # donors, inst 0, open LEFTs
    idx[lane, :4] = 0
    depth[lane] = 4
    active[lane] = True
    inst[lane] = 0
for lane in (4, 6):                        # thieves bound to inst 1
    inst[lane] = 1
inst[10] = 0                               # thief bound to inst 0
lanes = lanes._replace(idx=jnp.asarray(idx), inst=jnp.asarray(inst),
                       active=jnp.asarray(active),
                       depth=jnp.asarray(depth))
from repro.core.checkpoint import rebuild_stacks
lanes = dist._shard_lanes(rebuild_stacks(prob, lanes), mesh)
out = jax.tree_util.tree_map(np.asarray, STEAL(lanes))

newly = np.flatnonzero(out.active & ~np.asarray(lanes.active))
res = {
    "donated": int(out.donated.sum()),
    "newly_active": [int(x) for x in newly],
    "inst_of_new": [int(out.inst[x]) for x in newly],
}

# Budget-starvation regression: with max_ship=1, device 0 holds one
# donor of instance 0 (which has ZERO demand anywhere) and one donor of
# instance 1 (demanded on device 2).  A donatable-count budget would hand
# the whole advertisement to instance 0 and ship nothing; the
# demand-limited quota must ship the instance-1 task.
STEAL1 = steal_fn(1)
lanes = init_lanes(prob, D * W, seed_root=False)
idx = np.asarray(lanes.idx).copy()
inst = np.full(D * W, -1, np.int32)
active = np.zeros(D * W, bool)
depth = np.zeros(D * W, np.int32)
for lane, i in ((0, 0), (1, 1)):           # device 0: donors of inst 0 & 1
    idx[lane, :4] = 0
    depth[lane] = 4
    active[lane] = True
    inst[lane] = i
inst[4] = inst[5] = 1                      # device 2: thieves of inst 1
lanes = lanes._replace(idx=jnp.asarray(idx), inst=jnp.asarray(inst),
                       active=jnp.asarray(active),
                       depth=jnp.asarray(depth))
lanes = dist._shard_lanes(rebuild_stacks(prob, lanes), mesh)
out = jax.tree_util.tree_map(np.asarray, STEAL1(lanes))
newly = np.flatnonzero(out.active & ~np.asarray(lanes.active))
res["starve_donated"] = int(out.donated.sum())
res["starve_new_inst"] = [int(out.inst[x]) for x in newly]
print("RESULT " + json.dumps(res))
"""


def test_cross_device_steal_is_instance_scoped():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CROSS_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    import json
    res = json.loads(line[len("RESULT "):])
    # Exactly one task may move: donor(inst 0) -> the single inst-0 thief.
    assert res["donated"] == 1, res
    assert res["newly_active"] == [10], res
    assert res["inst_of_new"] == [0], res
    # Budget starvation: a zero-demand instance must not crowd a demanded
    # one out of the max_ship advertisement.
    assert res["starve_donated"] == 1, res
    assert res["starve_new_inst"] == [1], res
