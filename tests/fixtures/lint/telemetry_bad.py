"""Known-bad fixture for the telemetry-schema rule: unknown event
kind, unknown trace kind, missing required trace fields, unknown
lifecycle kind."""
from repro.solver import emit


def report(cb, trace, collector):
    emit(cb, "warp", round=1)                   # BAD: not in EVENT_KINDS
    trace.write("bogus", round=1)               # BAD: not in TRACE_KINDS
    trace.write("incumbent", round=1, inst=0)   # BAD: missing 'best'
    collector.lifecycle("nope", round_no=1, rid=2)   # BAD: unknown kind


class Emitter:
    def _emit(self, kind, **kw):
        pass

    def poke(self):
        self._emit("finished", rid=1)           # BAD: not in EVENT_KINDS
