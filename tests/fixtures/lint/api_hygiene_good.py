"""Known-good fixture for the api-hygiene rule: the exactly-once
deprecation shim pattern (DeprecationWarning + stacklevel=2 + a
message the pytest.ini error filters can pin)."""
import warnings


def old_entry(*args, **kwargs):
    warnings.warn("old_entry is deprecated; use new_entry",
                  DeprecationWarning, stacklevel=2)
    return None


def loud(msg):
    warnings.warn(msg)            # not a deprecation: out of scope
