"""Known-bad fixture for the pallas-contract rule: unpadded grid
divide, impure index_map, and an over-budget hard-coded tile."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import count_stats


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def doubled(x, *, tile: int = 8):
    rows = x.shape[0]
    grid = (rows // tile,)            # BAD: no _pad_rows before // tile
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, x.shape[1]),
                               lambda t: (pick(t), 0))],   # BAD: call in
        out_specs=pl.BlockSpec((tile, x.shape[1]),          # index_map
                               lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def pick(t):
    return t


def over_budget(table, mask, valid):
    # BAD: hard-coded tile with the split-phase layout blows the 4 MiB
    # VMEM working-set budget at the documented bound shape.
    return count_stats(table, mask, valid, tile=4096, stages=2)
