"""Known-good fixture for the telemetry-schema rule: valid kinds with
all required fields (plus allowed extras), variable kinds skipped."""
from repro.solver import emit


def report(cb, trace, collector, kind):
    emit(cb, "round", round=1, open_work=3)
    emit(cb, "done", round=9, open_work=0)
    trace.write("incumbent", round=1, inst=0, best=4, rid=7)
    trace.write("summary", rounds=2, nodes=10, lane_nodes=[10],
                inst_nodes=[10])
    trace.write(kind, round=1, rid=2)        # variable kind: runtime's job
    collector.lifecycle("admit", round_no=1, rid=2)


class Emitter:
    def _emit(self, kind, **kw):
        pass

    def poke(self):
        self._emit("retire", rid=1, best=3)
