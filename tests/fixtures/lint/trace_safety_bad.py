"""Known-bad fixture: every trace-safety hazard class, plus the
round-path placement readback.  tests/test_lint.py asserts the
trace-safety rule fires on each marked line."""
import jax
import jax.numpy as jnp
import numpy as np


def kernel(x, y):
    if x > 0:                      # BAD: Python `if` on a traced operand
        y = y + 1
    while y > 0:                   # BAD: Python `while` on a traced operand
        y = y - 1
    n = int(x)                     # BAD: int() of a traced value
    h = x.item()                   # BAD: .item() host sync
    a = np.asarray(y)              # BAD: np.asarray of a device array
    return n + h + a


jitted = jax.jit(kernel)


def make_loop(steps):
    def loop(x):
        z = jnp.sum(x)
        flag = bool(z)             # BAD: bool() of a traced value (builder)
        return z if flag else x
    return loop


run = jax.jit(make_loop(4))


class BadDriver:
    def __init__(self, lanes):
        self.lanes = lanes

    def step_round(self):
        self._bookkeep()
        return 0

    def _bookkeep(self):
        # BAD: per-round placement readback on the step_round path
        active = np.asarray(self.lanes.active)
        return active
