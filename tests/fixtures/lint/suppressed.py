"""Suppression fixture: one properly-suppressed hazard (with reason)
and one reasonless suppression (which is itself a finding)."""
import jax


def kernel(x):
    # repro-lint: disable=trace-safety -- fixture: deliberate host sync under test
    n = int(x)
    m = x.item()  # repro-lint: disable=trace-safety
    return n + m


jitted = jax.jit(kernel)
