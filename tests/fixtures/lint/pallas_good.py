"""Known-good fixture for the pallas-contract rule: padded grid,
pure index_map, autotuned tile."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import count_stats


def _pad_rows(x, tile: int):
    pad = (-x.shape[0]) % tile
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def doubled(x, *, tile: int = 8):
    x = _pad_rows(x, tile)
    grid = (x.shape[0] // tile,)          # padded first: exact tiling
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, x.shape[1]), lambda t: (t, 0))],
        out_specs=pl.BlockSpec((tile, x.shape[1]), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def in_budget(table, mask, valid):
    # tile=None defers to the autotuner, which owns the VMEM budget.
    return count_stats(table, mask, valid, tile=None, stages=None)
