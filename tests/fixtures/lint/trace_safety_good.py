"""Known-good fixture: the same shapes as trace_safety_bad.py written
the branchless/boundary way — the trace-safety rule must stay silent."""
import jax
import jax.numpy as jnp
import numpy as np


def kernel(x, y, tile: int, flip: bool = False):
    y = jnp.where(x > 0, y + 1, y)           # branchless select
    y = jax.lax.while_loop(lambda v: v > 0, lambda v: v - 1, y)
    if tile > 8:                              # static param: fine
        y = y * 2
    if flip:                                  # literal-default param: fine
        y = -y
    if y.shape[0] > 1:                        # shape is static metadata
        y = y.reshape(-1)
    return y


jitted = jax.jit(kernel)


def host_boundary(fn, x):
    """Host-side round boundary: syncs OUTSIDE the jitted region."""
    out = fn(x)
    return int(np.asarray(out).sum())         # not reachable from a jit


class GoodDriver:
    def __init__(self, lanes):
        self.lanes = lanes
        self._dirty = True

    def step_round(self):
        if self._dirty:
            self._rebuild_mirror()
        return 0

    def _rebuild_mirror(self):
        # Event-driven (dirty-flag guarded) readback of non-placement
        # state only — nothing for the round-path clause to flag.
        self._dirty = False
        return np.asarray(self.lanes.nodes)
