"""Known-bad fixture for the api-hygiene rule: malformed deprecation
shims (missing stacklevel; message the filters cannot pin)."""
import warnings


def old_entry(*args, **kwargs):
    warnings.warn("old_entry is deprecated; use new_entry",
                  DeprecationWarning)          # BAD: no stacklevel=2
    return None


def legacy_solve(*args, **kwargs):
    warnings.warn("use solve_instead",          # BAD: doesn't say
                  DeprecationWarning,           # 'deprecated'
                  stacklevel=2)
    return None
