"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The CI image bundles hypothesis; some dev containers don't.  This shim
implements the tiny strategy subset the suite uses (``integers``,
``sampled_from``, ``lists``) and replays ``max_examples`` seeded random
examples per test, so the property tests still exercise many inputs —
just without shrinking or example databases.  Import via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, strategies as st
"""

from __future__ import annotations


import random
from types import SimpleNamespace


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: rng.choice(seq))


def _lists(elem, min_size=0, max_size=10):
    return _Strategy(
        lambda rng: [elem.sample(rng)
                     for _ in range(rng.randint(min_size, max_size))])


strategies = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                             lists=_lists)


def settings(deadline=None, max_examples=20, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the example parameters (it would resolve them as fixtures).
        def wrapper():
            n = getattr(fn, "_stub_max_examples", 20)
            rng = random.Random(0)
            for _ in range(n):
                fn(*[s.sample(rng) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
