"""Fused node-evaluation protocol tests (DESIGN.md §1/§3).

Three properties of the refactor:

1. FUSION — the fused vertex-cover ``evaluate`` performs exactly ONE
   degree computation per node visit, while the legacy three-callback
   adapter pays one per callback (4 total).
2. ADAPTER EQUIVALENCE — a problem adapted via ``from_callbacks`` drives
   the engine through the identical search tree as its native fused form.
3. BACKEND INVARIANCE — the Pallas ``degree_stats`` backend is bitwise
   identical to the jnp backend: same NodeEval on every reachable state,
   same tree node-for-node as the serial oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import INF_VALUE, BinaryProblem
from _legacy import legacy_solve as solve
from repro.core.engine import init_lanes, make_expand
from repro.core.serial import serial_rb
from repro.problems import (
    gnp_graph, random_regularish_graph,
    make_degree_stats_fn, make_domination_stats_fn, make_dominating_set,
    make_dominating_set_py, make_vertex_cover, make_vertex_cover_callbacks,
    make_vertex_cover_py,
)


# -- 1. fusion: one degree pass per node visit --------------------------------

def test_fused_evaluate_single_degree_pass():
    """Acceptance criterion: exactly one degree computation per node."""
    g = gnp_graph(16, 0.35, seed=5)
    calls = {"n": 0}
    base = make_degree_stats_fn(g, backend="jnp")

    def counting(alive):
        calls["n"] += 1
        return base(alive)

    prob = make_vertex_cover(g, stats_fn=counting)
    state = prob.root()
    for _ in range(4):                    # walk a few nodes eagerly
        before = calls["n"]
        ev = prob.evaluate(state, INF_VALUE)
        assert calls["n"] == before + 1   # ONE pass services the whole visit
        state = ev.left

    # Tracing the engine step embeds exactly one pass per lane-step too.
    calls["n"] = 0
    jax.make_jaxpr(lambda l: make_expand(prob, 1)(l))(init_lanes(prob, 1))
    assert calls["n"] == 1


def test_legacy_adapter_pays_per_callback():
    """The pre-fusion baseline really did recompute degrees per callback —
    the measured gap the refactor closes (motivation, not a regression)."""
    g = gnp_graph(16, 0.35, seed=5)
    counter = {"n": 0}
    prob = make_vertex_cover_callbacks(g, degrees_counter=counter)
    prob.evaluate(prob.root(), INF_VALUE)
    assert counter["n"] >= 3              # leaf_value + lower_bound + applys


# -- 2. adapter equivalence ---------------------------------------------------

@pytest.mark.parametrize("n,p,seed", [(14, 0.3, 0), (16, 0.35, 5)])
def test_adapter_walks_identical_tree(n, p, seed):
    g = gnp_graph(n, p, seed=seed)
    serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
    for prob in (make_vertex_cover(g), make_vertex_cover_callbacks(g)):
        lanes = init_lanes(prob, 1)
        lanes = make_expand(prob, 200_000)(lanes)
        assert not bool(lanes.active.any())
        assert int(lanes.best.min()) == serial_best
        assert int(lanes.nodes.sum()) == serial_nodes


# -- 3. pallas backend == jnp backend -----------------------------------------

@pytest.mark.parametrize("n,p,seed", [(14, 0.3, 0), (16, 0.35, 5)])
def test_pallas_backend_matches_serial_tree(n, p, seed):
    """Node-for-node: the Pallas-backed engine walks the oracle's tree."""
    g = gnp_graph(n, p, seed=seed)
    serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
    prob = make_vertex_cover(g, backend="pallas", tile=32)
    lanes = init_lanes(prob, 1)
    lanes = make_expand(prob, 200_000)(lanes)
    assert not bool(lanes.active.any())
    assert int(lanes.best.min()) == serial_best
    assert int(lanes.nodes.sum()) == serial_nodes


def test_pallas_backend_nodeeval_bitwise_identical():
    """Every NodeEval field agrees between backends along a search walk."""
    g = gnp_graph(18, 0.3, seed=7)
    pj = make_vertex_cover(g)
    pp = make_vertex_cover(g, backend="pallas", tile=32)
    frontier = [pj.root()]
    seen = 0
    while frontier and seen < 40:
        state = frontier.pop()
        ej = pj.evaluate(state, INF_VALUE)
        ep = pp.evaluate(state, INF_VALUE)
        for a, b in zip(jax.tree_util.tree_leaves(ej),
                        jax.tree_util.tree_leaves(ep)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        seen += 1
        if not bool(ej.is_solution):
            frontier += [ej.left, ej.right]


def test_pallas_backend_multilane_solve():
    """Steals + CONVERTINDEX replay also route through the kernel."""
    g = gnp_graph(16, 0.35, seed=5)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    payload, stats, _ = solve(make_vertex_cover(g, backend="pallas", tile=32),
                              num_lanes=4, steps_per_round=64,
                              bootstrap_rounds=2, bootstrap_steps=4)
    assert stats.best == serial_best
    assert int(np.bitwise_count(np.asarray(payload)).sum()) == serial_best


def test_backend_rejects_unknown():
    g = gnp_graph(8, 0.3, seed=0)
    with pytest.raises(ValueError):
        make_vertex_cover(g, backend="cuda")
    with pytest.raises(ValueError):
        make_dominating_set(g, backend="cuda")


# -- 4. dominating set: pallas backend == jnp backend -------------------------
# (the backend-equivalence sweep of DESIGN.md §5.4; the stacked-service leg
# lives in tests/test_service.py)


@pytest.mark.parametrize("n,p,seed", [(12, 0.3, 9), (14, 0.25, 2)])
def test_ds_pallas_backend_matches_serial_tree(n, p, seed):
    """Node-for-node: the Pallas-backed ds engine walks the oracle's tree."""
    g = gnp_graph(n, p, seed=seed)
    serial_best, serial_nodes, _ = serial_rb(make_dominating_set_py(g))
    prob = make_dominating_set(g, backend="pallas", tile=32)
    lanes = init_lanes(prob, 1)
    lanes = make_expand(prob, 200_000)(lanes)
    assert not bool(lanes.active.any())
    assert int(lanes.best.min()) == serial_best
    assert int(lanes.nodes.sum()) == serial_nodes


def test_ds_pallas_backend_nodeeval_bitwise_identical():
    """Every NodeEval field agrees between ds backends along a search walk,
    including infeasible nodes (zero-coverage states)."""
    g = gnp_graph(14, 0.3, seed=2)
    pj = make_dominating_set(g)
    pp = make_dominating_set(g, backend="pallas", tile=16)
    frontier = [pj.root()]
    seen = 0
    while frontier and seen < 40:
        state = frontier.pop()
        ej = pj.evaluate(state, INF_VALUE)
        ep = pp.evaluate(state, INF_VALUE)
        for a, b in zip(jax.tree_util.tree_leaves(ej),
                        jax.tree_util.tree_leaves(ep)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        seen += 1
        if not bool(ej.is_solution):
            frontier += [ej.left, ej.right]


def test_ds_stats_fn_backends_agree_on_dead_state():
    """All-dominated / no-candidate states (kernel reports vertex -1, jnp
    argmax reports 0) must still produce identical discarded children."""
    g = gnp_graph(10, 0.4, seed=4)
    sj = make_domination_stats_fn(g)
    sp = make_domination_stats_fn(g, backend="pallas", tile=8)
    from repro.problems.graphs import full_mask
    full = jnp.asarray(np.asarray(full_mask(g.n)))
    zero = jnp.zeros_like(full)
    for dominated, cand in [(full, zero), (full, full), (zero, zero)]:
        a = [np.asarray(x) for x in sj(dominated, cand)]
        b = [np.asarray(x) for x in sp(dominated, cand)]
        np.testing.assert_array_equal(a, b)


def test_ds_pallas_multilane_solve():
    """Steals + CONVERTINDEX replay also route through the ds kernel."""
    g = gnp_graph(12, 0.3, seed=9)
    serial_best, _, _ = serial_rb(make_dominating_set_py(g))
    payload, stats, _ = solve(
        make_dominating_set(g, backend="pallas", tile=16),
        num_lanes=4, steps_per_round=64, bootstrap_rounds=2,
        bootstrap_steps=4)
    assert stats.best == serial_best
    assert int(np.bitwise_count(np.asarray(payload)).sum()) == serial_best


# -- derived helpers ----------------------------------------------------------

def test_derived_apply_matches_children():
    g = gnp_graph(14, 0.3, seed=3)
    prob = make_vertex_cover(g)
    s = prob.root()
    ev = prob.evaluate(s, INF_VALUE)
    for bit, child in ((0, ev.left), (1, ev.right)):
        got = prob.apply(s, jnp.int32(bit))
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(child)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_arity_from_evaluate():
    g = gnp_graph(14, 0.3, seed=3)
    prob = make_vertex_cover(g)
    root = prob.root()
    assert int(prob.arity(root, INF_VALUE)) == 2        # root branches
    assert int(prob.arity(root, jnp.int32(0))) == 0     # bound prunes all
