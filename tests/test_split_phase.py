"""Split-phase reduction, block autotuning, and the fused multi-step
round (DESIGN.md §5.5/§5.6 — the ISSUE 6 tentpole).

Four properties:

1. SPLIT-PHASE PARITY — the stages=2 kernels (stage-1 per-block partial
   stats + stage-2 combine) are bitwise-identical to the stages=1 grid,
   the ``ref.py`` jnp oracle and an independent numpy oracle across a
   (n, lanes, tile) sweep — including the smallest-id tie-break when the
   winning count appears in several tile blocks, and under vmap lifting.
2. IDLE-LANE PARKING — ``stacked_count_stats`` lanes with inst < 0
   (NO_INSTANCE) produce the empty-pass row (-1, -1, 0, 0) and are
   unaffected by any slot's table contents.
3. AUTOTUNER — ``kernels.autotune.choose`` returns valid cached choices
   (power-of-two tile, stages ∈ {1, 2}) and ``tile``/``stages``
   validation rejects malformed values with clear errors.
4. FUSED ROUNDS — ``evaluate_batch`` is bitwise-identical to
   ``vmap(evaluate)`` for vc, ds and stacked-service states, and the
   engine's search tree is identical to the serial oracle for S ∈ {1, 4}
   fused steps under both backends and autotuned tiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import INF_VALUE
from repro.core.engine import NO_INSTANCE, init_lanes, make_expand
from repro.core.serial import serial_rb
from repro.kernels import autotune, bitset_ops, ref
from repro.problems.dominating_set import (make_dominating_set,
                                           make_dominating_set_py)
from repro.problems.graphs import circulant_graph, full_mask, gnp_graph
from repro.problems.vertex_cover import (make_vertex_cover,
                                         make_vertex_cover_py)
from repro.service.batch_problem import (FAMILY_DS, FAMILY_VC, StackedSpec,
                                         StackedTables, pack_instance)
from repro.solver import Solver, SolverConfig
from test_bitset_ops import np_count_stats, random_masks


# -- 1. split-phase parity ----------------------------------------------------


@pytest.mark.parametrize("n,lanes,tile", [
    (40, 4, 8), (96, 6, 16), (130, 8, 32), (200, 5, 64), (64, 3, 64),
])
def test_split_phase_matches_single_stage_and_oracles(n, lanes, tile):
    """stages=2 ≡ stages=1 ≡ ref.py ≡ numpy across block counts (the
    tile sweep covers blocks ∈ {1 .. 17})."""
    g = gnp_graph(n, 0.2, seed=n)
    rng = np.random.default_rng(n)
    mask, valid = random_masks(rng, lanes, n), random_masks(rng, lanes, n)
    adj = jnp.asarray(g.adj)
    want = np_count_stats(g.adj, mask, valid)
    split = bitset_ops.count_stats(adj, jnp.asarray(mask),
                                   jnp.asarray(valid), tile=tile, stages=2)
    seq = bitset_ops.count_stats(adj, jnp.asarray(mask),
                                 jnp.asarray(valid), tile=tile, stages=1)
    np.testing.assert_array_equal(np.asarray(split), want)
    np.testing.assert_array_equal(np.asarray(seq), want)
    np.testing.assert_array_equal(
        np.asarray(ref.count_stats_ref(adj, jnp.asarray(mask),
                                       jnp.asarray(valid))), want)


def test_split_phase_tiebreak_across_block_boundary():
    """The winning count appears in EVERY tile block (circulant graph:
    all vertices tie) — the combine must keep the smallest id, i.e. the
    winner of block 0, not of the last block written."""
    g = circulant_graph(96, (1, 7))            # 4-regular: global tie
    adj = jnp.asarray(g.adj)
    alive = jnp.asarray(full_mask(g.n))[None, :]
    for tile in (8, 16, 32):                   # 12, 6, 3 blocks
        got = np.asarray(bitset_ops.count_stats(adj, alive, alive,
                                                tile=tile, stages=2))[0]
        assert (got[0], got[1]) == (4, 0), f"tile={tile}: {got}"
    # Tie constructed to straddle exactly one block boundary: only
    # vertices 15 and 16 valid (tile=16 puts them in blocks 0 and 1).
    sel = np.zeros((1, g.words), np.uint32)
    for v in (15, 16):
        sel[0, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    got = np.asarray(bitset_ops.count_stats(
        adj, alive, jnp.asarray(sel), tile=16, stages=2))[0]
    assert got[1] == 15                        # smaller id wins the tie


def test_split_phase_vmap_lift():
    """vmap over lanes — the engine's calling convention — agrees with
    the flat call for the split-phase path."""
    g = gnp_graph(80, 0.25, seed=17)
    rng = np.random.default_rng(17)
    mask = jnp.asarray(random_masks(rng, 6, g.n))
    valid = jnp.asarray(random_masks(rng, 6, g.n))
    adj = jnp.asarray(g.adj)
    flat = bitset_ops.count_stats(adj, mask, valid, tile=16, stages=2)
    mapped = jax.jit(jax.vmap(
        lambda m, v: bitset_ops.count_stats(adj, m[None, :], v[None, :],
                                            tile=16, stages=2)[0]))(
        mask, valid)
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(flat))


@pytest.mark.parametrize("stages", [1, 2])
def test_stacked_split_phase_matches_numpy(stages):
    k, n, lanes = 3, 40, 9
    w = (n + 31) // 32
    tables = np.zeros((k, n, w), np.uint32)
    for i, s in enumerate((21, 22, 23)):
        g = gnp_graph(n - 2 * i, 0.3, seed=s)
        tables[i] = pack_instance(g, i % 2, n)[0]
    rng = np.random.default_rng(31)
    inst = rng.integers(-1, k, lanes).astype(np.int32)
    inst[0] = -1                               # force an idle lane
    mask, valid = random_masks(rng, lanes, n), random_masks(rng, lanes, n)
    got = bitset_ops.stacked_count_stats(
        jnp.asarray(tables), jnp.asarray(inst), jnp.asarray(mask),
        jnp.asarray(valid), tile=16, stages=stages)
    want = np.stack([
        np.array([-1, -1, 0, 0], np.int32) if int(i) < 0
        else np_count_stats(tables[int(i)], mask[l:l + 1],
                            valid[l:l + 1])[0]
        for l, i in enumerate(inst)])
    np.testing.assert_array_equal(np.asarray(got), want)


# -- 2. idle-lane parking -----------------------------------------------------


def test_stacked_idle_lanes_ignore_table_contents():
    """A NO_INSTANCE lane's output is the empty-pass row and does not
    change when every slot's table flips every bit — idle lanes do no
    table traffic."""
    k, n, lanes = 2, 32, 5
    w = (n + 31) // 32
    rng = np.random.default_rng(5)
    tables = rng.integers(0, 2**32, (k, n, w),
                          dtype=np.uint64).astype(np.uint32)
    inst = np.full(lanes, NO_INSTANCE, np.int32)
    mask, valid = random_masks(rng, lanes, n), random_masks(rng, lanes, n)

    def run(tb):
        return np.asarray(bitset_ops.stacked_count_stats(
            jnp.asarray(tb), jnp.asarray(inst), jnp.asarray(mask),
            jnp.asarray(valid), tile=16))

    parked = np.tile(np.array([-1, -1, 0, 0], np.int32), (lanes, 1))
    np.testing.assert_array_equal(run(tables), parked)
    np.testing.assert_array_equal(run(~tables), parked)


# -- 3. autotuner + validation ------------------------------------------------


def test_autotune_choices_are_valid_and_cached():
    autotune.clear_cache()
    for (n, w, lanes, k) in [(60, 2, 16, 1), (128, 4, 64, 1),
                             (256, 8, 64, 8), (7, 1, 1, 1)]:
        c = autotune.choose(n, w, lanes=lanes, k=k)
        assert c.tile >= 1 and (c.tile & (c.tile - 1)) == 0, c
        assert c.stages in (1, 2), c
        assert autotune.choose(n, w, lanes=lanes, k=k) is c  # cache hit
    # The predicted cost of the chosen config is minimal among candidates.
    c = autotune.choose(128, 4, lanes=64)
    best = autotune.predict_cost(128, 4, 64, 1, tile=c.tile,
                                 stages=c.stages, platform="cpu")
    for tile in autotune.candidate_tiles(128):
        for stages in (1, 2):
            cost = autotune.predict_cost(128, 4, 64, 1, tile=tile,
                                         stages=stages, platform="cpu")
            if cost is not None:
                assert best <= cost + 1e-12


def test_tile_validation_errors():
    g = gnp_graph(40, 0.2, seed=1)
    adj = jnp.asarray(g.adj)
    m = jnp.asarray(random_masks(np.random.default_rng(1), 2, g.n))
    # Split-phase requires a power-of-two tile; stages=1 does not.
    with pytest.raises(ValueError, match="power of two"):
        bitset_ops.count_stats(adj, m, m, tile=24, stages=2)
    np.testing.assert_array_equal(
        np.asarray(bitset_ops.count_stats(adj, m, m, tile=24, stages=1)),
        np_count_stats(g.adj, np.asarray(m), np.asarray(m)))
    for bad in (0, -4, True):
        with pytest.raises(ValueError, match="tile"):
            bitset_ops.count_stats(adj, m, m, tile=bad)
    with pytest.raises(ValueError, match="stages"):
        bitset_ops.count_stats(adj, m, m, tile=16, stages=3)


# -- 4. fused rounds ----------------------------------------------------------


def _tree_equal(a, b):
    fa, _ = jax.tree_util.tree_flatten(a)
    fb, _ = jax.tree_util.tree_flatten(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


@pytest.mark.parametrize("family", ["vc", "ds"])
def test_evaluate_batch_matches_vmap_evaluate(family):
    g = gnp_graph(48, 0.2, seed=13)
    maker = make_vertex_cover if family == "vc" else make_dominating_set
    prob = maker(g, backend="pallas")
    assert prob.evaluate_batch is not None
    lanes = 7
    rng = np.random.default_rng(13)
    root = prob.root()
    states = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (lanes,) + x.shape), root)
    leaves, treedef = jax.tree_util.tree_flatten(states)
    sub = jnp.asarray(random_masks(rng, lanes, g.n))
    leaves = [leaves[0] & sub] + list(leaves[1:])
    states = jax.tree_util.tree_unflatten(treedef, leaves)
    best = jnp.full((lanes,), int(INF_VALUE), jnp.int32)
    assert _tree_equal(jax.jit(prob.evaluate_batch)(states, best),
                       jax.jit(jax.vmap(prob.evaluate))(states, best))


def test_stacked_evaluate_batch_matches_vmap_evaluate():
    spec = StackedSpec(n=40, k=3)
    tb = spec.empty_tables()
    for s, (fam, seed) in enumerate([(FAMILY_VC, 41), (FAMILY_DS, 42),
                                     (FAMILY_VC, 43)]):
        adj, fm, f = pack_instance(gnp_graph(40 - s, 0.25, seed=seed),
                                   fam, 40)
        tb.adj[s], tb.fullm[s], tb.family[s] = adj, fm, f
    tables = StackedTables(*(jnp.asarray(t) for t in tb))
    prob = spec.bind(tables, "pallas")
    assert prob.evaluate_batch is not None
    inst = jnp.asarray([0, 1, 2, 0, 1, -1, 2, -1], jnp.int32)
    states = jax.vmap(prob.instance_root)(inst)
    best = jnp.full((inst.shape[0],), int(INF_VALUE), jnp.int32)
    assert _tree_equal(jax.jit(prob.evaluate_batch)(states, best),
                       jax.jit(jax.vmap(prob.evaluate))(states, best))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_steps_tree_identity(backend):
    """S ∈ {1, 4} fused steps produce the IDENTICAL search (same best,
    same node count, same payload) for both backends at autotuned tiles,
    and the optimum matches the serial oracle."""
    g = gnp_graph(24, 0.3, seed=2)
    for maker, py in ((make_vertex_cover, make_vertex_cover_py),
                      (make_dominating_set, make_dominating_set_py)):
        prob = maker(g, backend=backend)
        want_best, _, _ = serial_rb(py(g))
        results = [
            Solver(SolverConfig(lanes=4, steps_per_round=8,
                                backend=backend, fused_steps=s)).solve(prob)
            for s in (1, 4)]
        for res in results:
            assert res.stats.best == want_best
        assert results[0].stats.nodes == results[1].stats.nodes
        assert np.array_equal(results[0].payload, results[1].payload)


@pytest.mark.parametrize("fused_steps", [1, 4])
def test_fused_steps_expand_identity(fused_steps):
    """make_expand at S>1 visits the identical node sequence (same nodes
    AND same per-lane step counters) as S=1."""
    g = gnp_graph(20, 0.3, seed=8)
    prob = make_vertex_cover(g)
    lanes0 = init_lanes(prob, 4)
    base = jax.jit(make_expand(prob, 16))(lanes0)
    fused = jax.jit(make_expand(prob, 16, fused_steps=fused_steps))(lanes0)
    assert _tree_equal(base, fused)
