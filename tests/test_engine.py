"""Vectorized-engine tests: the jnp lanes must agree with the serial oracle.

Paper validation targets applied to the TPU-native engine:
  * identical optima to SERIAL-RB for any lane count / round granularity;
  * exhaustive trees: total nodes visited == serial count (no subtree lost,
    none explored twice — the GETHEAVIESTTASKINDEX/DELEGATED invariant);
  * T_S <= T_R accounting;
  * checkpoint/restart (paper §VII) resumes to the same optimum, including
    elastic restarts onto a different lane count.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import checkpoint as ckpt
from repro.core.api import BinaryProblem, INF_VALUE
from _legacy import legacy_solve as solve
from repro.core.engine import init_lanes, make_expand
from repro.core.serial import serial_rb
from repro.problems import (
    gnp_graph, random_regularish_graph,
    make_dominating_set, make_dominating_set_py,
    make_subset_sum, make_subset_sum_py,
    make_vertex_cover, make_vertex_cover_py,
)


def full_tree_problem_jnp(depth: int) -> BinaryProblem:
    """Exhaustive complete binary tree (same as the serial twin in
    test_serial_protocol) — exact node accounting, pruning never fires.

    Built through the legacy-callback adapter, which doubles as its
    regression test: the engine must drive adapted problems identically.
    """

    def root():
        return (jnp.int32(0), jnp.int32(0))

    def apply(s, b):
        d, p = s
        return (d + 1, p * 2 + b.astype(jnp.int32))

    def leaf_value(s):
        d, p = s
        return d == depth, p + 1

    return BinaryProblem.from_callbacks(
        name=f"full{depth}", max_depth=depth, root=root, apply=apply,
        leaf_value=leaf_value,
        lower_bound=lambda s: jnp.int32(0),
        solution_payload=lambda s: s[1],
        payload_zero=lambda: jnp.int32(0),
    )


# -- single-lane engine == serial oracle -------------------------------------

@pytest.mark.parametrize("depth", [3, 6])
def test_single_lane_exhaustive_tree(depth):
    prob = full_tree_problem_jnp(depth)
    lanes = init_lanes(prob, 1)
    lanes = make_expand(prob, 1 << (depth + 3))(lanes)
    assert not bool(lanes.active.any())
    assert int(lanes.best.min()) == 1
    assert int(lanes.nodes.sum()) == 2 ** (depth + 1) - 1


@pytest.mark.parametrize("n,p,seed", [(14, 0.3, 0), (16, 0.35, 5), (18, 0.2, 7)])
def test_single_lane_vc_matches_serial(n, p, seed):
    g = gnp_graph(n, p, seed=seed)
    serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
    prob = make_vertex_cover(g)
    lanes = init_lanes(prob, 1)
    lanes = make_expand(prob, 200_000)(lanes)
    assert not bool(lanes.active.any())
    assert int(lanes.best.min()) == serial_best
    # One lane has no steals: the engine must walk the identical tree.
    assert int(lanes.nodes.sum()) == serial_nodes


# -- multi-lane solve == serial optimum, full coverage ------------------------

@pytest.mark.parametrize("lanes_n", [2, 4, 8])
@pytest.mark.parametrize("depth", [4, 6])
def test_multilane_exhaustive_coverage(lanes_n, depth):
    prob = full_tree_problem_jnp(depth)
    _, stats, _ = solve(prob, num_lanes=lanes_n, steps_per_round=8,
                        bootstrap_rounds=3, bootstrap_steps=2)
    assert stats.best == 1
    assert stats.nodes == 2 ** (depth + 1) - 1     # none lost, none twice
    assert stats.t_s <= stats.t_r + 1              # paper: T_S <= T_R


@pytest.mark.parametrize("lanes_n", [1, 4, 16])
def test_multilane_vc_optimum(lanes_n):
    g = gnp_graph(16, 0.35, seed=5)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    prob = make_vertex_cover(g)
    payload, stats, _ = solve(prob, num_lanes=lanes_n, steps_per_round=64,
                              bootstrap_rounds=2, bootstrap_steps=4)
    assert stats.best == serial_best
    # The returned payload must be a valid cover of the claimed size.
    cover_bits = np.asarray(payload)
    assert int(np.bitwise_count(cover_bits).sum()) == serial_best


@pytest.mark.parametrize("lanes_n", [4, 8])
def test_multilane_ds_optimum(lanes_n):
    g = gnp_graph(12, 0.3, seed=9)
    serial_best, _, _ = serial_rb(make_dominating_set_py(g))
    payload, stats, _ = solve(make_dominating_set(g), num_lanes=lanes_n,
                              steps_per_round=64, bootstrap_rounds=2,
                              bootstrap_steps=4)
    assert stats.best == serial_best


def test_multilane_subset_sum_optimum():
    vals = [3, 34, 4, 12, 5, 2, 7, 13]
    serial_best, _, _ = serial_rb(make_subset_sum_py(vals, 30))
    _, stats, _ = solve(make_subset_sum(vals, 30), num_lanes=4,
                        steps_per_round=32, bootstrap_rounds=2)
    assert stats.best == serial_best


def test_harder_regular_instance_many_lanes():
    g = random_regularish_graph(36, 4, seed=3)
    serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
    _, stats, _ = solve(make_vertex_cover(g), num_lanes=32,
                        steps_per_round=64, bootstrap_rounds=4,
                        bootstrap_steps=4)
    assert stats.best == serial_best
    # Bound-sharing may prune differently than the serial order but must
    # never *expand* the tree beyond ~the serial count by re-exploration.
    assert stats.nodes <= serial_nodes * 2


# -- checkpoint / restart (paper §VII) ----------------------------------------

def test_checkpoint_restart_same_lanes(tmp_path):
    g = gnp_graph(16, 0.3, seed=11)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    prob = make_vertex_cover(g)
    path = str(tmp_path / "solver.ckpt")

    # Run a few rounds only, checkpointing every round.
    solve(prob, num_lanes=4, steps_per_round=16, max_rounds=3,
          bootstrap_rounds=1, checkpoint_every=1, checkpoint_path=path)
    assert os.path.exists(path)

    # Resume to completion; optimum must match the serial oracle.
    _, stats, _ = solve(prob, num_lanes=4, steps_per_round=64,
                        resume_from=path)
    assert stats.best == serial_best


@pytest.mark.parametrize("new_lanes", [2, 8])
def test_elastic_restart_different_lane_count(new_lanes, tmp_path):
    g = gnp_graph(16, 0.3, seed=13)
    serial_best, _, _ = serial_rb(make_vertex_cover_py(g))
    prob = make_vertex_cover(g)
    path = str(tmp_path / "solver.ckpt")
    solve(prob, num_lanes=4, steps_per_round=16, max_rounds=3,
          bootstrap_rounds=1, checkpoint_every=1, checkpoint_path=path)
    _, stats, _ = solve(prob, num_lanes=new_lanes, steps_per_round=64,
                        resume_from=path)
    assert stats.best == serial_best


def test_checkpoint_roundtrip_is_lossless(tmp_path):
    prob = full_tree_problem_jnp(5)
    lanes = init_lanes(prob, 4)
    lanes = make_expand(prob, 7)(lanes)
    path = str(tmp_path / "rt.ckpt")
    ckpt.save(path, lanes)
    restored, pool = ckpt.restore(path, prob, 4)
    assert not pool
    np.testing.assert_array_equal(np.asarray(restored.idx),
                                  np.asarray(lanes.idx))
    np.testing.assert_array_equal(np.asarray(restored.depth),
                                  np.asarray(lanes.depth))
    np.testing.assert_array_equal(np.asarray(restored.active),
                                  np.asarray(lanes.active))
    np.testing.assert_array_equal(np.asarray(restored.best),
                                  np.asarray(lanes.best))
