"""The public API of the front-door modules is snapshot-guarded (ISSUE 4):
any change to the surface of ``repro.registry`` / ``repro.solver`` must be
reviewed by regenerating ``tools/api_surface.txt`` in the same commit.
"""

import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_tool(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "api_surface.py"), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=300)


def test_api_surface_matches_snapshot():
    proc = run_tool()
    assert proc.returncode == 0, (
        "public API drifted from tools/api_surface.txt — review the diff "
        "and run `python tools/api_surface.py --update`:\n" + proc.stderr)


def test_api_surface_detects_drift(tmp_path):
    """The checker actually fails on drift (guards the guard)."""
    snap = ROOT / "tools" / "api_surface.txt"
    original = snap.read_text()
    try:
        snap.write_text(original + "  def rogue_symbol()\n")
        proc = run_tool()
        assert proc.returncode == 1
        assert "rogue_symbol" in proc.stderr
    finally:
        snap.write_text(original)
