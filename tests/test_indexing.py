"""Indexing machinery tests: Fig. 4 transcriptions, vectorized forms, §IV-C.

The invariants come straight from the paper:
  * GETHEAVIESTTASKINDEX returns the *shallowest* open slot (max weight);
  * FIXINDEX reconstructs the right-sibling path (interior -1 -> 0, last=1);
  * the vectorized jnp forms agree with the scalar Fig. 4 forms bit-for-bit;
  * the §IV-C arbitrary-branching encoding degenerates to the binary one.
"""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.api import DELEGATED, LEFT, RIGHT, UNVISITED
from repro.core.indexing import (
    ArbitraryIndex, extract_task, fix_index, get_heaviest_task_index,
    heaviest_open_slot, index_to_position, task_weight,
)

D = 12


# -- scalar Fig. 4 ----------------------------------------------------------

def test_paper_worked_example():
    """§IV-A worked example: current_idx={1,0,1,0} at N_{3,2}."""
    cur = [1, 0, 1, 0]
    got = get_heaviest_task_index(cur)
    assert got == [1, -1]
    assert cur == [1, -1, 1, 0]
    fixed = fix_index(got)
    assert fixed == [1, 1]           # N_{1,1}, the heaviest task
    # second steal while still at N_{3,2}
    got2 = get_heaviest_task_index(cur)
    assert got2 == [1, -1, 1, -1]
    assert cur == [1, -1, 1, -1]
    assert fix_index(got2) == [1, 0, 1, 1]


def test_get_heaviest_none_when_all_explored():
    assert get_heaviest_task_index([1, 1, 1]) is None
    assert get_heaviest_task_index([1, -1, 1]) is None
    assert get_heaviest_task_index([]) is None


@given(st.lists(st.sampled_from([0, 1, -1]), min_size=1, max_size=D))
def test_scalar_extract_marks_first_zero(bits):
    cur = [1] + bits                       # leading root marker like the paper
    before = list(cur)
    got = get_heaviest_task_index(cur)
    zeros = [i for i, b in enumerate(before) if b == 0]
    if not zeros:
        assert got is None
        assert cur == before
    else:
        i = zeros[0]
        assert cur[i] == -1
        assert cur[:i] == before[:i] and cur[i + 1:] == before[i + 1:]
        assert got == before[:i] + [-1]
        fixed = fix_index(got)
        assert fixed[-1] == 1
        assert all(b in (0, 1) for b in fixed)
        # FIXINDEX restores the donor's *path*: interior negatives were lefts
        assert fixed[:-1] == [0 if b < 0 else b for b in before[:i]]


# -- vectorized == scalar ---------------------------------------------------

@given(st.lists(st.sampled_from([0, 1, -1]), min_size=1, max_size=D))
@settings(deadline=None, max_examples=50)
def test_vectorized_matches_scalar(bits):
    depth = len(bits)
    idx = np.full(D + 1, int(UNVISITED), np.int8)
    idx[:depth] = bits
    jidx = jnp.asarray(idx)
    slot = heaviest_open_slot(jidx, jnp.int32(0), jnp.int32(depth))
    scal = list(bits)
    got = get_heaviest_task_index(scal)
    if got is None:
        assert int(slot) == D + 1      # sentinel: no open slot
        return
    zero_pos = len(got) - 1
    assert int(slot) == zero_pos
    donor, task_bits = extract_task(jidx, slot)
    assert int(donor[zero_pos]) == int(DELEGATED)
    fixed = fix_index(got)
    np.testing.assert_array_equal(
        np.asarray(task_bits[: len(fixed)]), np.asarray(fixed, np.int8))
    assert np.all(np.asarray(task_bits[len(fixed):]) == int(UNVISITED))


def test_base_depth_protects_inherited_path():
    """Slots below ``base`` (the thief's fixed path) are never donated."""
    idx = jnp.asarray(np.array([0, 0, 1, 0, 0], np.int8))
    # base=2: slots 0,1 are the inherited path (zeros there NOT stealable).
    slot = heaviest_open_slot(idx, jnp.int32(2), jnp.int32(5))
    assert int(slot) == 3


def test_task_weight_matches_paper():
    # w(N_{d,p}) = 1/(d+1); stolen node sits at depth slot+1.
    assert float(task_weight(jnp.int32(0))) == pytest.approx(1 / 2)
    assert float(task_weight(jnp.int32(3))) == pytest.approx(1 / 5)


def test_index_to_position():
    assert index_to_position([]) == (0, 0)
    assert index_to_position([0, 1]) == (2, 1)
    assert index_to_position([1, 1]) == (2, 3)


# -- §IV-C arbitrary branching ---------------------------------------------

def test_arbitrary_binary_degenerates():
    """With branching factor 2 the two-row §IV-C encoding must agree with
    the binary scheme: heaviest depth == shallowest open slot."""
    a = ArbitraryIndex(8)
    a.push_child(0, 2)      # went left at depth 0 -> idx2=1 (right pending)
    a.push_child(1, 2)      # went right at depth 1 -> idx2=0
    a.push_child(0, 2)      # left at depth 2 -> idx2=1
    assert a.heaviest_depth() == 0
    path, first, s = a.steal()
    assert list(path) == [0] and first == 1 and s == 1
    assert a.heaviest_depth() == 2


def test_arbitrary_steal_suffix_rule():
    """§IV-C: the stolen set S must be a suffix of the children ordering."""
    a = ArbitraryIndex(4)
    a.push_child(1, 5)      # at child 1 of 5 -> 3 right siblings pending
    path, first, s = a.steal(take=2)
    assert (first, s) == (3, 2)        # children {3,4}: the suffix
    assert a.idx2[0] == 1              # child 2 still stealable
    path, first, s = a.steal(take=5)
    assert (first, s) == (2, 1)
    assert a.heaviest_depth() is None


def test_arbitrary_advance_sibling():
    a = ArbitraryIndex(4)
    a.push_child(0, 3)
    assert a.advance_sibling()
    assert a.idx1[0] == 1 and a.idx2[0] == 1
    a.steal()
    assert not a.advance_sibling()     # last sibling was delegated
