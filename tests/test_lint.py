"""repro-lint pass tests (ISSUE 10): every rule pack fires exactly on
its bad fixture, stays silent on the good one, suppressions behave,
and the full-repo run is clean."""
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import all_rules, lint_paths  # noqa: E402

FIXTURES = "tests/fixtures/lint"


def _lint(relpath, **kw):
    return lint_paths([relpath], root=ROOT, **kw)


def _rules_hit(result):
    return {f.rule for f in result.findings}


# ---------------------------------------------------------------------------
# rule packs fire on bad fixtures, stay silent on good ones
# ---------------------------------------------------------------------------

PACKS = [
    ("trace-safety", "trace_safety_bad.py", "trace_safety_good.py"),
    ("pallas-contract", "pallas_bad.py", "pallas_good.py"),
    ("telemetry-schema", "telemetry_bad.py", "telemetry_good.py"),
    ("api-hygiene", "api_hygiene_bad.py", "api_hygiene_good.py"),
]


@pytest.mark.parametrize("rule,bad,good", PACKS,
                         ids=[p[0] for p in PACKS])
def test_pack_fires_on_bad_and_only_there(rule, bad, good):
    bad_result = _lint(f"{FIXTURES}/{bad}")
    assert _rules_hit(bad_result) == {rule}, bad_result.findings
    good_result = _lint(f"{FIXTURES}/{good}")
    assert good_result.findings == [], \
        [f.format() for f in good_result.findings]


def test_trace_safety_finds_every_hazard_class():
    result = _lint(f"{FIXTURES}/trace_safety_bad.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "`if` on a traced value" in messages
    assert "`while` on a traced value" in messages
    assert "`int()` of a traced value" in messages
    assert "`.item()` on a traced value" in messages
    assert "np.asarray" in messages
    assert "`bool()` of a traced value" in messages      # builder closure
    assert "per-round bookkeeping" in messages           # step_round path
    assert len(result.findings) >= 7


def test_pallas_contract_finds_every_clause():
    result = _lint(f"{FIXTURES}/pallas_bad.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "without padding" in messages
    assert "index_map must be pure" in messages
    assert "VMEM" in messages
    assert len(result.findings) == 3


def test_telemetry_schema_finds_every_shape():
    result = _lint(f"{FIXTURES}/telemetry_bad.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "unknown progress-event kind 'warp'" in messages
    assert "unknown trace record kind 'bogus'" in messages
    assert "missing required field(s) ['best']" in messages
    assert "unknown lifecycle kind 'nope'" in messages
    assert "unknown progress-event kind 'finished'" in messages
    assert len(result.findings) == 5


def test_api_hygiene_deprecation_clauses():
    result = _lint(f"{FIXTURES}/api_hygiene_bad.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "stacklevel=2" in messages
    assert "should say 'deprecated'" in messages
    assert len(result.findings) == 2


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_with_reason_silences_reasonless_does_not():
    result = _lint(f"{FIXTURES}/suppressed.py")
    # int(x) is suppressed with a reason; x.item()'s suppression lacks
    # one, which silences the hazard but is itself an error.
    assert _rules_hit(result) == {"suppression"}
    assert len(result.findings) == 1
    assert "missing its reason" in result.findings[0].message


def test_unknown_rule_suppression_reported(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text("x = 1  # repro-lint: disable=no-such-rule -- because\n")
    result = lint_paths([str(src)], root=tmp_path)
    assert any("unknown rule" in f.message for f in result.findings)


# ---------------------------------------------------------------------------
# api-surface snapshot clause (needs a module inside MODULES)
# ---------------------------------------------------------------------------

def _fake_repo(tmp_path, snapshot_text):
    pkg = tmp_path / "src" / "repro" / "obs"
    pkg.mkdir(parents=True)
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    (pkg / "__init__.py").write_text(
        '__all__ = ["Ghost"]\nGhost = 1\n')
    tools = tmp_path / "tools"
    tools.mkdir()
    (tools / "api_surface.py").write_text('MODULES = ("repro.obs",)\n')
    (tools / "api_surface.txt").write_text(snapshot_text)
    return tmp_path


def test_export_missing_from_snapshot_is_flagged(tmp_path):
    root = _fake_repo(tmp_path, "module repro.obs\n  const Real = 1\n")
    result = lint_paths(["src"], root=root, rules=["api-hygiene"])
    assert any("Ghost" in f.message and "missing from" in f.message
               for f in result.findings), result.findings


def test_module_without_snapshot_section_is_flagged(tmp_path):
    root = _fake_repo(tmp_path, "module repro.other\n")
    result = lint_paths(["src"], root=root, rules=["api-hygiene"])
    assert any("no section" in f.message for f in result.findings)


def test_snapshot_clause_clean_when_synced(tmp_path):
    root = _fake_repo(tmp_path, "module repro.obs\n  const Ghost = 1\n")
    result = lint_paths(["src"], root=root, rules=["api-hygiene"])
    assert result.findings == []


# ---------------------------------------------------------------------------
# whole-repo + CLI
# ---------------------------------------------------------------------------

def test_full_repo_is_clean():
    result = lint_paths(["src"], root=ROOT)
    assert result.errors == [], [f.format() for f in result.errors]
    assert result.files > 30
    # idle seed modules stay allowlisted until ROADMAP Open item 3
    assert result.skipped, "expected allowlisted seed modules"


def test_registry_has_all_four_packs():
    names = set(all_rules())
    assert {"trace-safety", "pallas-contract", "telemetry-schema",
            "api-hygiene"} <= names


def test_cli_exit_codes_and_json(tmp_path):
    out = tmp_path / "findings.json"
    bad = subprocess.run(
        [sys.executable, "tools/lint.py",
         f"{FIXTURES}/api_hygiene_bad.py", "--json", str(out)],
        cwd=ROOT, capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    payload = json.loads(out.read_text())
    assert payload["errors"] == 2
    assert all(f["rule"] == "api-hygiene" for f in payload["findings"])

    good = subprocess.run(
        [sys.executable, "tools/lint.py",
         f"{FIXTURES}/api_hygiene_good.py"],
        cwd=ROOT, capture_output=True, text=True)
    assert good.returncode == 0, good.stdout + good.stderr


def test_cli_list_rules():
    proc = subprocess.run(
        [sys.executable, "tools/lint.py", "--list-rules"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0
    for rule in ("trace-safety", "pallas-contract", "telemetry-schema",
                 "api-hygiene"):
        assert rule in proc.stdout
