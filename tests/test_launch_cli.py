"""Launcher capability checks (ISSUE 3 satellite).

``launch/solve.py`` used to hard-code "pallas is vc-only" and fail fast on
``--backend pallas --problem ds``.  The check is now DATA: every problem
factory advertises its kernel backends (``backends`` attribute, DESIGN.md
§5.4) and the CLI validates --backend against the registry — so ds+pallas
is accepted the moment the factory supports it, and a hypothetical
jnp-only problem still fails fast with the capability list in the error.
"""

import sys

import pytest

from repro.launch import solve
from repro.problems import (PROBLEM_FACTORIES, make_subset_sum,
                            problem_backends)


def test_factories_advertise_backends():
    assert problem_backends("vc") == ("jnp", "pallas")
    assert problem_backends("ds") == ("jnp", "pallas")
    assert make_subset_sum.backends == ("jnp",)     # no bitset table


def run_main(argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", ["solve"] + argv)
    solve.main()


def test_solve_cli_accepts_ds_pallas(monkeypatch, capsys):
    """The stale fail-fast is gone: a ds Pallas solve runs end-to-end and
    prints the same optimum as the jnp backend."""
    args = ["--problem", "ds", "--instance", "gnp:10:30:4", "--lanes", "4",
            "--steps-per-round", "16"]
    run_main(args + ["--backend", "pallas"], monkeypatch)
    out_pallas = capsys.readouterr().out
    run_main(args + ["--backend", "jnp"], monkeypatch)
    out_jnp = capsys.readouterr().out
    opt = [l for l in out_pallas.splitlines() if "optimum=" in l][0]
    assert "optimum=" in opt
    assert (opt.split("optimum=")[1].split()[0]
            == [l for l in out_jnp.splitlines()
                if "optimum=" in l][0].split("optimum=")[1].split()[0])


def test_solve_cli_rejects_unsupported_backend(monkeypatch):
    """A factory that does not advertise pallas still fails fast, with the
    advertised capability list in the error message."""
    def jnp_only_factory(graph, backend="jnp"):
        raise AssertionError("factory must not be called on a rejected run")

    jnp_only_factory.backends = ("jnp",)
    monkeypatch.setitem(PROBLEM_FACTORIES, "ds", jnp_only_factory)
    with pytest.raises(SystemExit):
        run_main(["--problem", "ds", "--instance", "gnp:10:30:4",
                  "--backend", "pallas"], monkeypatch)
