"""Launcher capability checks (ISSUE 3 satellite, registry-driven since
ISSUE 4).

``launch/solve.py`` contains zero per-problem knowledge: ``--problem``
choices, instance parsing and ``--backend`` validation all come from the
``repro.registry`` ProblemSpec table.  A family gains a CLI the moment it
registers (demonstrated end-to-end by subset sum, which had no CLI before
the registry existed), and a jnp-only family still fails fast with the
capability list in the error.
"""

import dataclasses
import sys

import pytest

from repro import registry
from repro.launch import solve
from repro.problems import (PROBLEM_FACTORIES, make_subset_sum,
                            problem_backends)
from repro.solver import Solver


def test_factories_advertise_backends():
    assert problem_backends("vc") == ("jnp", "pallas")
    assert problem_backends("ds") == ("jnp", "pallas")
    assert make_subset_sum.backends == ("jnp",)     # no bitset table
    # The deprecated factory table is a registry view, never a fork.
    assert set(PROBLEM_FACTORIES) == set(registry.names())


def run_main(argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", ["solve"] + argv)
    solve.main()


def optimum_of(out: str) -> str:
    line = [l for l in out.splitlines() if "optimum=" in l][0]
    return line.split("optimum=")[1].split()[0]


def test_solve_cli_accepts_ds_pallas(monkeypatch, capsys):
    """The stale fail-fast is gone: a ds Pallas solve runs end-to-end and
    prints the same optimum as the jnp backend."""
    args = ["--problem", "ds", "--instance", "gnp:10:30:4", "--lanes", "4",
            "--steps-per-round", "16"]
    run_main(args + ["--backend", "pallas"], monkeypatch)
    out_pallas = capsys.readouterr().out
    run_main(args + ["--backend", "jnp"], monkeypatch)
    out_jnp = capsys.readouterr().out
    assert optimum_of(out_pallas) == optimum_of(out_jnp)


def test_solve_cli_subset_sum_end_to_end(monkeypatch, capsys):
    """ISSUE 4 satellite: subset sum is a registration, not a plumbing
    project — ``--problem ss`` works end-to-end with no launcher edits and
    its optimum matches the registered serial oracle."""
    run_main(["--problem", "ss", "--instance", "ss:12:3", "--lanes", "4",
              "--steps-per-round", "16"], monkeypatch)
    out = capsys.readouterr().out
    handle = registry.problem("ss", "ss:12:3")
    assert int(optimum_of(out)) == Solver().oracle(handle).best


def test_solve_cli_rejects_unsupported_backend(monkeypatch):
    """A family that does not register pallas still fails fast, with the
    registered capability list in the error message."""
    spec = registry.get("ds")
    jnp_only = dataclasses.replace(
        spec, backends=("jnp",),
        builder=lambda *a, **k: pytest.fail(
            "factory must not be called on a rejected run"))
    monkeypatch.setitem(registry._REGISTRY, "ds", jnp_only)
    with pytest.raises(SystemExit):
        run_main(["--problem", "ds", "--instance", "gnp:10:30:4",
                  "--backend", "pallas"], monkeypatch)


def test_solve_cli_rejects_bad_instance_spec(monkeypatch):
    """Instance-spec errors surface as argparse errors, not tracebacks."""
    with pytest.raises(SystemExit):
        run_main(["--problem", "vc", "--instance", "bogus:1:2"],
                 monkeypatch)
    with pytest.raises(SystemExit):
        run_main(["--problem", "ss", "--instance", "reg:10:4:1"],
                 monkeypatch)
