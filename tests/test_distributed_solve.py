"""Cross-device steal-round tests, run in a subprocess with 8 host devices.

jax locks the platform device count at first init, and the rest of the suite
must see ONE device (per the harness rules), so the mesh tests re-exec a
pristine interpreter with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import json
import os
import subprocess
import sys

import pytest


pytestmark = pytest.mark.slow      # 8-device subprocess mesh solve: full CI on main only
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.core.distributed import solve
from repro.core.serial import serial_rb
from repro.problems import (
    gnp_graph, make_vertex_cover, make_vertex_cover_py,
    make_dominating_set, make_dominating_set_py,
)

assert len(jax.devices()) == 8, jax.devices()

out = {}

# 2-D mesh (the production-mesh shape in miniature: data x model).
mesh = jax.make_mesh((2, 4), ("data", "model"))

g = gnp_graph(16, 0.35, seed=5)
serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
payload, stats, _ = solve(make_vertex_cover(g), num_lanes=4,
                          steps_per_round=32, mesh=mesh,
                          bootstrap_rounds=3, bootstrap_steps=4)
out["vc_best"] = stats.best
out["vc_serial"] = serial_best
out["vc_ts"] = stats.t_s
out["vc_tr"] = stats.t_r
out["vc_lanes"] = stats.lanes
out["vc_cover_size"] = int(np.bitwise_count(np.asarray(payload)).sum())

g2 = gnp_graph(12, 0.3, seed=9)
ds_serial, _, _ = serial_rb(make_dominating_set_py(g2))
_, ds_stats, _ = solve(make_dominating_set(g2), num_lanes=2,
                       steps_per_round=32, mesh=mesh,
                       bootstrap_rounds=3, bootstrap_steps=4)
out["ds_best"] = ds_stats.best
out["ds_serial"] = ds_serial

# 1-D mesh sanity (flat worker pool).
mesh1 = jax.make_mesh((8,), ("workers",))
_, stats1, _ = solve(make_vertex_cover(g), num_lanes=2,
                     steps_per_round=32, mesh=mesh1,
                     bootstrap_rounds=3, bootstrap_steps=4)
out["vc1_best"] = stats1.best

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_vc_optimum(mesh_result):
    assert mesh_result["vc_best"] == mesh_result["vc_serial"]


def test_mesh_vc_payload_is_cover_of_right_size(mesh_result):
    assert mesh_result["vc_cover_size"] == mesh_result["vc_serial"]


def test_mesh_lane_pool_spans_devices(mesh_result):
    assert mesh_result["vc_lanes"] == 8 * 4     # 8 devices x 4 lanes


def test_mesh_ts_le_tr(mesh_result):
    assert mesh_result["vc_ts"] <= mesh_result["vc_tr"] + 1


def test_mesh_ds_optimum(mesh_result):
    assert mesh_result["ds_best"] == mesh_result["ds_serial"]


def test_flat_mesh_optimum(mesh_result):
    assert mesh_result["vc1_best"] == mesh_result["vc_serial"]
