"""Randomized differential soak harness for the sharded service (§9).

One seeded random request trace is replayed on three legs:

  serial    SERIAL-RB per instance (ground-truth optima and tree sizes;
            computed while the trace is generated — rejection sampling
            needs the tree sizes anyway);
  1-device  the ticketed service on one device, with mid-flight
            W' != W lane-pool resizes;
  mesh      the service sharded over a forced host-device mesh
            (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) with
            mid-flight device-count resizes (even seeds) or the
            queue-depth autoscaler growing the mesh from one device
            (odd seeds).

A trace mixes vc/ds instances, priorities, deadline and node-budget
evictions, and queued/running cancellations.  Each request carries a
*role* whose terminal status is deterministic BY CONSTRUCTION, so the
legs must agree exactly:

  done            small instance, no limits -> DONE, optimum == serial;
  budget          node_budget=1, big tree   -> EXPIRED at the end of its
                  first running round (>= 1 node used, cannot finish);
  deadline        deadline_rounds=1, big tree -> EXPIRED at the first
                  step after submission, queued or running;
  cancel_queued   cancelled right after submit -> CANCELLED;
  cancel_running  cancelled at first observed RUNNING -> CANCELLED.

The determinism hinges on one engine fact: an instance's admission
round expands ONLY its seed lane (idle retargeted lanes hold no stack
until the steal phase at the round's end), at most ``steps_per_round``
nodes — so "big tree" instances (rejection-sampled to ``MIN_TREE``
serial nodes) cannot finish before their eviction/cancellation lands,
on any lane count or mesh shape.

Per leg the harness also asserts ticket conservation (every submitted
rid reaches exactly one terminal event, nothing rejected, nothing
double-retired) and runs ``tools/trace_report.py``'s ledger checks over
the service trace (per-lane == per-instance == total node sums, which
the resize carried-counter convention must preserve).

CLI (the CI soak-smoke job; must start a FRESH process so the forced
device count lands before jax initializes):

  python tests/soak.py --seeds 0,1 --devices 4
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import random
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent

STEPS = 8            # steps_per_round for both service legs
SLOTS = 3            # instance slots for both service legs
LANES_1DEV = 8       # 1-device leg lane pool
LANES_PER_DEV = 4    # mesh leg lanes PER DEVICE
MAX_N = 24           # constant padding -> jit cache shared across seeds
MIN_TREE = 4 * STEPS  # limit-role instances must exceed this serially
N_REQUESTS = 10

ROLES = ("done", "budget", "deadline", "cancel_queued", "cancel_running")
_ROLE_WEIGHTS = (5, 1, 1, 1, 2)
#: role -> the terminal RequestResult/TicketStatus every leg must reach.
EXPECTED = {"done": "done", "budget": "expired", "deadline": "expired",
            "cancel_queued": "cancelled", "cancel_running": "cancelled"}


def _sample_instance(rng: random.Random, big: bool) -> dict:
    """One random graph instance; ``big`` rejection-samples until the
    serial tree is deep enough to outlive a single seed-lane round."""
    from repro import registry
    from repro.core.serial import serial_rb
    from repro.problems import gnp_graph

    while True:
        family = rng.choice(("vc", "ds"))
        if big:
            n, p = rng.randrange(18, 23), rng.choice((35, 45))
        else:
            n, p = rng.randrange(10, 15), rng.choice((25, 30, 35))
        gseed = rng.randrange(10 ** 6)
        graph = gnp_graph(n, p / 100.0, seed=gseed)
        best, nodes, _ = serial_rb(registry.problem(family, graph).oracle())
        if not big or nodes >= MIN_TREE:
            return {"family": family, "n": n, "p": p, "gseed": gseed,
                    "serial_best": int(best), "serial_nodes": int(nodes)}


def make_trace(seed: int, n_requests: int = N_REQUESTS) -> dict:
    """Seeded random trace: requests with roles + an op script of submit
    waves, stepping, and two resize points.  The first four rids cover
    one of each event class so EVERY trace exercises cancels and (via
    the op script) elastic resharding."""
    rng = random.Random(seed)
    forced = ["done", "cancel_queued", "cancel_running",
              rng.choice(("budget", "deadline"))]
    reqs = []
    for rid in range(n_requests):
        role = (forced[rid] if rid < len(forced)
                else rng.choices(ROLES, weights=_ROLE_WEIGHTS)[0])
        req = dict(_sample_instance(rng, big=role != "done"),
                   rid=rid, role=role, priority=rng.randrange(4))
        if role == "budget":
            req["node_budget"] = 1
        elif role == "deadline":
            req["deadline_rounds"] = 1
        reqs.append(req)

    ops, i, resizes = [], 0, 0
    while i < len(reqs):
        wave = min(len(reqs) - i, rng.randrange(2, 6))
        for req in reqs[i:i + wave]:
            ops.append(("submit", req))
        i += wave
        ops.append(("step", rng.randrange(1, 4)))
        if resizes < 2 and rng.random() < 0.5:
            ops.append(("resize", resizes))
            resizes += 1
    while resizes < 2:           # always two elastic events per trace
        ops.append(("resize", resizes))
        ops.append(("step", 1))
        resizes += 1
    return {"seed": seed, "reqs": reqs, "ops": ops}


def run_service_leg(trace: dict, *, devices: int, lanes: int,
                    resize_plan, trace_path: str,
                    autoscale_to: int = 0) -> tuple:
    """Replay ``trace`` on one service configuration.

    ``resize_plan`` maps the trace's resize ops to (devices, per-device
    lanes-or-None) targets; with ``autoscale_to`` set the plan is
    ignored and the queue-depth :class:`AutoscalePolicy` drives the mesh
    instead.  Returns ({rid: {"status", "optimum"}}, info-dict) after
    asserting ticket conservation.
    """
    import jax

    from repro.problems import gnp_graph
    from repro.service import SolveRequest
    from repro.service.scheduler import AutoscalePolicy
    from repro.service.ticket import TERMINAL, TicketStatus
    from repro.solver import Solver, SolverConfig

    def make_mesh(n_dev):
        return (jax.make_mesh((n_dev,), ("workers",),
                              devices=jax.devices()[:n_dev])
                if n_dev > 1 else None)

    cfg = SolverConfig(
        lanes=lanes, steps_per_round=STEPS, mesh=make_mesh(devices),
        autoscale=(AutoscalePolicy(grow_at=1, max_devices=autoscale_to,
                                   cooldown_rounds=1)
                   if autoscale_to > 1 else None),
        trace_path=trace_path)
    svc = Solver(cfg).serve(max_n=MAX_N, slots=SLOTS)
    events = []
    svc.on_event = events.append
    tickets, watch = {}, set()    # watch: cancel_running rids still live

    def poll():
        for rid in sorted(watch):
            ticket = tickets[rid]
            if ticket.status is TicketStatus.RUNNING:
                ticket.cancel()
                watch.discard(rid)
            elif ticket.status in TERMINAL:
                watch.discard(rid)

    def step():
        if svc._has_work():
            svc.step_round()
            poll()

    for op in trace["ops"]:
        if op[0] == "submit":
            req = op[1]
            tickets[req["rid"]] = svc.submit(SolveRequest(
                rid=req["rid"], family=req["family"],
                graph=gnp_graph(req["n"], req["p"] / 100.0,
                                seed=req["gseed"]),
                priority=req["priority"],
                deadline_rounds=req.get("deadline_rounds"),
                node_budget=req.get("node_budget")))
            if req["role"] == "cancel_queued":
                assert tickets[req["rid"]].cancel()
            elif req["role"] == "cancel_running":
                watch.add(req["rid"])
        elif op[0] == "step":
            for _ in range(op[1]):
                step()
        elif op[0] == "resize" and not autoscale_to:
            n_dev, per_dev = resize_plan[op[1]]
            svc.resize(mesh=make_mesh(n_dev), num_lanes=per_dev)
    while svc._has_work():
        step()
    svc.finalize_trace()

    # Ticket conservation: exactly one terminal event per rid, nothing
    # rejected, every ticket terminal.
    terminal = {}
    for ev in events:
        assert ev.kind != "reject", f"unexpected reject: {ev}"
        if ev.kind in ("retire", "expire", "cancel"):
            terminal.setdefault(ev.rid, []).append(ev.kind)
    for req in trace["reqs"]:
        kinds = terminal.get(req["rid"], [])
        assert len(kinds) == 1, (
            f"rid {req['rid']} saw terminal events {kinds}, want exactly 1")
        assert tickets[req["rid"]].status in TERMINAL, (
            f"rid {req['rid']} never resolved: {tickets[req['rid']].status}")
    assert set(terminal) == {req["rid"] for req in trace["reqs"]}

    out = {}
    for req in trace["reqs"]:
        ticket = tickets[req["rid"]]
        result = svc.results.get(req["rid"])
        out[req["rid"]] = {
            "status": ticket.status.value,
            "optimum": (int(result.optimum)
                        if result is not None
                        and ticket.status is TicketStatus.DONE else None)}
    import numpy as np
    info = {"rounds": svc.rounds, "devices_final": svc.n_devices,
            "resizes": sum(1 for ev in events if ev.kind == "resize"),
            "cross_steals": int(np.asarray(svc.lanes.t_c).sum())}
    return out, info


def check_ledger(trace_path: str) -> dict:
    """tools/trace_report.py's full consistency pass (raises TraceError
    on any per-lane / per-instance / total node-count mismatch)."""
    sys.path.insert(0, str(ROOT / "tools"))
    import trace_report

    from repro.obs.trace import read_trace
    return trace_report.analyze(read_trace(trace_path))


def run_soak(seed: int, devices: int = 4) -> dict:
    """The three-leg differential run for one seed; raises on any
    disagreement, returns a summary dict."""
    trace = make_trace(seed)
    with tempfile.TemporaryDirectory() as td:
        one_path = os.path.join(td, "one.jsonl")
        mesh_path = os.path.join(td, "mesh.jsonl")
        one, one_info = run_service_leg(
            trace, devices=1, lanes=LANES_1DEV,
            resize_plan=[(1, LANES_1DEV + 4), (1, LANES_1DEV)],
            trace_path=one_path)
        autoscale_to = devices if seed % 2 else 0
        mesh, mesh_info = run_service_leg(
            trace, devices=1 if autoscale_to else devices,
            lanes=LANES_PER_DEV,
            resize_plan=[(max(2, devices // 2), None), (devices, None)],
            trace_path=mesh_path, autoscale_to=autoscale_to)
        assert one_info["resizes"] == 2, one_info
        if not autoscale_to:
            assert mesh_info["resizes"] == 2, mesh_info
        ledgers = {"one": check_ledger(one_path),
                   "mesh": check_ledger(mesh_path)}

    serial = {req["rid"]: req for req in trace["reqs"]}
    for rid, req in serial.items():
        want = EXPECTED[req["role"]]
        for leg, got in (("1dev", one), ("mesh", mesh)):
            assert got[rid]["status"] == want, (
                f"seed {seed} rid {rid} role {req['role']}: {leg} leg "
                f"ended {got[rid]['status']!r}, want {want!r}")
            if want == "done":
                assert got[rid]["optimum"] == req["serial_best"], (
                    f"seed {seed} rid {rid}: {leg} optimum "
                    f"{got[rid]['optimum']} != serial {req['serial_best']}")
    assert one == mesh, f"seed {seed}: legs disagree\n1dev={one}\nmesh={mesh}"

    return {"seed": seed, "requests": len(serial),
            "statuses": {rid: one[rid]["status"] for rid in sorted(one)},
            "one": one_info, "mesh": mesh_info,
            "nodes": {leg: ledgers[leg]["nodes"] for leg in ledgers}}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seeds", default="0",
                    help="comma-separated trace seeds (default: 0)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count for the mesh leg")
    args = ap.parse_args(argv)
    # Must land before jax initializes — hence a fresh process per run.
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    sys.path.insert(0, str(ROOT / "src"))
    seeds = [int(s) for s in args.seeds.split(",") if s.strip()]
    for seed in seeds:
        summary = run_soak(seed, devices=args.devices)
        print("RESULT " + json.dumps(summary))
    print(f"SOAK_OK seeds={seeds}")


if __name__ == "__main__":
    main()
