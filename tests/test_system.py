"""End-to-end system tests: examples run, launchers run, serving path
agrees with training forward, dry-run machinery works in miniature."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_script(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable] + args, env=env, cwd=REPO,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    return proc.stdout


def test_quickstart_example():
    out = run_script(["examples/quickstart.py"])
    assert "optimum matches the serial oracle" in out


@pytest.mark.slow
def test_guided_decode_example():
    out = run_script(["examples/guided_decode.py"])
    assert "same optimum" in out


@pytest.mark.slow
def test_train_lm_example_short():
    out = run_script(["examples/train_lm.py", "--steps", "40",
                      "--batch", "4", "--seq", "128"])
    assert "improved" in out


def test_solver_cli_with_checkpoint(tmp_path):
    ck = str(tmp_path / "s.ckpt")
    out = run_script(["-m", "repro.launch.solve", "--problem", "vc",
                      "--instance", "gnp:20:30:5", "--lanes", "8",
                      "--ckpt", ck])
    assert "optimum=" in out


def test_solver_cli_ds_pallas_solves():
    """--backend pallas with --problem ds used to fail fast (ds had no
    kernel path); since the bitset_ops layer (DESIGN.md §5) it must solve —
    the capability check is factory-driven (tests/test_launch_cli.py covers
    the rejection path for jnp-only factories)."""
    out = run_script(["-m", "repro.launch.solve", "--problem", "ds",
                      "--backend", "pallas", "--instance", "gnp:10:30:1",
                      "--lanes", "4", "--steps-per-round", "16"])
    assert "optimum=" in out


def test_serve_solver_cli_smoke():
    out = run_script(["-m", "repro.launch.serve_solver",
                      "--instances", "vc:gnp:12:30:5,ds:gnp:10:30:7",
                      "--lanes", "8", "--slots", "2",
                      "--steps-per-round", "16"])
    assert "drained 2 requests" in out


@pytest.mark.slow
def test_serve_cli_smoke():
    out = run_script(["-m", "repro.launch.serve", "--arch", "qwen2-7b",
                      "--smoke", "--batch", "2", "--prompt-len", "16",
                      "--gen", "4"])
    assert "decoded 4 tokens" in out


@pytest.mark.slow
def test_kv_quant_matches_bf16_decode():
    """int8 KV cache must produce near-identical decode logits on the
    smoke model (quantization noise small vs logit scale)."""
    from repro import configs
    from repro.models import model as M
    from repro.serve.engine import make_decode_step, make_prefill_step

    cfg = configs.smoke("qwen2-7b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    outs = {}
    for quant in (False, True):
        prefill = make_prefill_step(cfg, block_q=8, block_k=8,
                                    kv_quant=quant)
        decode = make_decode_step(cfg, kv_quant=quant)
        logits, cache = prefill(params, {"tokens": toks[:, :16]})
        cache = M.pad_cache(cfg, cache, 24)
        seq = []
        for i in range(4):
            pos = jnp.int32(16 + i)
            logits, cache = decode(params, cache, toks[:, 16 + i:17 + i],
                                   pos)
            seq.append(np.asarray(logits, np.float32))
        outs[quant] = np.stack(seq)
    np.testing.assert_allclose(outs[False], outs[True], rtol=0.15,
                               atol=0.15)


@pytest.mark.slow
def test_dryrun_cell_miniature():
    """The dry-run module end-to-end on one cheap cell (subprocess: the
    512-device flag must precede jax init)."""
    out = run_script(["-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
                      "--shape", "decode_32k"], timeout=900)
    assert "[ok]" in out and "dry-run: 1 ok" in out
