"""Randomized differential soak-test of the sharded service (§9).

The harness lives in ``tests/soak.py`` (also the CI soak-smoke CLI);
these tests drive it through a fresh subprocess per seed batch because
the mesh leg forces ``--xla_force_host_platform_device_count`` via
XLA_FLAGS, which must land before jax initializes.

Per seed the harness replays one random request trace — mixed vc/ds,
priorities, deadline/node-budget evictions, queued and running
cancellations, and two elastic W' != W resizes (explicit on even seeds,
queue-depth autoscaler on odd) — on a serial oracle, a 1-device
service, and a mesh-sharded service, and asserts all three agree on
every terminal status and optimum, no ticket is lost or double-retired,
and both service traces reconcile under tools/trace_report.py.
"""

import json
import os
import pathlib
import subprocess
import sys

import pytest

_SOAK = str(pathlib.Path(__file__).resolve().parent / "soak.py")
#: 20 seeds (the acceptance floor), batched so one subprocess amortizes
#: jit compilation across its seeds while the suite stays parallelizable.
_BATCHES = [tuple(range(i, i + 5)) for i in range(0, 20, 5)]


def _run_soak(seeds, devices=4):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # the harness forces its own count
    env.pop("PYTHONPATH", None)     # soak.py inserts src/ itself
    proc = subprocess.run(
        [sys.executable, _SOAK, "--seeds", ",".join(map(str, seeds)),
         "--devices", str(devices)],
        env=env, capture_output=True, text=True, timeout=3000)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-6000:])
    results = [json.loads(line[len("RESULT "):])
               for line in proc.stdout.splitlines()
               if line.startswith("RESULT ")]
    assert [r["seed"] for r in results] == list(seeds)
    return results


@pytest.mark.slow
@pytest.mark.parametrize("seeds", _BATCHES, ids=lambda s: f"{s[0]}-{s[-1]}")
def test_soak_differential(seeds):
    """Each seed's three legs agree; the mesh legs collectively steal
    across devices (every trace's own invariants are asserted inside
    the harness — a clean exit IS the differential verdict)."""
    results = _run_soak(seeds)
    # Sharding must actually engage somewhere in the batch: at least one
    # mesh leg crossed device boundaries while agreeing with the oracle.
    assert any(r["mesh"]["cross_steals"] > 0 for r in results), results
    # Elastic events happened and the ledgers still reconciled.
    assert all(r["one"]["resizes"] == 2 for r in results), results


def test_trace_generation_is_deterministic():
    """make_trace(seed) is pure: identical ops and request specs across
    calls — the property the three-leg comparison rests on.  (In-process
    and device-count independent: trace generation uses the serial
    oracle only.)"""
    sys.path.insert(0, str(pathlib.Path(_SOAK).parent))
    import soak

    a, b = soak.make_trace(101), soak.make_trace(101)
    assert a == b
    roles = [r["role"] for r in a["reqs"]]
    assert roles[:3] == ["done", "cancel_queued", "cancel_running"]
    assert roles[3] in ("budget", "deadline")
    assert sum(1 for op in a["ops"] if op[0] == "resize") == 2
    for req in a["reqs"]:
        if req["role"] != "done":
            assert req["serial_nodes"] >= soak.MIN_TREE
