"""Fault-tolerance & load-balance posture tests.

* Straggler mitigation IS the paper's contribution: when one lane starts
  with all the work (maximal skew), steal rounds must spread it — the
  node count processed by the initially-idle lanes must dominate.
* Elastic training restore: a checkpoint written under one mesh must
  restore under a different device count with different shardings.
* Serving driver: batched lockstep decode equals unbatched decoding.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _legacy import legacy_solve as solve
from repro.core.serial import serial_rb
from repro.problems import (make_vertex_cover, make_vertex_cover_py,
                            random_regularish_graph)


def test_steal_rounds_spread_skewed_work():
    """All work starts on lane 0 (the paper's initialization); after the
    solve, the other lanes must have done the large majority of the node
    expansions — the implicit load balancer working."""
    g = random_regularish_graph(40, 4, seed=1)
    prob = make_vertex_cover(g)
    serial_best, serial_nodes, _ = serial_rb(make_vertex_cover_py(g))
    _, stats, lanes = solve(prob, num_lanes=16, steps_per_round=32,
                            bootstrap_rounds=4, bootstrap_steps=4)
    assert stats.best == serial_best
    per_lane = np.asarray(lanes.nodes)
    assert per_lane.sum() >= serial_nodes * 0.5
    # lane 0 must NOT have done most of the work
    assert per_lane[0] < per_lane.sum() * 0.5
    # at least half the lanes participated
    assert (per_lane > 0).sum() >= 8


def test_solver_checkpoint_is_tiny():
    """Paper §VII: solver state is O(W * D_MAX) int8 — verify the
    checkpoint for 64 lanes on a 40-vertex problem is a few KB, not a
    graph copy per lane."""
    import tempfile
    from repro.core import checkpoint as ckpt
    from repro.core.engine import init_lanes, make_expand
    g = random_regularish_graph(40, 4, seed=1)
    prob = make_vertex_cover(g)
    lanes = init_lanes(prob, 64)
    lanes = make_expand(prob, 50)(lanes)
    path = os.path.join(tempfile.mkdtemp(), "s.ckpt")
    ckpt.save(path, lanes)
    assert os.path.getsize(path) < 64 * 1024     # < 64 KB for 64 lanes


_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import model as M
from repro.train.checkpoint import save, restore
from repro.train.optim import adamw_init
from repro.train.step import master_params

cfg = configs.smoke("qwen2-7b")
params = master_params(cfg, M.init(cfg, jax.random.PRNGKey(0)))
opt = adamw_init(params)

# place under an 8-device mesh, checkpoint
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
specs = M.specs(cfg, mesh8.axis_names, M.mesh_axis_sizes(mesh8))
sh8 = jax.tree_util.tree_map(lambda s: NamedSharding(mesh8, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
params8 = jax.tree_util.tree_map(jax.device_put, params, sh8)
save("/tmp/elastic.ckpt", params8, opt, step=5)

# restore under a DIFFERENT mesh (2x2 = "shrunk cluster")
mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                      devices=jax.devices()[:4])
specs4 = M.specs(cfg, mesh4.axis_names, M.mesh_axis_sizes(mesh4))
sh4 = jax.tree_util.tree_map(lambda s: NamedSharding(mesh4, s), specs4,
                             is_leaf=lambda x: isinstance(x, P))
opt_sh4 = type(opt)(m=sh4, v=sh4)
p4, o4, step = restore("/tmp/elastic.ckpt", params, opt,
                       shardings=(sh4, opt_sh4))
assert step == 5
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(p4)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_train_restore_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _ELASTIC], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout


@pytest.mark.slow
def test_batched_server_matches_reference():
    from repro import configs
    from repro.models import model as M
    from repro.serve.driver import BatchedServer, Request

    cfg = configs.smoke("glm4-9b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    plen, n_new = 12, 5
    key = jax.random.PRNGKey(2)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                             (plen,), 0, cfg.vocab))
               for i in range(3)]
    reqs = [Request(rid=i, prompt=p, max_new=n_new)
            for i, p in enumerate(prompts)]
    server = BatchedServer(cfg, params, batch_slots=2,
                           max_seq=plen + n_new + 1, block=4)
    server.run(reqs)
    assert all(len(r.out) == n_new for r in reqs)

    # unbatched reference for request 0
    from repro.serve.engine import (greedy_sample, make_decode_step,
                                    make_prefill_step)
    prefill = make_prefill_step(cfg, block_q=4, block_k=4)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts[0])[None]})
    cache = M.pad_cache(cfg, cache, plen + n_new + 1)
    tok = greedy_sample(logits).reshape(1, 1)
    ref = []
    pos = plen
    for _ in range(n_new):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = greedy_sample(logits).reshape(1, 1)
        ref.append(int(tok[0, 0]))
        pos += 1
    assert reqs[0].out == ref


_MESH_SERVICE_KILL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, random
import jax
import numpy as np
from repro import registry
from repro.problems import gnp_graph
from repro.service import SolveRequest, SolverService
from repro.solver import Solver, SolverConfig

SEED = int(os.environ.get("MESH_KILL_SEED", "7"))
rng = random.Random(SEED)


def mesh_of(d):
    return (jax.make_mesh((d,), ("workers",), devices=jax.devices()[:d])
            if d > 1 else None)


graphs = [(rng.choice(("vc", "ds")), gnp_graph(rng.randrange(14, 19),
                                               rng.choice((30, 40)) / 100.0,
                                               seed=rng.randrange(10 ** 6)))
          for _ in range(6)]
want = {i: Solver().oracle(registry.problem(fam, g)).best
        for i, (fam, g) in enumerate(graphs)}

# Service A: 4 devices x 4 lanes; kill it at a random early round.
svc = Solver(SolverConfig(lanes=4, steps_per_round=4, mesh=mesh_of(4))
             ).serve(max_n=20, slots=2)
for i, (fam, g) in enumerate(graphs):
    svc.submit(SolveRequest(rid=i, graph=g, family=fam))
# Random kill round, but only once stealing has spread the work past
# the restore capacity (2 lanes) — the W' != W surplus precondition.
extra = rng.randrange(0, 3)
kill_at = 0
while svc._has_work():
    svc.step_round()
    kill_at += 1
    live = int(np.asarray(svc.lanes.active).sum())
    if live > 2 and extra == 0:
        break
    if live > 2:
        extra -= 1
    assert kill_at < 80, "work never spread past 2 lanes"
svc.save("/tmp/mesh_service_kill.ckpt")
live = int(np.asarray(svc.lanes.active).sum())
del svc        # the "kill": nothing of service A survives but the file

# Service B: a DIFFERENT, smaller mesh (W' != W) — more checkpointed
# live tasks than the 2x1=2 new lanes, so the pending pool MUST be
# non-empty right after restore while queued requests also survive.
svc2 = SolverService.restore("/tmp/mesh_service_kill.ckpt", num_lanes=1,
                             steps_per_round=4, mesh=mesh_of(2))
pool_after = len(svc2.pool)
queue_after = len(svc2.queue)
res = svc2.drain()
got = {i: int(res[i].optimum) for i in want}
print("RESULT " + json.dumps({
    "kill_at": kill_at, "live_at_kill": live, "pool_after": pool_after,
    "queue_after": queue_after, "devices": svc2.n_devices,
    "ok": got == want, "got": got, "want": want}))
"""


@pytest.mark.slow
def test_mesh_service_kill_restore_elastic():
    """Kill a 4-device sharded service at a random round mid-run and
    restore it onto a 2-device mesh with fewer total lanes (W' != W):
    the surplus in-flight subtrees must park in the pending pool (it is
    asserted NON-empty — the elastic path actually engaged) and the
    drained optima must still match the serial oracle for every tenant.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env["MESH_KILL_SEED"] = "7"
    proc = subprocess.run([sys.executable, "-c", _MESH_SERVICE_KILL],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    import json
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["ok"], res
    assert res["pool_after"] > 0, res      # W' != W really shed work
    assert res["devices"] == 2, res
