"""Training-substrate tests: optimizer, data determinism, microbatching,
gradient compression, pipeline parallelism, training checkpoints."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # shim: see _hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.train.checkpoint import restore as t_restore, save as t_save
from repro.train.optim import adamw_init, adamw_update, cosine_lr
from repro.train.step import make_train_step, master_params



pytestmark = pytest.mark.slow      # LM training-substrate tests: full CI on main only
def test_data_pipeline_determinism():
    cfg = configs.smoke("qwen2-7b")
    b1 = synthetic_batch(cfg, 4, 32, seed=7, step=jnp.int32(13))
    b2 = synthetic_batch(cfg, 4, 32, seed=7, step=jnp.int32(13))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_batch(cfg, 4, 32, seed=7, step=jnp.int32(14))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = configs.smoke("qwen2-7b")
    b = synthetic_batch(cfg, 2, 16, seed=3, step=jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(b["tokens"])[:, 1:],
                                  np.asarray(b["labels"])[:, :-1])


@given(st.integers(0, 10_000))
@settings(deadline=None, max_examples=20)
def test_cosine_lr_bounds(step):
    lr = float(cosine_lr(jnp.int32(step), peak=1e-3, warmup=100,
                         total=10_000))
    assert 0.0 <= lr <= 1e-3 + 1e-9


def test_adamw_moves_toward_minimum():
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(p)
    for s in range(200):
        g = {"w": 2 * p["w"]}               # d/dw of w^2
        p, opt = adamw_update(p, g, opt, jnp.int32(s + 1), lr=5e-2,
                              weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_microbatching_matches_full_batch():
    """Grad accumulation over M microbatches == one big batch (linearity
    of gradients; losses averaged)."""
    cfg = configs.smoke("qwen2-7b")
    params = master_params(cfg, M.init(cfg, jax.random.PRNGKey(0)))
    opt = adamw_init(params)
    batch = synthetic_batch(cfg, 8, 32, seed=5, step=jnp.int32(0))
    outs = {}
    for nmb in (1, 4):
        step = make_train_step(cfg, mesh=None, microbatches=nmb,
                               block_q=16, block_k=16)
        p2, _, metrics = step(params, opt, batch, jnp.int32(1))
        outs[nmb] = (float(metrics["loss"]),
                     np.asarray(jax.tree_util.tree_leaves(p2)[0],
                                np.float32))
    assert abs(outs[1][0] - outs[4][0]) < 5e-3
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-2,
                               atol=2e-3)


def test_train_checkpoint_roundtrip(tmp_path):
    cfg = configs.smoke("mamba2-130m")
    params = master_params(cfg, M.init(cfg, jax.random.PRNGKey(0)))
    opt = adamw_init(params)
    path = str(tmp_path / "t.ckpt")
    t_save(path, params, opt, step=17)
    p2, o2, step = t_restore(path, params, opt)
    assert step == 17
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

# --- gradient compression: int8 psum with error feedback ---------------
from repro.train.compression import compressed_psum
mesh = jax.make_mesh((8,), ("data",))

def body(g):
    out, err = compressed_psum({"g": g}, ("data",))
    return out["g"], err["g"]

from repro.compat import shard_map
fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                       out_specs=(P("data"), P("data")),
                       check=False))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
mean, err = fn(g)
true_mean = jnp.mean(g, axis=0)
# int8 quantization: per-worker error <= scale/2; mean error small.
scale = float(jnp.max(jnp.abs(g))) / 127.0
got = np.asarray(mean)
assert np.max(np.abs(got - np.asarray(true_mean)[None, :])) <= scale, (
    np.max(np.abs(got - np.asarray(true_mean)[None, :])), scale)
# error feedback residual = g - q*scale (bounded by scale/2 per element)
assert float(jnp.max(jnp.abs(err))) <= scale * 0.51 + 1e-9
print("COMPRESSION_OK")

# --- pipeline parallelism: 4 stages x identity-ish stages --------------
from repro.distributed.pipeline_parallel import pipeline_forward
mesh2 = jax.make_mesh((4,), ("stage",))
S, M_, mb, d = 4, 6, 2, 16
ws = jax.random.normal(jax.random.PRNGKey(1), (S, d, d)) * 0.1 \
    + jnp.eye(d)[None]

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(2), (M_, mb, d))
out = pipeline_forward(stage_fn, ws, x, mesh2)
# reference: sequential application of the 4 stages
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("PIPELINE_OK")
"""


@pytest.mark.parametrize("marker", ["COMPRESSION_OK", "PIPELINE_OK"])
def test_multidevice_substrate(marker, multidev_output):
    assert marker in multidev_output


@pytest.fixture(scope="module")
def multidev_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout
