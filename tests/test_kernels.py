"""Pallas kernel validation: interpret=True vs pure-jnp oracles.

Per the harness rules each kernel is swept over shapes/dtypes and
assert_allclose'd against its ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bitset_degree import degree_argmax, degree_stats
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.problems.graphs import gnp_graph


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, s, h, g, hd, window, softcap, dtype)
    (1, 256, 4, 4, 64, None, 0.0, jnp.float32),
    (2, 256, 4, 2, 64, None, 0.0, jnp.bfloat16),
    (1, 512, 8, 2, 64, None, 0.0, jnp.float32),
    (1, 256, 2, 1, 128, None, 0.0, jnp.float32),
    (2, 512, 4, 4, 64, 128, 0.0, jnp.float32),      # sliding window
    (1, 256, 4, 2, 64, None, 50.0, jnp.float32),    # softcap (gemma2)
    (1, 512, 4, 1, 64, 256, 30.0, jnp.bfloat16),    # window + softcap
]


@pytest.mark.parametrize("b,s,h,g,hd,window,softcap,dtype", ATTN_CASES)
def test_flash_attention_matches_ref(b, s, h, g, hd, window, softcap, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(k1, (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (b, s, g, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (b, s, g, hd)) * 0.5).astype(dtype)
    got = flash_attention(q, k, v, window=window, softcap=softcap,
                          block_q=128, block_k=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window, softcap=softcap,
                                   block_q=128, block_k=128)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_sweep():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (1, 512, 4, 64), jnp.float32) * 0.5
    k = jax.random.normal(k2, (1, 512, 2, 64), jnp.float32) * 0.5
    v = jax.random.normal(k3, (1, 512, 2, 64), jnp.float32) * 0.5
    want = ref.flash_attention_ref(q, k, v)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (256, 256),
                   (512, 512)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"bq={bq} bk={bk}")


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, h, p, g, n, chunk, dtype)
    (1, 128, 2, 64, 1, 64, 64, jnp.float32),
    (2, 256, 4, 64, 1, 128, 64, jnp.float32),
    (1, 256, 4, 64, 2, 64, 128, jnp.float32),
    (2, 128, 2, 32, 1, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,p,g,n,chunk,dtype", SSD_CASES)
def test_ssd_scan_matches_ref(b, s, h, p, g, n, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 5)
    x = (jax.random.normal(keys[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    bb = (jax.random.normal(keys[3], (b, s, g, n)) * 0.3).astype(dtype)
    cc = (jax.random.normal(keys[4], (b, s, g, n)) * 0.3).astype(dtype)
    d = jnp.ones((h,), jnp.float32)
    y, st = ssd_scan(x, dt, a, bb, cc, d, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x, dt, a, bb, cc, d, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=tol, atol=tol)


def test_ssd_state_continuity():
    """Final state from the kernel must continue a decode stream exactly."""
    from repro.models.ssm import ssd_decode_step
    b, s, h, p, g, n = 1, 128, 2, 32, 1, 32
    keys = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(keys[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    bb = jax.random.normal(keys[3], (b, s, g, n)) * 0.3
    cc = jax.random.normal(keys[4], (b, s, g, n)) * 0.3
    d = jnp.ones((h,), jnp.float32)
    _, st = ssd_scan(x, dt, a, bb, cc, d, chunk=64, interpret=True)
    # one more token via the decode step vs a longer chunked run
    xt = jax.random.normal(keys[5], (b, h, p)) * 0.5
    dt_t = jnp.full((b, h), 0.3)
    bt = jnp.ones((b, g, n)) * 0.1
    ct = jnp.ones((b, g, n)) * 0.1
    y_dec, st_dec = ssd_decode_step(st, xt, dt_t, a, bt, ct, d)
    x2 = jnp.concatenate([x, xt[:, None]], axis=1)
    dt2 = jnp.concatenate([dt, dt_t[:, None]], axis=1)
    b2 = jnp.concatenate([bb, bt[:, None]], axis=1)
    c2 = jnp.concatenate([cc, ct[:, None]], axis=1)
    y2, st2 = ref.ssd_scan_ref(x2, dt2, a, b2, c2, d, chunk=43)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y2[:, -1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_dec), np.asarray(st2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bitset degree/argmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,lanes,tile", [
    (60, 0.2, 4, 32), (200, 0.1, 8, 128), (300, 0.05, 2, 128),
    (128, 0.5, 16, 64),
])
def test_degree_argmax_matches_ref(n, p, lanes, tile):
    g = gnp_graph(n, p, seed=n)
    adj = jnp.asarray(g.adj)
    key = jax.random.PRNGKey(n)
    alive = jax.random.bernoulli(key, 0.7, (lanes, n))
    # pack alive masks
    w = adj.shape[1]
    masks = np.zeros((lanes, w), np.uint32)
    av = np.asarray(alive)
    for l in range(lanes):
        for v in range(n):
            if av[l, v]:
                masks[l, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    masks = jnp.asarray(masks)
    got = degree_argmax(adj, masks, tile=tile, interpret=True)
    want = ref.degree_argmax_ref(adj, masks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_degree_argmax_all_dead():
    g = gnp_graph(40, 0.3, seed=1)
    adj = jnp.asarray(g.adj)
    masks = jnp.zeros((3, adj.shape[1]), jnp.uint32)
    got = degree_argmax(adj, masks, interpret=True)
    assert (np.asarray(got)[:, 0] == -1).all()


def test_degree_argmax_tie_break_smallest_id():
    """4-regular circulant: every vertex ties; the pick must be vertex 0."""
    from repro.problems.graphs import circulant_graph, full_mask
    g = circulant_graph(96, (1, 7))
    adj = jnp.asarray(g.adj)
    alive = jnp.asarray(full_mask(g.n))[None, :]
    got = degree_argmax(adj, alive, tile=32, interpret=True)
    assert got[0, 0] == 4 and got[0, 1] == 0


@pytest.mark.parametrize("n,p,lanes,tile", [
    (60, 0.2, 4, 32), (200, 0.1, 8, 128), (300, 0.05, 2, 128),
    (128, 0.5, 16, 64),
])
def test_degree_stats_matches_ref(n, p, lanes, tile):
    """The fused (degree, argmax, degree-sum) triple behind vertex cover's
    single-pass evaluate (DESIGN.md §3) — exact match vs the jnp oracle."""
    g = gnp_graph(n, p, seed=n + 1)
    adj = jnp.asarray(g.adj)
    alive = jax.random.bernoulli(jax.random.PRNGKey(n + 1), 0.6, (lanes, n))
    w = adj.shape[1]
    masks = np.zeros((lanes, w), np.uint32)
    av = np.asarray(alive)
    for l in range(lanes):
        for v in range(n):
            if av[l, v]:
                masks[l, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    masks = jnp.asarray(masks)
    got = degree_stats(adj, masks, tile=tile, interpret=True)
    want = ref.degree_stats_ref(adj, masks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_degree_stats_all_dead_and_vmap():
    g = gnp_graph(40, 0.3, seed=2)
    adj = jnp.asarray(g.adj)
    masks = jnp.zeros((3, adj.shape[1]), jnp.uint32)
    got = np.asarray(degree_stats(adj, masks, interpret=True))
    np.testing.assert_array_equal(got, np.full((3, 3), [-1, -1, 0]))
    # vmap over lane masks (as the engine does) must match the flat call.
    from repro.problems.graphs import full_mask
    alive = jnp.tile(jnp.asarray(full_mask(g.n))[None, :], (4, 1))
    flat = degree_stats(adj, alive, tile=32, interpret=True)
    mapped = jax.vmap(
        lambda m: degree_stats(adj, m[None, :], tile=32, interpret=True)[0]
    )(alive)
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(flat))
