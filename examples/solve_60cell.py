"""The paper's hard case: a 4-regular graph whose regularity defeats
degree pruning (their 60-cell took a week serially).  Demonstrates:

  * near-linear lane scaling on a "sufficiently hard" instance,
  * checkpoint every K rounds (the paper's §VII claim: persist
    current_idx), kill, and ELASTIC restart on a different lane count,
  * the T_S/T_R accounting.

  PYTHONPATH=src python examples/solve_60cell.py
"""

import os
import tempfile
import time

from repro import registry
from repro.solver import Solver, SolverConfig


def main() -> None:
    problem = registry.problem("vc", "reg:48:4:1")   # 60-cell analogue
    graph = problem.instance
    print(f"instance: 4-regular-ish n={graph.n} m={graph.m}")

    for lanes in (4, 16, 64):
        t0 = time.time()
        cfg = SolverConfig(lanes=lanes, steps_per_round=64,
                           bootstrap_rounds=4, bootstrap_steps=8)
        stats = Solver(cfg).solve(problem).stats
        print(f"lanes={lanes:3d} optimum={stats.best} rounds={stats.rounds}"
              f" nodes={stats.nodes} T_S={stats.t_s} T_R={stats.t_r}"
              f" wall={time.time()-t0:.1f}s")

    # Checkpoint / elastic restart: run 5 rounds at 16 lanes, checkpoint,
    # then finish the search at 32 lanes from the persisted current_idx —
    # the lane count is config, the checkpoint is elastic.
    path = os.path.join(tempfile.mkdtemp(), "solver.ckpt")
    Solver(SolverConfig(lanes=16, steps_per_round=64, max_rounds=5,
                        bootstrap_rounds=2, checkpoint_every=1,
                        checkpoint_path=path)).solve(problem)
    print(f"checkpointed 16-lane run -> {path}")
    stats = Solver(SolverConfig(lanes=32, steps_per_round=64,
                                resume_from=path)).solve(problem).stats
    print(f"elastic restart at 32 lanes: optimum={stats.best} "
          f"(+{stats.rounds} rounds)")


if __name__ == "__main__":
    main()
