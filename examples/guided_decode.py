"""The paper's technique applied OUTSIDE graph problems: exact best-path
decoding over an LM's pruned token lattice as indexed-search-tree
backtracking.

Problem: find the exact highest-likelihood continuation of length D when
each step may choose one of the TOP-2 tokens (a binary search tree, depth
D).  Greedy decoding is the leftmost leaf; the optimum may differ (the
classic beam-search-vs-greedy gap).  The solver enumerates the lattice
with branch-and-bound: bound = achieved logprob + optimistic per-step
best, tasks are current_idx prefixes, lanes steal heaviest subtrees —
exactly the PARALLEL-RB machinery, problem-oblivious as promised (§I).

  PYTHONPATH=src python examples/guided_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serial import PyNodeEval, PyProblem, serial_rb
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.models.model import Shardings, make_ctx

CFG = ArchConfig(name="toy-lm", family="dense", n_layers=2, d_model=64,
                 vocab=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
                 remat="none")
DEPTH = 8
PROMPT_LEN = 8
SCALE = 1000        # logprob -> integer objective (the engine minimizes)


def build_lattice(seed: int = 0):
    """Precompute top-2 token ids + logprobs along every lattice node.

    For a toy depth the lattice is small (2^D leaves share prefixes =>
    2^(D+1) nodes); we score nodes lazily via memoized full forwards —
    the demonstration is the search layer, not serving throughput."""
    params = M.init(CFG, jax.random.PRNGKey(seed))
    ctx = make_ctx(CFG, "train", Shardings(None), block_q=16, block_k=16)
    prompt = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                (1, PROMPT_LEN), 0, CFG.vocab)

    @jax.jit
    def logits_at(tokens):
        return M.forward(CFG, params, {"tokens": tokens}, ctx)[0, -1]

    memo = {}

    def expand(prefix):
        """prefix: tuple of chosen token ids -> (top2 ids, logprobs)."""
        if prefix in memo:
            return memo[prefix]
        toks = jnp.concatenate(
            [prompt, jnp.asarray(prefix, jnp.int32)[None]], axis=1) \
            if prefix else prompt
        lg = jax.nn.log_softmax(logits_at(toks).astype(jnp.float32))
        v, i = jax.lax.top_k(lg, 2)
        out = (np.asarray(i), np.asarray(v))
        memo[prefix] = out
        return out

    return expand


def make_problem(expand):
    """State: (depth, prefix tokens, accumulated -logprob).

    Fused evaluate: one ``expand`` call yields the solution test, the bound
    and BOTH children in one pass (expand itself memoizes per prefix, so
    the LM forward runs once per lattice node either way — the point here
    is the protocol shape, not a forward-count saving).
    """

    def root():
        return (0, (), 0)

    def evaluate(state, best):
        d, prefix, cost = state
        if d >= DEPTH:              # leaf: children are never taken
            return PyNodeEval(True, cost, cost, state, state)
        ids, lps = expand(prefix)   # the one shared LM forward
        left = (d + 1, prefix + (int(ids[0]),), cost + int(-lps[0] * SCALE))
        right = (d + 1, prefix + (int(ids[1]),), cost + int(-lps[1] * SCALE))
        # bound: achieved cost (admissible — future steps cost >= 0)
        return PyNodeEval(False, cost, cost, left, right)

    return PyProblem(name="guided-decode", max_depth=DEPTH, root=root,
                     evaluate=evaluate)


def main() -> None:
    expand = build_lattice()
    prob = make_problem(expand)

    # Greedy = always take the left (top-1) branch.
    state = prob.root()
    for _ in range(DEPTH):
        state = prob.apply(state, 0)
    greedy_cost = state[2]
    print(f"greedy continuation: tokens={state[1]} "
          f"-logprob={greedy_cost/SCALE:.3f}")

    best, nodes, _ = serial_rb(prob)
    print(f"exact optimum: -logprob={best/SCALE:.3f} "
          f"(searched {nodes} lattice nodes, greedy gap "
          f"{(greedy_cost-best)/SCALE:.3f})")
    assert best <= greedy_cost

    from repro.core.serial import ParallelRBSimulator
    sim = ParallelRBSimulator(make_problem(expand), c=8).run()
    assert sim.best == best
    print(f"PARALLEL-RB x8: same optimum in {sim.makespan} ticks "
          f"(T_S={sim.avg_t_s:.1f}, T_R={sim.avg_t_r:.1f}) — "
          "the framework is oblivious to the problem being an LM lattice.")


if __name__ == "__main__":
    main()
