"""Quickstart: parallelize a recursive backtracking solver in ~20 lines.

The paper's promise is that migrating SERIAL-RB to parallel needs almost
no problem-specific code.  Here the full path: define a problem once
(Vertex Cover on a random graph), check it against the serial oracle, then
solve it with vectorized lanes + implicit heaviest-task load balancing.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.distributed import solve
from repro.core.serial import serial_rb
from repro.problems import (gnp_graph, make_vertex_cover,
                            make_vertex_cover_py)


def main() -> None:
    graph = gnp_graph(24, 0.25, seed=42)
    print(f"instance: G(n={graph.n}, m={graph.m})")

    # 1. The serial oracle (paper Fig. 1) — ground truth.
    best, nodes, _ = serial_rb(make_vertex_cover_py(graph))
    print(f"SERIAL-RB: optimum={best}, nodes={nodes}")

    # 2. The parallel engine: 16 vectorized lanes, steal rounds, implicit
    #    load balancing (no problem-specific knowledge, no task buffers).
    cover, stats, _ = solve(make_vertex_cover(graph), num_lanes=16,
                            steps_per_round=64, bootstrap_rounds=3,
                            bootstrap_steps=8)
    print(f"PARALLEL-RB (16 lanes): optimum={stats.best}, "
          f"rounds={stats.rounds}, nodes={stats.nodes}, "
          f"T_S={stats.t_s}, T_R={stats.t_r}")
    assert stats.best == best
    print("optimum matches the serial oracle — done.")


if __name__ == "__main__":
    main()
