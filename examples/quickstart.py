"""Quickstart: parallelize a recursive backtracking solver in ~20 lines.

The paper's promise is that migrating SERIAL-RB to parallel needs almost
no problem-specific code.  Here the full front door (DESIGN.md §6): every
problem family is one ``@register_problem`` entry, and a single Solver
session drives both the serial oracle and the vectorized engine.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro import registry
from repro.solver import Solver, SolverConfig


def main() -> None:
    # One handle carries the engine form AND the serial-oracle form.
    problem = registry.problem("vc", "gnp:24:25:42")
    graph = problem.instance
    print(f"instance: G(n={graph.n}, m={graph.m})")

    solver = Solver(SolverConfig(lanes=16, steps_per_round=64,
                                 bootstrap_rounds=3, bootstrap_steps=8))

    # 1. The serial oracle (paper Fig. 1) — ground truth.
    ref = solver.oracle(problem)
    print(f"SERIAL-RB: optimum={ref.best}, nodes={ref.nodes}")

    # 2. The parallel engine: 16 vectorized lanes, steal rounds, implicit
    #    load balancing (no problem-specific knowledge, no task buffers).
    res = solver.solve(problem)
    print(f"PARALLEL-RB (16 lanes): optimum={res.stats.best}, "
          f"rounds={res.stats.rounds}, nodes={res.stats.nodes}, "
          f"T_S={res.stats.t_s}, T_R={res.stats.t_r}")
    assert res.stats.best == ref.best
    print("optimum matches the serial oracle — done.")


if __name__ == "__main__":
    main()
