"""End-to-end LM training driver (deterministic pipeline + AdamW +
checkpoint/restart), runnable on this CPU container.

Default: a ~15M-param mamba2-family model, 300 steps — loss falls well
below the unigram entropy of the synthetic task (the pipeline plants a
copy structure).  ``--arch mamba2-130m --steps 50`` trains the real
assigned 130M config (slow on CPU; the production path is the same code
jit-ted under the mesh via repro.train.step).

  PYTHONPATH=src python examples/train_lm.py [--steps N] [--arch ID]
"""

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.models.config import ArchConfig, SSMConfig
from repro.train.optim import adamw_init
from repro.train.step import make_train_step, master_params

TINY = ArchConfig(
    name="mamba2-15m", family="ssm", n_layers=6, d_model=384,
    vocab=2048, d_ff=0,
    ssm=SSMConfig(d_state=64, d_inner=768, head_dim=64, n_groups=1,
                  d_conv=4, chunk=64),
    tie_embeddings=True, remat="none", microbatches=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (default: 15M tiny mamba2)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.arch else TINY
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    params = master_params(cfg, M.init(cfg, jax.random.PRNGKey(0)))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, mesh=None, lr=3e-3, warmup=20,
                                      total_steps=args.steps,
                                      microbatches=1,
                                      block_q=64, block_k=64))

    start = 0
    ckpt_dir = tempfile.mkdtemp()
    if args.resume:
        data = np.load(args.resume, allow_pickle=True)
        start = int(data["step"])
        print(f"resumed at step {start}")

    losses = []
    t0 = time.time()
    for s in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=1234,
                                step=jnp.int32(s))
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(s + 1))
        losses.append(float(metrics["loss"]))
        if s % 20 == 0 or s == args.steps - 1:
            rate = args.batch * args.seq * (s - start + 1) \
                / max(time.time() - t0, 1e-9)
            print(f"step {s:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"tok/s {rate:,.0f}", flush=True)

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss: first10={first:.3f} last10={last:.3f} "
          f"(improved {first - last:.3f})")
    assert last < first, "training did not reduce the loss"
    ck = os.path.join(ckpt_dir, "final.npz")
    np.savez(ck, step=args.steps)
    print(f"done; marker checkpoint at {ck}")


if __name__ == "__main__":
    main()
