"""Batched serving: continuous slot-based decode over a smoke model.

Submits a wave of requests, runs the lockstep decode loop, and checks
every request's greedy continuation against an unbatched reference.

  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve.driver import BatchedServer, Request
from repro.serve.engine import greedy_sample, make_decode_step, \
    make_prefill_step


def reference_decode(cfg, params, prompt, n_new, max_seq):
    prefill = make_prefill_step(cfg, block_q=16, block_k=16)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, {"tokens": prompt[None]})
    cache = M.pad_cache(cfg, cache, max_seq)
    tok = greedy_sample(logits).reshape(1, 1)
    out = []
    pos = prompt.shape[0]
    for _ in range(n_new):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = greedy_sample(logits).reshape(1, 1)
        out.append(int(tok[0, 0]))
        pos += 1
    return out


def main() -> None:
    cfg = configs.smoke("qwen2-7b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    plen, n_new, slots = 16, 8, 4
    max_seq = plen + n_new + 2

    key = jax.random.PRNGKey(1)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                             (plen,), 0, cfg.vocab))
               for i in range(6)]
    reqs = [Request(rid=i, prompt=p, max_new=n_new)
            for i, p in enumerate(prompts)]

    server = BatchedServer(cfg, params, batch_slots=slots, max_seq=max_seq,
                           block=16)
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.1f}s "
          f"({slots} slots)")

    mismatch = 0
    for r in reqs[:3]:
        ref = reference_decode(cfg, params, jnp.asarray(r.prompt),
                               len(r.out), max_seq)
        if ref != r.out:
            mismatch += 1
    print("reference check:", "OK" if mismatch == 0 else
          f"{mismatch} mismatches")
    assert mismatch == 0


if __name__ == "__main__":
    main()
