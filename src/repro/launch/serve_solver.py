"""Multi-tenant solver service launcher: many instances, one lane pool.

  PYTHONPATH=src python -m repro.launch.serve_solver \
      --instances vc:gnp:20:30:5,ds:gnp:16:30:7,vc:reg:24:4:1 \
      --lanes 32 --slots 4 [--backend pallas] [--ckpt svc.ckpt] [--resume]

Each instance spec is ``<family>:<instance>`` where ``<family>`` is any
*servable* registered problem family (``repro.registry``) and
``<instance>`` uses that family's own registered parser
(``gnp:<n>:<p*100>:<seed>``, ``reg:<n>:<k>:<seed>``, ``cell60``).
``--repeat R`` replays the whole mix R times (distinct request ids) to
exercise continuous batching past the slot count.  ``--backend pallas``
routes the shared stacked evaluate through the batched masked-popcount
kernel (DESIGN.md §5.3) — results are bitwise-identical to jnp.

The launcher contains zero per-family branching: admission rules live in
the registry + ``SolverService.submit`` (typed ``AdmissionError``), and
the service is built through the :class:`repro.solver.Solver` facade
(DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import time

from repro import registry
from repro.service import SolveRequest, SolverService
from repro.solver import Solver, SolverConfig


def parse_workload(spec: str, repeat: int):
    """-> list of (family, instance) over the comma-separated mix."""
    out = []
    for item in spec.split(","):
        family, _, inst = item.partition(":")
        if not inst:
            raise SystemExit(
                f"bad instance spec {item!r}: want <family>:<instance>")
        try:
            pspec = registry.get(family)
        except registry.UnknownProblemError as e:
            raise SystemExit(f"bad instance spec {item!r}: {e}")
        if not pspec.servable:
            raise SystemExit(
                f"bad instance spec {item!r}: family {family!r} is not "
                f"servable (no service packing registered)")
        try:
            out.append((family, pspec.parse(inst)))
        except ValueError as e:
            raise SystemExit(f"bad instance spec {item!r}: {e}")
    return out * repeat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances",
                    default="vc:gnp:20:30:5,ds:gnp:16:30:7,vc:reg:24:4:1,"
                            "ds:gnp:14:25:2")
    ap.add_argument("--repeat", type=int, default=1)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp",
                    help="shared-evaluate kernel backend (DESIGN.md §5.3)")
    ap.add_argument("--steps-per-round", type=int, default=64)
    ap.add_argument("--ckpt", default=None,
                    help="service checkpoint path (written every "
                         "--ckpt-every rounds and after the drain)")
    ap.add_argument("--ckpt-every", type=int, default=10,
                    help="rounds between mid-run checkpoints (0 = final only)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the service from --ckpt before serving")
    args = ap.parse_args()

    if args.resume and not args.ckpt:
        ap.error("--resume requires --ckpt")

    workload = parse_workload(args.instances, args.repeat)
    if args.resume:
        svc = SolverService.restore(args.ckpt, num_lanes=args.lanes,
                                    steps_per_round=args.steps_per_round,
                                    backend=args.backend)
        print(f"restored service: slots={svc.slot_rid} "
              f"pool={len(svc.pool)} rounds={svc.rounds}")
        # In-flight slots finish under their checkpointed rids; the
        # --instances workload is submitted as NEW requests with rids past
        # everything the checkpoint knows about (the checkpoint does not
        # record drained queues, so resubmission is the caller's job).
        rid0 = 1 + max([r for r in svc.slot_rid if r >= 0] + [-1])
        reqs = [SolveRequest(rid=rid0 + i, graph=g, family=fam)
                for i, (fam, g) in enumerate(workload)]
    else:
        max_n = max(registry.get(fam).size(g) for fam, g in workload)
        config = SolverConfig(lanes=args.lanes,
                              steps_per_round=args.steps_per_round,
                              backend=args.backend)
        svc = Solver(config).serve(max_n=max_n, slots=args.slots)
        reqs = [SolveRequest(rid=i, graph=g, family=fam)
                for i, (fam, g) in enumerate(workload)]
    for r in reqs:
        svc.submit(r)

    print(f"serving {len(reqs)} requests over {args.lanes} lanes / "
          f"{svc.spec.k} slots (padded n={svc.spec.n}, "
          f"backend={svc.backend})")
    t0 = time.time()
    while svc._has_work():
        svc.step_round()
        if (args.ckpt and args.ckpt_every
                and svc.rounds % args.ckpt_every == 0):
            svc.save(args.ckpt)
    wall = time.time() - t0
    by_rid = {q.rid: q for q in reqs}
    for rid in sorted(svc.results):
        r = svc.results[rid]
        req = by_rid.get(rid)
        label = (f"{req.family}[{req.graph.name}]" if req is not None
                 else "(restored in-flight)")
        print(f"  rid={r.rid:3d} {label} optimum={r.optimum} rounds="
              f"{r.admitted_round}..{r.retired_round}")
    done = len(svc.results)
    print(f"drained {done} requests in {svc.rounds} rounds, "
          f"{wall:.2f}s -> {done / max(wall, 1e-9):.2f} instances/s")
    if args.ckpt:
        svc.save(args.ckpt)
        print(f"service checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
