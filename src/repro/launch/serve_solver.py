"""Multi-tenant solver service launcher: many instances, one lane pool.

  PYTHONPATH=src python -m repro.launch.serve_solver \
      --instances vc:gnp:20:30:5@prio=2,ds:gnp:16:30:7@deadline=60,vc:reg:24:4:1 \
      --lanes 32 --slots 4 [--scheduler sjf] [--backend pallas] \
      [--devices 4] [--autoscale 8] [--ckpt svc.ckpt] [--resume]

Each instance spec is ``<family>:<instance>[@<attr>=<v>...]`` where
``<family>`` is any *servable* registered problem family
(``repro.registry``) and ``<instance>`` uses that family's own registered
parser (``gnp:<n>:<p*100>:<seed>``, ``reg:<n>:<k>:<seed>``, ``cell60``).
Per-request lifecycle attributes ride after ``@`` separators:
``prio=<int>`` (admission priority under the priority scheduler),
``deadline=<rounds>`` (expire the request that many service rounds after
submission) and ``budget=<nodes>`` (evict after that many search nodes) —
e.g. ``vc:gnp:20:30:5@prio=3@deadline=80``.  ``--scheduler`` picks the
admission policy (``priority`` default, ``sjf``, ``fifo`` —
``repro.service.scheduler``).  ``--repeat R`` replays the whole mix R
times (distinct request ids) to exercise continuous batching past the
slot count.  ``--backend pallas`` routes the shared stacked evaluate
through the batched masked-popcount kernel (DESIGN.md §5.3) — results are
bitwise-identical to jnp.  ``--devices N`` shards the lane pool over the
first N devices (``--lanes`` is PER DEVICE; DESIGN.md §9) and
``--autoscale MAXDEV`` lets the service grow/shrink the mesh elastically
with the admission queue depth.

``submit()`` returns a Ticket per request; the drain loop reports each
ticket's terminal status (done / expired / cancelled) and its
submission-to-resolution latency in rounds.  The launcher contains zero
per-family branching: admission rules live in the registry +
``SolverService.submit`` (typed ``AdmissionError`` after a ``reject``
event), and the service is built through the :class:`repro.solver.Solver`
facade (DESIGN.md §6/§7).
"""

from __future__ import annotations

import argparse
import time

from repro import registry
from repro.service import SCHEDULERS, SolveRequest, SolverService
from repro.solver import Solver, SolverConfig

_ATTRS = {"prio": "priority", "deadline": "deadline_rounds",
          "budget": "node_budget"}


def parse_workload(spec: str, repeat: int):
    """-> list of (family, instance, lifecycle-kwargs) over the mix."""
    out = []
    for item in spec.split(","):
        body, *attrs = item.split("@")
        family, _, inst = body.partition(":")
        if not inst:
            raise SystemExit(
                f"bad instance spec {item!r}: want <family>:<instance>")
        try:
            pspec = registry.get(family)
        except registry.UnknownProblemError as e:
            raise SystemExit(f"bad instance spec {item!r}: {e}")
        if not pspec.servable:
            raise SystemExit(
                f"bad instance spec {item!r}: family {family!r} is not "
                f"servable (no service packing registered)")
        kwargs = {}
        for attr in attrs:
            key, _, val = attr.partition("=")
            if key not in _ATTRS or not val:
                raise SystemExit(
                    f"bad instance spec {item!r}: want @<attr>=<int> with "
                    f"attr in {sorted(_ATTRS)}, got {attr!r}")
            try:
                kwargs[_ATTRS[key]] = int(val)
            except ValueError:
                raise SystemExit(
                    f"bad instance spec {item!r}: {attr!r} is not an int")
        try:
            out.append((family, pspec.parse(inst), kwargs))
        except ValueError as e:
            raise SystemExit(f"bad instance spec {item!r}: {e}")
    return out * repeat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances",
                    default="vc:gnp:20:30:5,ds:gnp:16:30:7,vc:reg:24:4:1,"
                            "ds:gnp:14:25:2")
    ap.add_argument("--repeat", type=int, default=1)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--scheduler", choices=sorted(SCHEDULERS), default=None,
                    help="admission policy (DESIGN.md §7; default: priority,"
                         " or the checkpointed policy with --resume)")
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp",
                    help="shared-evaluate kernel backend (DESIGN.md §5.3)")
    ap.add_argument("--steps-per-round", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the lane pool over the first N devices "
                         "(--lanes is PER DEVICE; DESIGN.md §9)")
    ap.add_argument("--max-ship", type=int, default=16,
                    help="cross-device tasks shipped per device per round")
    ap.add_argument("--autoscale", type=int, default=0, metavar="MAXDEV",
                    help="grow/shrink the mesh elastically up to MAXDEV "
                         "devices, keyed on admission queue depth "
                         "(starts at --devices)")
    ap.add_argument("--ckpt", default=None,
                    help="service checkpoint path (written every "
                         "--ckpt-every rounds and after the drain)")
    ap.add_argument("--ckpt-every", type=int, default=10,
                    help="rounds between mid-run checkpoints (0 = final only)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the service from --ckpt before serving")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a JSONL service trace (repro.obs schema; "
                         "summarize with tools/trace_report.py)")
    ap.add_argument("--metrics", action="store_true",
                    help="collect in-process metrics and print a summary")
    args = ap.parse_args()

    if args.resume and not args.ckpt:
        ap.error("--resume requires --ckpt")

    import jax

    from repro.service.scheduler import AutoscalePolicy

    if args.devices > len(jax.devices()):
        ap.error(f"--devices {args.devices} > available device count "
                 f"{len(jax.devices())} (force host devices with "
                 f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    mesh = (jax.make_mesh((args.devices,), ("workers",),
                          devices=jax.devices()[:args.devices])
            if args.devices > 1 else None)
    autoscale = (AutoscalePolicy(max_devices=args.autoscale)
                 if args.autoscale > 1 else None)

    workload = parse_workload(args.instances, args.repeat)
    if args.resume:
        svc = SolverService.restore(args.ckpt, num_lanes=args.lanes,
                                    steps_per_round=args.steps_per_round,
                                    backend=args.backend,
                                    scheduler=args.scheduler,
                                    mesh=mesh, max_ship=args.max_ship,
                                    trace_path=args.trace,
                                    metrics=args.metrics)
        svc.autoscale = autoscale
        print(f"restored service: slots={svc.slot_rid} "
              f"queue={len(svc.queue)} pool={len(svc.pool)} "
              f"rounds={svc.rounds} scheduler={svc.sched.policy.name}")
        # In-flight slots and the restored queue finish under their
        # checkpointed rids/tickets; the --instances workload is submitted
        # as NEW requests with rids past everything the checkpoint issued
        # (pre-ticket checkpoints carry no ticket table, so in-flight slot
        # rids count too).
        rid0 = 1 + max(list(svc.tickets)
                       + [r for r in svc.slot_rid if r >= 0] + [-1])
    else:
        max_n = max(registry.get(fam).size(g) for fam, g, _ in workload)
        config = SolverConfig(lanes=args.lanes,
                              steps_per_round=args.steps_per_round,
                              backend=args.backend,
                              scheduler=args.scheduler or "priority",
                              mesh=mesh, max_ship=args.max_ship,
                              autoscale=autoscale,
                              trace_path=args.trace, metrics=args.metrics)
        svc = Solver(config).serve(max_n=max_n, slots=args.slots)
        rid0 = 0
    reqs = [SolveRequest(rid=rid0 + i, graph=g, family=fam, **kwargs)
            for i, (fam, g, kwargs) in enumerate(workload)]
    tickets = {r.rid: svc.submit(r) for r in reqs}

    print(f"serving {len(reqs)} requests over {svc.num_lanes} lanes "
          f"({svc.n_devices} device(s) x {svc.lanes_per_device}) / "
          f"{svc.spec.k} slots (padded n={svc.spec.n}, "
          f"backend={svc.backend}, scheduler={svc.sched.policy.name})")
    t0 = time.time()
    while svc._has_work():
        svc.step_round()
        if (args.ckpt and args.ckpt_every
                and svc.rounds % args.ckpt_every == 0):
            svc.save(args.ckpt)
    wall = time.time() - t0
    svc.finalize_trace()          # manual step loop: write the summary row
    by_rid = {q.rid: q for q in reqs}
    # Pre-ticket checkpoints restore in-flight slots without tickets, so
    # report over tickets AND results.
    served = sorted(set(svc.tickets) | set(svc.results))
    for rid in served:
        ticket = svc.tickets.get(rid)
        req = by_rid.get(rid)
        label = (f"{req.family}[{req.graph.name}]" if req is not None
                 else "(restored)")
        res = svc.results.get(rid)
        shown = ("cancelled" if res is None
                 else f"optimum={res.optimum}" if res.status == "done"
                 else f"{res.status} anytime={res.optimum}")
        span = (f"rounds={ticket.submitted_round}..{ticket.finished_round} "
                f"latency={ticket.finished_round - ticket.submitted_round}"
                if ticket is not None and ticket.finished_round is not None
                else f"rounds=..{res.retired_round}" if res is not None
                else "")
        print(f"  rid={rid:3d} {label} {shown} {span}")
    done = sum(1 for r in svc.results.values() if r.status == "done")
    print(f"drained {len(served)} requests ({done} exact) in "
          f"{svc.rounds} rounds, {wall:.2f}s -> "
          f"{done / max(wall, 1e-9):.2f} instances/s")
    if args.metrics:
        snap = svc.metrics()
        util = snap.value("lane_utilization")
        steals = snap.value("steal_received", scope="intra")
        print(f"metrics: nodes={snap.value('engine_nodes')} "
              f"dispatches={snap.value('engine_dispatches')} "
              f"util={util:.3f} steals intra={steals} "
              f"queue_depth={snap.value('service_queue_depth')}")
    if args.trace:
        print(f"trace -> {args.trace}")
    if args.ckpt:
        svc.save(args.ckpt)
        print(f"service checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
