"""Training launcher: restartable driver around repro.train.step.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 100 --batch 8 --seq 256 [--ckpt /path/run.ckpt] [--resume]

Production posture: deterministic step-indexed data, atomic checkpoints
every --ckpt-every steps, resume picks up at the recorded step with
byte-identical batches.  On the real mesh the same step function lowers
with the shardings from repro.train.step.shardings_for_step (the dry-run
proves the 16x16 and 2x16x16 configurations compile and fit).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.optim import adamw_init
from repro.train.step import make_train_step, master_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")
    params = master_params(cfg, M.init(cfg, jax.random.PRNGKey(0)))
    opt = adamw_init(params)
    start = 0
    if args.resume and args.ckpt and os.path.exists(args.ckpt):
        params, opt, start = ckpt.restore(args.ckpt, params, opt)
        print(f"resumed from {args.ckpt} at step {start}")

    step_fn = jax.jit(make_train_step(cfg, mesh=None, lr=args.lr,
                                      total_steps=args.steps,
                                      microbatches=1,
                                      block_q=64, block_k=64))
    t0 = time.time()
    for s in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=args.seed,
                                step=jnp.int32(s))
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s + 1))
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if args.ckpt and (s + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, params, opt, s + 1)
    if args.ckpt:
        ckpt.save(args.ckpt, params, opt, args.steps)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
