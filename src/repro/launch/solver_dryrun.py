"""Dry-run the PAPER'S OWN technique on the production meshes.

Lowers one distributed steal round (expand R nodes -> intra-device steal
-> cross-device steal -> incumbent pmin -> termination psum) for a
512-vertex Vertex Cover instance over the 16x16 (256-chip) and 2x16x16
(512-chip) meshes, and runs the same roofline analysis as the LM cells.

This quantifies the paper's central claim at pod scale: tasks are O(d)
int8 index vectors, so the steal phase's collective payload is tiny
relative to the compute phase — the table shows collective bytes per
round of a few MB against hundreds of ms of node-expansion compute.

  PYTHONPATH=src python -m repro.launch.solver_dryrun [--multi-pod]
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json

import jax
import numpy as np

from repro import registry
from repro.core.distributed import make_distributed_round
from repro.core.engine import init_lanes
from repro.launch.dryrun import ARTIFACT_DIR
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.roofline import analyze_hlo


def run(multi_pod: bool, lanes_per_device: int = 8,
        steps_per_round: int = 256, problem: str = "vc",
        instance: str = "reg:512:4:1"):
    """Lower one distributed round of any registered problem family over
    the production mesh (registry-driven — no per-problem code here)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    spec = registry.get(problem)
    g = spec.parse(instance)
    prob = spec.build(g)

    fn = make_distributed_round(prob, mesh, steps_per_round, max_ship=16)
    lanes = init_lanes(prob, lanes_per_device * n_dev, seed_root=False)
    ab = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), lanes)
    with mesh:
        lowered = fn.lower(ab)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    counts = analyze_hlo(compiled.as_text())
    terms = counts.terms(PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
    out = {
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lanes_total": lanes_per_device * n_dev,
        "steps_per_round": steps_per_round,
        "problem": problem,
        "instance": spec.label(g),
        "peak_bytes": int(mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes
                          + mem.output_size_in_bytes
                          - mem.alias_size_in_bytes),
        "collective_bytes_per_round_per_dev": counts.collective_bytes,
        "per_collective": counts.per_collective,
        "hbm_bytes_per_dev": counts.hbm_bytes,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
    }
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(
        ARTIFACT_DIR, f"solver__round__{'mp' if multi_pod else 'sp'}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--problem", default="vc",
                    help="registered problem family (repro.registry)")
    ap.add_argument("--instance", default="reg:512:4:1")
    args = ap.parse_args()
    if args.both:
        run(False, problem=args.problem, instance=args.instance)
        run(True, problem=args.problem, instance=args.instance)
    else:
        run(args.multi_pod, problem=args.problem, instance=args.instance)


if __name__ == "__main__":
    main()
