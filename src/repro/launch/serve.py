"""Serving launcher: batched prefill + greedy decode driver.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Runs the same prefill/decode step functions the dry-run lowers for the
prefill_32k / decode_32k / long_500k cells (incl. the int8 KV-cache path
with --kv-quant).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.serve.engine import greedy_sample, make_decode_step, \
    make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = M.init(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, block_q=32, block_k=32,
                                        kv_quant=args.kv_quant))
    decode = jax.jit(make_decode_step(cfg, kv_quant=args.kv_quant))

    key = jax.random.PRNGKey(7)
    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.n_codebooks else (args.batch, args.prompt_len))
    prompts = jax.random.randint(key, shape, 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision"] = jax.random.normal(
            key, (args.batch, cfg.vision_tokens, cfg.d_model),
            jnp.bfloat16) * 0.02

    t0 = time.time()
    logits, cache = prefill(params, batch)
    cache = M.pad_cache(cfg, cache, max_seq)
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{time.time()-t0:.2f}s")

    tok = greedy_sample(logits)[:, None]
    if cfg.n_codebooks and tok.ndim == 2:
        tok = tok[..., None] if tok.shape[-1] == cfg.n_codebooks \
            else tok.reshape(args.batch, 1, cfg.n_codebooks)
    outs = []
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, tok, pos)
        tok = greedy_sample(logits)[:, None]
        if cfg.n_codebooks:
            tok = tok.reshape(args.batch, 1, cfg.n_codebooks)
        outs.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    print("sample:", jnp.asarray(gen)[0].ravel()[:16].tolist())


if __name__ == "__main__":
    main()
