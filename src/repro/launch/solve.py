"""Solver launcher: the paper's framework as a CLI (registry-driven).

  PYTHONPATH=src python -m repro.launch.solve --problem vc \
      --instance reg:48:4:1 --lanes 32 [--ckpt run.ckpt] [--resume]

``--problem`` accepts any family registered with
``repro.registry.register_problem`` and ``--instance`` uses that family's
own registered parser (graph families: ``gnp:<n>:<p*100>:<seed>``,
``reg:<n>:<k>:<seed>``, ``cell60``; subset sum: ``ss:<n>:<seed>``).  The
CLI contains zero per-problem branching: parsing, capability validation
and construction all come from the registry, and the solve itself runs
through the :class:`repro.solver.Solver` facade (DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import time

from repro import registry
from repro.problems.graphs import parse_graph_instance
from repro.solver import Solver, SolverConfig


def parse_instance(spec: str):
    """DEPRECATED graph-spec parser, kept for pre-registry callers — use
    ``repro.registry.get(family).parse`` (each family owns its grammar)."""
    return parse_graph_instance(spec)


def main() -> None:
    families = registry.names()
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=sorted(families), default="vc",
                    help="registered problem family: " + "; ".join(
                        f"{n}: {registry.get(n).doc}" for n in families))
    ap.add_argument("--backend", default="jnp",
                    help="node-evaluation kernel backend (validated against "
                         "the family's registered capabilities)")
    ap.add_argument("--instance", default="reg:48:4:1")
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--steps-per-round", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a JSONL search trace (repro.obs schema; "
                         "summarize with tools/trace_report.py)")
    ap.add_argument("--metrics", action="store_true",
                    help="collect in-process metrics and print a summary")
    args = ap.parse_args()

    # Capability check is registry data, not per-problem branching
    # (DESIGN.md §5.4/§6): a problem gains --backend pallas the moment its
    # registration does.
    spec = registry.get(args.problem)
    if args.backend not in spec.backends:
        ap.error(
            f"--backend {args.backend} is not supported by --problem "
            f"{args.problem} (registry advertises: "
            f"{', '.join(spec.backends)})")
    try:
        instance = spec.parse(args.instance)
    except ValueError as e:
        ap.error(str(e))

    config = SolverConfig(
        lanes=args.lanes, steps_per_round=args.steps_per_round,
        backend=args.backend, bootstrap_rounds=4, bootstrap_steps=8,
        checkpoint_every=args.ckpt_every if args.ckpt else 0,
        checkpoint_path=args.ckpt,
        resume_from=args.ckpt if args.resume else None,
        trace_path=args.trace, metrics=args.metrics)
    handle = registry.problem(args.problem, instance)
    print(f"{args.problem}[{spec.label(instance)}]: lanes={args.lanes} "
          f"backend={args.backend}")
    t0 = time.time()
    solver = Solver(config)
    result = solver.solve(handle)
    stats = result.stats
    print(f"optimum={stats.best} rounds={stats.rounds} nodes={stats.nodes} "
          f"T_S={stats.t_s} T_R={stats.t_r} wall={time.time()-t0:.1f}s")
    if args.metrics:
        snap = solver.metrics()
        util = snap.value("lane_utilization")
        steals = snap.value("steal_received", scope="intra")
        cross = snap.value("steal_received", scope="cross")
        print(f"metrics: nodes={snap.value('engine_nodes')} "
              f"dispatches={snap.value('engine_dispatches')} "
              f"util={util:.3f} steals intra={steals} cross={cross}")
    if args.trace:
        print(f"trace -> {args.trace}")


if __name__ == "__main__":
    main()
