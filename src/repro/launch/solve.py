"""Solver launcher: the paper's framework as a CLI.

  PYTHONPATH=src python -m repro.launch.solve --problem vc \
      --instance reg:48:4:1 --lanes 32 [--ckpt run.ckpt] [--resume]

Instances: ``gnp:<n>:<p*100>:<seed>``, ``reg:<n>:<k>:<seed>``,
``cell60`` (the 4-regular analogue).  Problems: vc | ds.
"""

from __future__ import annotations

import argparse
import time

from repro.core.distributed import solve
from repro.problems import (PROBLEM_FACTORIES, cell60_graph, gnp_graph,
                            problem_backends, random_regularish_graph)


def parse_instance(spec: str):
    if spec == "cell60":
        return cell60_graph()
    kind, *rest = spec.split(":")
    if kind == "gnp":
        n, p100, seed = (int(x) for x in rest)
        return gnp_graph(n, p100 / 100.0, seed=seed)
    if kind == "reg":
        n, k, seed = (int(x) for x in rest)
        return random_regularish_graph(n, k, seed=seed)
    raise SystemExit(f"unknown instance spec {spec}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=sorted(PROBLEM_FACTORIES),
                    default="vc")
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp",
                    help="node-evaluation kernel backend (validated against "
                         "the problem factory's advertised capabilities)")
    ap.add_argument("--instance", default="reg:48:4:1")
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--steps-per-round", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # Capability check is data, not per-problem branching: every factory
    # advertises its kernel backends (DESIGN.md §5.4), so a problem gains
    # --backend pallas the moment its factory does.
    supported = problem_backends(args.problem)
    if args.backend not in supported:
        ap.error(
            f"--backend {args.backend} is not supported by --problem "
            f"{args.problem} (factory advertises: {', '.join(supported)})")

    g = parse_instance(args.instance)
    prob = PROBLEM_FACTORIES[args.problem](g, backend=args.backend)
    print(f"{prob.name}: n={g.n} m={g.m} lanes={args.lanes}")
    t0 = time.time()
    payload, stats, _ = solve(
        prob, num_lanes=args.lanes, steps_per_round=args.steps_per_round,
        bootstrap_rounds=4, bootstrap_steps=8,
        checkpoint_every=args.ckpt_every if args.ckpt else 0,
        checkpoint_path=args.ckpt,
        resume_from=args.ckpt if args.resume else None)
    print(f"optimum={stats.best} rounds={stats.rounds} nodes={stats.nodes} "
          f"T_S={stats.t_s} T_R={stats.t_r} wall={time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
