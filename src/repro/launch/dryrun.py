"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES (below) must run before any other import — jax locks
the platform device count on first init.  Do NOT replicate this flag in
conftest.py / pyproject: only the dry-run sees 512 placeholder devices.

Per cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. constructs abstract params / optimizer / batch / cache
     (ShapeDtypeStruct only — zero allocation);
  3. jit(...).lower(...).compile() with explicit in/out shardings;
  4. records memory_analysis() (fits-in-16GB proof), cost_analysis(),
     and the trip-count-corrected roofline terms (repro.roofline);
  5. writes one JSON artifact per cell under benchmarks/artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402  (the XLA flag must precede every jax-touching import)
import argparse
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.data.pipeline import input_abstract
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import model as M
from repro.models.config import ArchConfig, ShapeConfig, shapes_for
from repro.roofline import analyze_hlo, model_flops
from repro.serve.engine import (decode_tokens_abstract, make_decode_step,
                                make_prefill_step)
from repro.train.optim import AdamState
from repro.train.step import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "benchmarks", "artifacts",
                            "dryrun")


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _tree_ns(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: _ns(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _f32_abstract(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(
            a.shape, jnp.float32 if jnp.issubdtype(a.dtype, jnp.floating)
            else a.dtype), tree)


def batch_specs(cfg: ArchConfig, mesh: Mesh) -> Dict[str, P]:
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    keys = ["tokens", "labels"] + (["vision"] if cfg.vision_tokens else [])
    return {k: P(fsdp or None) for k in keys}


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                perf_opts: Optional[Dict[str, Any]] = None
                ) -> Tuple[Any, Tuple, Dict[str, Any], Any]:
    """Returns (step_fn, abstract_args, in_shardings, out_shardings)."""
    perf = dict(block_q=256, block_k=256, skip_masked_blocks=False,
                microbatches=None, seq_shard=False, kv_quant=None,
                attn_heads_shard=True)
    perf.update(perf_opts or {})
    pspecs = M.specs(cfg, mesh.axis_names, M.mesh_axis_sizes(mesh))
    p_sh = _tree_ns(mesh, pspecs)
    b_sh = {k: _ns(mesh, v) for k, v in batch_specs(cfg, mesh).items()}

    if shape.kind == "train":
        ab_params = _f32_abstract(M.abstract(cfg))      # f32 masters
        ab_opt = AdamState(m=_f32_abstract(M.abstract(cfg)),
                           v=_f32_abstract(M.abstract(cfg)))
        ab_batch = input_abstract(cfg, shape.global_batch, shape.seq_len)
        ab_step = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_train_step(cfg, mesh,
                               microbatches=perf.get("microbatches"),
                               skip_masked_blocks=perf["skip_masked_blocks"],
                               block_q=perf["block_q"],
                               block_k=perf["block_k"],
                               seq_shard=perf.get("seq_shard", False),
                               attn_heads_shard=perf.get(
                                   "attn_heads_shard", True))
        in_sh = (p_sh, AdamState(m=p_sh, v=p_sh), b_sh, _ns(mesh, P()))
        out_sh = (p_sh, AdamState(m=p_sh, v=p_sh),
                  {"loss": _ns(mesh, P()), "lr": _ns(mesh, P()),
                   "grad_norm": _ns(mesh, P())})
        return step, (ab_params, ab_opt, ab_batch, ab_step), in_sh, out_sh

    if shape.kind == "prefill":
        ab_params = M.abstract(cfg)
        ab_batch = input_abstract(cfg, shape.global_batch, shape.seq_len)
        ab_batch.pop("labels")
        bsh = {k: v for k, v in b_sh.items() if k in ab_batch}
        step = make_prefill_step(cfg, mesh, block_q=perf["block_q"],
                                 block_k=perf["block_k"],
                                 skip_masked_blocks=perf["skip_masked_blocks"],
                                 attn_heads_shard=perf.get(
                                     "attn_heads_shard", True))
        c_sh = _tree_ns(mesh, M.cache_specs(cfg, mesh, shape.global_batch,
                                            shape.seq_len))
        logits_sh = _ns(mesh, P(tuple(
            a for a in ("pod", "data") if a in mesh.axis_names) or None))
        return step, (ab_params, ab_batch), (p_sh, bsh), (logits_sh, c_sh)

    # decode
    from repro.serve.engine import auto_kv_quant
    n_dev = int(np.prod(mesh.devices.shape))
    quant = perf.get("kv_quant")
    if quant is None:
        quant = auto_kv_quant(cfg, shape.global_batch, shape.seq_len, n_dev)
    ab_params = M.abstract(cfg)
    ab_cache = M.cache_abstract(cfg, shape.global_batch, shape.seq_len,
                                quant=quant)
    ab_tok = decode_tokens_abstract(cfg, shape.global_batch)
    ab_pos = jax.ShapeDtypeStruct((), jnp.int32)
    c_sh = _tree_ns(mesh, M.cache_specs(cfg, mesh, shape.global_batch,
                                        shape.seq_len, quant=quant))
    step = make_decode_step(cfg, mesh, kv_quant=quant)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = (_ns(mesh, P(fsdp))
             if shape.global_batch % max(np.prod(
                 [dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                  for a in fsdp]), 1) == 0 else _ns(mesh, P()))
    logits_sh = bspec
    in_sh = (p_sh, c_sh, bspec, _ns(mesh, P()))
    out_sh = (logits_sh, c_sh)
    return step, (ab_params, ab_cache, ab_tok, ab_pos), in_sh, out_sh


def cpu_upcast_artifact_bytes(hlo: str) -> int:
    """Bytes of f32 buffers that are CPU-backend upcast twins.

    The CPU XLA backend computes bf16 dots by converting operands to f32
    and (under scan linearization) SAVES the converted copy per layer next
    to the bf16 original — a buffer that cannot exist on TPU, where the
    MXU consumes bf16 natively (verified with a minimal scan repro; no
    flag disables it).  Detected conservatively: an op
    ``%x = f32[dims] convert(%y: bf16[dims])`` with > 256 MB result, each
    distinct shape counted once.  The dry-run reports raw peak AND peak
    minus this artifact."""
    import re as _re
    from repro.roofline import parse_computations, _shape_dims
    comps, _ = parse_computations(hlo)
    seen = set()
    total = 0
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode != "convert" or not op.result_type.startswith("f32"):
                continue
            dims = tuple(_shape_dims(op.result_type))
            n = 1
            for d in dims:
                n *= d
            if n * 4 <= 256 * 2 ** 20 or dims in seen:
                continue
            m = _re.search(r"convert\(%([\w.\-]+)\)", op.rest)
            src = comp.symtab.get(m.group(1)) if m else None
            if src and src.startswith("bf16") and \
                    tuple(_shape_dims(src)) == dims:
                seen.add(dims)
                total += n * 4
    total += _donated_copy_artifact_bytes(hlo, comps)
    return total


def _donated_copy_artifact_bytes(hlo: str, comps) -> int:
    """CPU copy-insertion artifact for donated in-place buffers.

    Donated arguments (KV caches, params) appear in the header as
    ``input_output_alias={... may-alias ...}``; on TPU the in-place
    dynamic-update-slice reuses the donated buffer, but the CPU scheduler
    inserts full ``copy`` ops of the carried buffer inside the loop (one
    resident working copy per buffer).  Detected: a copy op whose result
    type exactly matches a may-aliased entry-parameter type; each distinct
    type counted once."""
    import re as _re
    from repro.roofline import _shape_bytes
    header = hlo.splitlines()[0] if hlo else ""
    am = _re.search(r"input_output_alias=\{(.*)\}, entry_computation_layout",
                    header)
    lm = _re.search(r"entry_computation_layout=\{?\((.*?)\)->", header)
    if not am or not lm:
        return 0
    params = _re.findall(r"(\w+\[[0-9,]*\])", lm.group(1))
    aliased_idx = [int(i) for i in
                   _re.findall(r"\((\d+), \{\}, may-alias\)", am.group(0))]
    copied_types = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "copy":
                copied_types.add(op.result_type.split("{")[0])
    # one working copy per aliased buffer whose type the scheduler copies
    # (k and v share a type string but are distinct buffers: count per
    # aliased parameter, not per distinct type).
    return sum(_shape_bytes(params[i]) for i in aliased_idx
               if i < len(params) and params[i] in copied_types)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             perf_opts: Optional[Dict[str, Any]] = None,
             save_hlo: bool = False) -> Dict[str, Any]:
    cfg = configs.get(arch)
    shape = {s.name: s for s in shapes_for(cfg)}.get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "quadratic attention at 500k (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))

    step, ab_args, in_sh, out_sh = input_specs(cfg, shape, mesh, perf_opts)
    # Buffer donation: train donates params+opt, decode donates the cache —
    # without it every step would double its resident state.
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*ab_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    memstats = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    counts = analyze_hlo(hlo)
    terms = counts.terms(PEAK_FLOPS_BF16, HBM_BW, ICI_BW)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mflops = model_flops(cfg, tokens, shape.is_train)
    hlo_flops_total = counts.flops * n_dev
    # Dominance / roofline use the kernel-adjusted memory term (score-block
    # traffic lives in VMEM under the Pallas kernels); the raw term is also
    # reported so the adjustment is visible.
    eff = {"compute_s": terms["compute_s"],
           "memory_s": terms["memory_kernel_adj_s"],
           "collective_s": terms["collective_s"]}
    dominant = max(eff, key=eff.get)
    arg_b = int(getattr(memstats, "argument_size_in_bytes", 0))
    tmp_b = int(getattr(memstats, "temp_size_in_bytes", 0))
    out_b = int(getattr(memstats, "output_size_in_bytes", 0))
    alias_b = int(getattr(memstats, "alias_size_in_bytes", 0))
    peak = arg_b + tmp_b + out_b - alias_b
    artifact = cpu_upcast_artifact_bytes(hlo)
    # artifacts live in temp space; never model below args+unaliased out.
    modeled = max(peak - artifact, arg_b + out_b - alias_b)

    result = {
        "arch": arch, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "perf_opts": perf_opts or {},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": arg_b, "temp_bytes": tmp_b,
            "output_bytes": out_b, "alias_bytes": alias_b,
            "peak_bytes": peak,
            "cpu_upcast_artifact_bytes": int(artifact),
            "peak_bytes_tpu_modeled": int(modeled),
            "fits_16GB": bool(modeled <= HBM_BYTES),
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "optimal_seconds")},
        "roofline": {
            "hlo_flops_per_dev": counts.flops,
            "hbm_bytes_per_dev": counts.hbm_bytes,
            "score_bytes_per_dev": counts.score_bytes,
            "collective_bytes_per_dev": counts.collective_bytes,
            "per_collective": counts.per_collective,
            "compute_s": terms["compute_s"],
            "memory_raw_s": terms["memory_s"],
            "memory_s": terms["memory_kernel_adj_s"],
            "collective_s": terms["collective_s"],
            "dominant": dominant,
            "model_flops_total": mflops,
            "useful_flops_ratio": (mflops / hlo_flops_total
                                   if hlo_flops_total else 0.0),
            "roofline_fraction": (
                (mflops / n_dev / PEAK_FLOPS_BF16) / max(eff.values())
                if max(eff.values()) > 0 else 0.0),
        },
    }
    if save_hlo:
        result["hlo_path"] = _save_hlo(arch, shape.name, multi_pod, hlo)
    return result


def _save_hlo(arch, shape, multi_pod, hlo) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    p = os.path.join(ARTIFACT_DIR,
                     f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}.hlo")
    with open(p, "w") as f:
        f.write(hlo)
    return p


def artifact_path(arch: str, shape: str, multi_pod: bool,
                  tag: str = "") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    mesh = "mp" if multi_pod else "sp"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--block-q", type=int, default=256)
    ap.add_argument("--block-k", type=int, default=256)
    ap.add_argument("--skip-masked-blocks", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--kv-quant", type=int, default=None,
                    help="1/0 override of the auto int8-KV policy")
    ap.add_argument("--no-heads-shard", action="store_true")
    args = ap.parse_args()

    perf = {"block_q": args.block_q, "block_k": args.block_k,
            "skip_masked_blocks": args.skip_masked_blocks,
            "microbatches": args.microbatches,
            "seq_shard": args.seq_shard,
            "kv_quant": None if args.kv_quant is None else bool(args.kv_quant),
            "attn_heads_shard": not args.no_heads_shard}

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = configs.get(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            meshes = [args.multi_pod]
            if args.both_meshes:
                meshes = [False, True]
            for mp in meshes:
                cells.append((arch, shape.name, mp))

    ok = failed = 0
    for arch, shape, mp in cells:
        path = artifact_path(arch, shape, mp, args.tag)
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {arch} {shape} {'mp' if mp else 'sp'}")
            ok += 1
            continue
        t0 = time.time()
        try:
            res = run_cell(arch, shape, mp, perf, args.save_hlo)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res.get("roofline", {})
            mem = res.get("memory", {})
            print(f"[ok] {arch} {shape} {'mp' if mp else 'sp'} "
                  f"{time.time()-t0:.0f}s peak="
                  f"{mem.get('peak_bytes', 0)/2**30:.2f}GB "
                  f"tpu={mem.get('peak_bytes_tpu_modeled', 0)/2**30:.2f}GB "
                  f"dominant={r.get('dominant')} "
                  f"frac={r.get('roofline_fraction', 0):.3f}", flush=True)
            ok += 1
        except Exception as e:            # noqa: BLE001 — record and continue
            failed += 1
            print(f"[FAIL] {arch} {shape} {'mp' if mp else 'sp'}: "
                  f"{type(e).__name__}: {e}", flush=True)
    print(f"dry-run: {ok} ok, {failed} failed")
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
