"""Production mesh construction (multi-pod dry-run requirement).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The single-pod mesh is
16 x 16 = 256 chips (data, model); the multi-pod mesh is 2 x 16 x 16 = 512
chips (pod, data, model) — the ``pod`` axis is outer data parallelism for
LM steps and the outer steal ring for the solver.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per hop)
HBM_BYTES = 16 * 2 ** 30          # 16 GB per chip
