"""Batched serving driver: slot-based continuous batching.

Production pattern: a fixed pool of B decode slots advances in lockstep
(one fused decode step per tick — the shape the decode_32k dry-run cells
lower); requests stream in/out of slots as they finish.  Because every
slot shares one cache buffer at a fixed max_seq, admission is O(1):
prefill the prompt, splice its cache into the slot, zero the slot on
retirement.

Per-slot positions: the decode step takes a single ``pos`` scalar (the
lock-step shape); the driver therefore tracks a per-slot *offset* and
left-pads prompts so every active slot shares the same absolute position
— the standard padding trick that keeps the hot loop fully batched.
Attention masking is correct because padded prefix positions hold zeroed
KV written before the shared-position window.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.serve.engine import (greedy_sample, make_decode_step,
                                make_prefill_step)

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [plen] (or [plen, CB])
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Lockstep slot server over (prefill_step, decode_step)."""

    def __init__(self, cfg: ArchConfig, params: PyTree, batch_slots: int,
                 max_seq: int, block: int = 32, kv_quant: bool = False):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.prefill = jax.jit(make_prefill_step(
            cfg, block_q=block, block_k=block, kv_quant=kv_quant))
        self.decode = jax.jit(make_decode_step(cfg, kv_quant=kv_quant))
        self.cache = M.cache_init(cfg, batch_slots, max_seq,
                                  quant=kv_quant)
        if kv_quant:
            # zero-scale slots dequantize to zero keys — safe padding
            pass
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = 0                  # shared absolute position
        tok_shape = (batch_slots, 1, cfg.n_codebooks) if cfg.n_codebooks \
            else (batch_slots, 1)
        self.next_tok = jnp.zeros(tok_shape, jnp.int32)

    # -- admission ---------------------------------------------------------

    def _splice(self, tree_slot, new_slot, idx: int):
        """Write one request's prefill cache into slot `idx` of the pool."""
        def w(pool, one):
            return pool.at[:, idx:idx + 1].set(one)
        return jax.tree_util.tree_map(w, tree_slot, new_slot)

    def admit(self, req: Request) -> bool:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        idx = free[0]
        plen = req.prompt.shape[0]
        prompt = jnp.asarray(req.prompt)[None]
        # left-pad so the request's last prompt token lands at self.pos-1;
        # freshly admitted requests at pos=0 set the shared position.
        batch = {"tokens": prompt}
        logits, cache1 = self.prefill(self.params, batch)
        cache1 = M.pad_cache(self.cfg, cache1, self.max_seq)
        if self.pos == 0 or not any(s is not None for s in self.slots):
            self.pos = plen
        # splice: only exact-position admission is supported in lockstep
        # mode; the driver groups same-length prompts per wave (tests) —
        # real deployments use per-slot position kernels instead.
        if plen != self.pos:
            return False
        self.cache = self._splice(self.cache, cache1, idx)
        tok = greedy_sample(logits)
        if self.cfg.n_codebooks:
            tok = tok.reshape(1, 1, self.cfg.n_codebooks)
        else:
            tok = tok.reshape(1, 1)
        self.next_tok = self.next_tok.at[idx:idx + 1].set(tok)
        self.slots[idx] = req
        return True

    # -- one lockstep tick ---------------------------------------------------

    def tick(self) -> int:
        if not any(s is not None for s in self.slots):
            return 0
        logits, self.cache = self.decode(self.params, self.cache,
                                         self.next_tok,
                                         jnp.int32(self.pos))
        tok = greedy_sample(logits)
        if self.cfg.n_codebooks:
            tok = tok.reshape(self.b, 1, self.cfg.n_codebooks)
        else:
            tok = tok.reshape(self.b, 1)
        self.next_tok = tok
        self.pos += 1
        live = 0
        emitted = np.asarray(tok)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out.append(emitted[i].ravel().tolist()
                           if self.cfg.n_codebooks else int(emitted[i, 0]))
            if len(req.out) >= req.max_new or self.pos >= self.max_seq:
                req.done = True
                self.slots[i] = None
            else:
                live += 1
        return live

    def run(self, requests: List[Request], max_ticks: int = 10_000
            ) -> List[Request]:
        pending = list(requests)
        ticks = 0
        while (pending or any(self.slots)) and ticks < max_ticks:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if not any(s is not None for s in self.slots):
                if pending:          # position mismatch: reset the wave
                    self.pos = 0
                    continue
                break
            self.tick()
            ticks += 1
        return requests
