"""Serving steps: prefill / decode factories with explicit shardings.

``make_prefill_step`` and ``make_decode_step`` return jit-able callables
whose in/out shardings follow the same rule table as training (params 2-D
sharded, cache per repro.models.model.cache_specs).  The batched request
driver (examples/serve_batch.py) composes them; the dry-run lowers them for
the decode_32k / long_500k / prefill_32k cells.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ArchConfig

PyTree = Any


def make_prefill_step(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                      block_q: int = 256, block_k: int = 256,
                      skip_masked_blocks: bool = False,
                      kv_quant: bool = False,
                      attn_heads_shard: bool = True):
    sh = M.Shardings(mesh, attn_heads_shard=attn_heads_shard)

    def step(params, batch):
        ctx = M.make_ctx(cfg, "prefill", sh, block_q=block_q,
                         block_k=block_k,
                         skip_masked_blocks=skip_masked_blocks,
                         kv_quant=kv_quant)
        return M.prefill(cfg, params, batch, ctx)

    return step


def make_decode_step(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                     kv_quant: bool = False):
    sh = M.Shardings(mesh)

    def step(params, cache, tokens, pos):
        ctx = M.make_ctx(cfg, "decode", sh, pos=pos, kv_quant=kv_quant)
        return M.decode_step(cfg, params, cache, tokens, pos, ctx)

    return step


def auto_kv_quant(cfg: ArchConfig, global_batch: int, seq_len: int,
                  n_devices: int) -> bool:
    """int8 KV when the bf16 cache would exceed ~40% of one chip's HBM
    after full (batch x seq/heads) sharding — the MHA archs at 32k x 128."""
    if cfg.family == "ssm":
        return False
    keep = min(seq_len, cfg.window) if cfg.window else seq_len
    site_count = cfg.n_layers if cfg.family != "hybrid" \
        else cfg.n_layers // cfg.hybrid_period
    total = 2 * site_count * keep * cfg.n_kv * cfg.head_dim * 2 \
        * global_batch
    return total / n_devices > 0.4 * 16 * 2 ** 30


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def decode_tokens_abstract(cfg: ArchConfig, batch: int):
    shape = (batch, 1, cfg.n_codebooks) if cfg.n_codebooks else (batch, 1)
    return jax.ShapeDtypeStruct(shape, jnp.int32)
