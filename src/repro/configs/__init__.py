"""Assigned-architecture registry: ``get(name)`` / ``smoke(name)``.

Each ``<id>.py`` exports ``CONFIG`` (the exact assigned configuration) and
``smoke()`` (a reduced same-family copy for CPU smoke tests: small widths,
few layers/experts, tiny vocab — structure preserved).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCH_IDS: List[str] = [
    "qwen1_5_32b",
    "qwen2_7b",
    "gemma2_27b",
    "glm4_9b",
    "internvl2_76b",
    "mamba2_130m",
    "llama4_scout_17b_a16e",
    "mixtral_8x22b",
    "zamba2_2_7b",
    "musicgen_large",
]

#: public ids (dashes) -> module names
ALIASES: Dict[str, str] = {
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2-7b": "qwen2_7b",
    "gemma2-27b": "gemma2_27b",
    "glm4-9b": "glm4_9b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-130m": "mamba2_130m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mixtral-8x22b": "mixtral_8x22b",
    "zamba2-2.7b": "zamba2_2_7b",
    "musicgen-large": "musicgen_large",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


def all_configs() -> Dict[str, ArchConfig]:
    return {aid: get(aid) for aid in ARCH_IDS}
