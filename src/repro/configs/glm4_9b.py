"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — partial RoPE (half the head dim), QKV bias.
[hf:THUDM/glm-4-9b; hf]

long_500k skipped: full quadratic attention.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    vocab=151552,
    n_heads=32,
    n_kv=2,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e4,
    rope_fraction=0.5,
    d_ff=13696,
    mlp_gated=True,
    norm_eps=1.5625e-07,
    remat="full",
    microbatches=4,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16, qkv_bias=True,
        rope_fraction=0.5, d_ff=128, mlp_gated=True, remat="none")
