"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + one always-on shared expert, early
fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

The early-fusion modality frontend is out of the backbone per the
assignment; the config is the text backbone.  long_500k skipped:
quadratic attention.
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    vocab=202048,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    rope_theta=5e5,
    d_ff=8192,
    mlp_gated=True,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192,
                  capacity_factor=1.25, shared_expert_ff=8192),
    norm_eps=1e-5,
    remat="full",
    microbatches=8,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, mlp_gated=True,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff=96,
                      capacity_factor=4.0, shared_expert_ff=96),
        remat="none")
