"""zamba2-2.7b [hybrid]: 54 Mamba-2 layers d_model=2560 + ONE shared
transformer block (32H MHA kv=32, d_ff=10240) applied once per 6-layer
group, vocab=32000, ssm_state=64.  [arXiv:2411.15242; hf]

Hybrid: Mamba state + a few attention sites => long_500k RUNS
(sequence-sharded KV at the shared sites).
"""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    vocab=32000,
    n_heads=32,
    n_kv=32,
    head_dim=80,
    rope_theta=1e4,
    d_ff=10240,
    mlp_gated=True,
    ssm=SSMConfig(d_state=64, d_inner=5120, head_dim=64, n_groups=1,
                  d_conv=4, chunk=64),
    hybrid_period=6,
    norm_eps=1e-5,
    tie_embeddings=True,
    remat="full",
    microbatches=8,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, vocab=256,
        n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, mlp_gated=True,
        ssm=SSMConfig(d_state=16, d_inner=128, head_dim=32, n_groups=1,
                      d_conv=4, chunk=16),
        hybrid_period=2, tie_embeddings=True, remat="none")
