"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-32B; hf]

long_500k skipped: full quadratic attention (DESIGN.md §Arch-applicability).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    vocab=152064,
    n_heads=40,
    n_kv=40,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    d_ff=27392,
    mlp_gated=True,
    norm_eps=1e-6,
    remat="full",
    microbatches=16,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=4, head_dim=16, qkv_bias=True,
        d_ff=128, mlp_gated=True, norm_eps=1e-6, remat="none")
