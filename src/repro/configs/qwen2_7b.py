"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]

long_500k skipped: full quadratic attention.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    vocab=152064,
    n_heads=28,
    n_kv=4,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    d_ff=18944,
    mlp_gated=True,
    norm_eps=1e-6,
    remat="full",
    microbatches=4,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16, qkv_bias=True,
        d_ff=128, mlp_gated=True, norm_eps=1e-6, remat="none")
