"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating attention (window 4096 on even
layers), attn logit softcap 50, final logit softcap 30, pre+post sublayer
RMSNorms with (1+w) scaling, sqrt(d) embedding scale, tied embeddings,
query scale 1/sqrt(d_model/n_heads) = 1/12.  [arXiv:2408.00118; hf]

long_500k skipped: the global layers are quadratic.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    vocab=256000,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    rope_theta=1e4,
    window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,
    d_ff=36864,
    mlp_gated=True,
    norm_eps=1e-6,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    remat="full",
    microbatches=8,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b-smoke", family="dense",
        n_layers=4, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16,
        window=32, local_global_period=2,
        attn_softcap=50.0, final_softcap=30.0,
        query_scale=(64 / 4) ** -0.5,
        d_ff=128, mlp_gated=True, norm_eps=1e-6,
        post_norms=True, embed_scale=True, tie_embeddings=True,
        remat="none")
