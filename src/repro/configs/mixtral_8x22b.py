"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention (per the assignment
spec).  [arXiv:2401.04088; hf]

SWA everywhere => sub-quadratic => long_500k RUNS (rolling window cache).
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab=32768,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    rope_theta=1e6,
    window=4096,
    d_ff=16384,
    mlp_gated=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=16384, capacity_factor=1.25),
    norm_eps=1e-5,
    remat="full",
    microbatches=16,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16,
        window=32,
        d_ff=96, mlp_gated=True,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=96, capacity_factor=4.0),
        remat="none")
