"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 1536, head_dim 64 => 24 SSD heads, 1 B/C group.
Attention-free => long_500k RUNS for this arch.
"""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    d_ff=0,
    ssm=SSMConfig(d_state=128, d_inner=1536, head_dim=64, n_groups=1,
                  d_conv=4, chunk=128),
    norm_eps=1e-5,
    tie_embeddings=True,
    remat="full",
    microbatches=1,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256, d_ff=0,
        ssm=SSMConfig(d_state=16, d_inner=128, head_dim=32, n_groups=1,
                      d_conv=4, chunk=16),
        tie_embeddings=True, remat="none")
