"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama-3-70B-style) LM backbone.
[arXiv:2404.16821; unverified]

Per the assignment, the InternViT frontend is a STUB: ``input_specs()``
provides 256 precomputed patch embeddings [B, 256, d_model] that replace
the first positions (early fusion).  long_500k skipped: quadratic attention.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    vocab=128256,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    rope_theta=5e5,
    d_ff=28672,
    mlp_gated=True,
    norm_eps=1e-5,
    vision_tokens=256,
    remat="full",
    microbatches=16,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b-smoke", family="vlm",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, mlp_gated=True, vision_tokens=8, remat="none")
