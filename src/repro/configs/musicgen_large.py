"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 per codebook — decoder-only over EnCodec tokens, 4 codebooks
(delay pattern), plain GELU MLP.  [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: the backbone consumes
4 parallel codebook token streams ([B, S, 4] ids) and emits 4 heads.
long_500k skipped: quadratic attention.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    vocab=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    rope_theta=1e4,
    d_ff=8192,
    mlp_gated=False,
    n_codebooks=4,
    norm_eps=1e-5,
    remat="full",
    microbatches=8,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, mlp_gated=False, n_codebooks=4, remat="none")
