"""ProblemSpec registry — the single registration point for problem families.

The paper's core claim is *ease of use*: "transforming almost any recursive
backtracking algorithm into a parallel one" should be a registration, not a
plumbing project.  Before this module existed, adding a problem meant
touching a factory table in ``repro.problems``, the service's hard-coded
family names, a ``make_*_py`` naming convention and per-CLI instance
parsing.  Now a family is ONE call::

    @register_problem(
        "vc",
        parse=parse_graph_instance,            # "reg:48:4:1" -> Graph
        oracle=lambda g: make_vertex_cover_py(g),
        backends=("jnp", "pallas"),            # kernel capabilities
        pack=_pack_vc, family_id=FAMILY_VC,    # optional: service admission
    )
    def make_vertex_cover(graph, backend="jnp", ...):
        ...

which binds, per family name:

  * the engine factory (jnp :class:`~repro.core.api.BinaryProblem`, with its
    advertised kernel-backend capabilities — DESIGN.md §5.4);
  * the serial ``PyProblem`` oracle factory (ground-truth parity);
  * the instance-spec parser consumed by every launcher;
  * optionally, service packing (``pack(instance, n) -> (adj, fullm,
    family)`` plus the stacked-table family id) — registering these makes
    the family admissible to the multi-tenant :class:`SolverService`.

Every launcher (``repro.launch.solve`` / ``serve_solver`` /
``solver_dryrun``), the service driver and the :class:`repro.solver.Solver`
facade resolve problems exclusively through this registry, so they contain
zero per-problem branching or name tables (DESIGN.md §6).

Built-in families register themselves when ``repro.problems`` is imported;
lookups trigger that import lazily, so ``repro.registry`` itself stays
import-cycle-free and cheap to import.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = [
    "ProblemHandle",
    "ProblemSpec",
    "UnknownProblemError",
    "get",
    "instance_size",
    "names",
    "problem",
    "problem_backends",
    "register_problem",
]


class UnknownProblemError(KeyError):
    """Lookup of a problem family that was never registered."""


_REGISTRY: Dict[str, "ProblemSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """Everything the framework needs to know about one problem family.

    Attributes:
      name: registry key (also the launchers' ``--problem`` /
        ``SolveRequest.family`` value).
      factory: the engine-problem factory as registered (kept for direct,
        keyword-rich use; launchers go through :meth:`build`).
      builder: ``(instance, backend) -> BinaryProblem`` — the normalized
        construction path used by :meth:`build`.
      oracle: ``instance -> PyProblem`` — the serial reference factory.
      parse: ``instance-spec str -> instance`` — the family's CLI parser.
      backends: kernel backends the factory accepts (DESIGN.md §5.4); the
        capability surface validated by CLIs and :class:`repro.solver.Solver`.
      family_id: stacked-table family id (``repro.service.batch_problem``)
        when the family is servable, else None.
      pack: ``(instance, n) -> (adj, fullm, family)`` service packing, or
        None when the family cannot ride the stacked tables.
      size: ``instance -> int`` — instance size used for service admission
        (defaults to ``instance.n``).
      doc: one-line description shown in CLI help.
    """

    name: str
    factory: Callable[..., Any]
    builder: Callable[[Any, str], Any]
    oracle: Callable[[Any], Any]
    parse: Callable[[str], Any]
    backends: Tuple[str, ...] = ("jnp",)
    family_id: Optional[int] = None
    pack: Optional[Callable[[Any, int], Any]] = None
    size: Callable[[Any], int] = lambda instance: int(instance.n)
    doc: str = ""

    @property
    def servable(self) -> bool:
        """True when the family can be admitted to the solver service."""
        return self.pack is not None and self.family_id is not None

    def build(self, instance: Any, backend: str = "jnp") -> Any:
        """Build the engine ``BinaryProblem``, validating ``backend``."""
        if backend not in self.backends:
            raise ValueError(
                f"problem {self.name!r} does not support backend "
                f"{backend!r} (advertises: {', '.join(self.backends)})")
        return self.builder(instance, backend)

    def label(self, instance: Any) -> str:
        """Human-readable instance label for logs."""
        return str(getattr(instance, "name", instance))


@dataclasses.dataclass(frozen=True)
class ProblemHandle:
    """A (family, instance) pair — the facade's unit of work.

    Produced by :func:`problem`; consumed by
    :meth:`repro.solver.Solver.solve` / ``.oracle`` so one object carries
    both the engine form and the serial-oracle form of the same instance.
    """

    spec: ProblemSpec
    instance: Any

    def build(self, backend: str = "jnp") -> Any:
        return self.spec.build(self.instance, backend)

    def oracle(self) -> Any:
        return self.spec.oracle(self.instance)

    @property
    def label(self) -> str:
        return f"{self.spec.name}:{self.spec.label(self.instance)}"


def register_problem(name: str, *, parse: Callable[[str], Any],
                     oracle: Callable[[Any], Any],
                     backends: Tuple[str, ...] = ("jnp",),
                     build: Optional[Callable[..., Any]] = None,
                     pack: Optional[Callable[[Any, int], Any]] = None,
                     family_id: Optional[int] = None,
                     size: Optional[Callable[[Any], int]] = None,
                     doc: str = ""):
    """Decorator: register the decorated engine factory as family ``name``.

    ``build`` overrides how an instance + backend reach the factory (the
    default calls ``factory(instance, backend=backend)``, which fits every
    graph problem).  The decorator also stamps ``factory.backends`` so the
    pre-registry capability attribute (DESIGN.md §5.4) keeps working.
    """

    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"problem {name!r} registered twice")
        builder = build or (
            lambda instance, backend: factory(instance, backend=backend))
        kwargs: Dict[str, Any] = {}
        if size is not None:
            kwargs["size"] = size
        _REGISTRY[name] = ProblemSpec(
            name=name, factory=factory, builder=builder, oracle=oracle,
            parse=parse, backends=tuple(backends), family_id=family_id,
            pack=pack, doc=doc, **kwargs)
        factory.backends = tuple(backends)
        return factory

    return deco


def _ensure_builtins() -> None:
    # Built-in families live in repro.problems and self-register on import;
    # importing lazily here keeps registry <-> problems acyclic.
    import repro.problems  # noqa: F401


def get(name: str) -> ProblemSpec:
    """Registered spec for family ``name`` (raises UnknownProblemError)."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownProblemError(
            f"unknown problem family {name!r} (registered: "
            f"{', '.join(sorted(_REGISTRY))})") from None


def names() -> Tuple[str, ...]:
    """All registered family names, sorted."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def problem_backends(name: str) -> Tuple[str, ...]:
    """Kernel backends supported by registered family ``name``."""
    return get(name).backends


def instance_size(name: str, instance: Any) -> int:
    """Registered ``size()`` of ``instance`` under family ``name`` — the
    admission measure the service checks against ``max_n`` and the key the
    ``ShortestJobFirst`` scheduling policy orders by."""
    return int(get(name).size(instance))


def problem(name: str, instance: Any) -> ProblemHandle:
    """Resolve (family, instance) into a :class:`ProblemHandle`.

    ``instance`` may be the family's native instance object (e.g. a
    :class:`~repro.problems.graphs.Graph`) or an instance-spec string,
    which is parsed with the family's registered parser.
    """
    spec = get(name)
    if isinstance(instance, str):
        instance = spec.parse(instance)
    return ProblemHandle(spec=spec, instance=instance)
