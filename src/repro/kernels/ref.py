"""Pure-jnp oracles for every Pallas kernel (the allclose references).

flash_attention -> repro.models.attention.blocked_attention
ssd_scan        -> repro.models.ssm.ssd_chunked
bitset_degree   -> degree_stats / degree_argmax below (mirrors
                   problems.vertex_cover)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import blocked_attention as flash_attention_ref  # noqa: F401
from repro.models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, a, b, c, d, chunk: int = 64):
    return ssd_chunked(x, dt, a, b, c, d, chunk=chunk)


def degree_stats_ref(adj: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """adj uint32[n, w]; alive uint32[L, w] -> int32[L, 3] of
    (best_degree, best_vertex, degree_sum); (-1, -1, 0) when nothing is
    alive.  ``degree_sum`` = twice the residual edge count."""
    n, w = adj.shape

    def one(mask):
        rows = jnp.bitwise_and(adj, mask[None, :])
        degs = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
        vid = jnp.arange(n)
        word = vid // 32
        bit = (vid % 32).astype(jnp.uint32)
        is_alive = ((mask[word] >> bit) & jnp.uint32(1)) == jnp.uint32(1)
        degs = jnp.where(is_alive, degs, jnp.int32(-1))
        best = jnp.max(degs)
        arg = jnp.argmax(degs).astype(jnp.int32)   # first max = smallest id
        total = jnp.sum(jnp.maximum(degs, 0))
        return jnp.stack([best, jnp.where(best < 0, jnp.int32(-1), arg),
                          total])

    return jax.vmap(one)(alive)


def degree_argmax_ref(adj: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """adj uint32[n, w]; alive uint32[L, w] -> int32[L, 2]."""
    return degree_stats_ref(adj, alive)[:, :2]
