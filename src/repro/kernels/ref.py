"""Pure-jnp oracles for every Pallas kernel (the allclose references).

flash_attention -> repro.models.attention.blocked_attention
ssd_scan        -> repro.models.ssm.ssd_chunked
bitset_ops      -> count_stats / stacked_count_stats / popcount_reduce /
                   masked_row_reduce / domination_stats below
                   (DESIGN.md §5.2's contract, stated in plain jnp)
bitset_degree   -> degree_stats / degree_argmax below (mirrors
                   problems.vertex_cover)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import blocked_attention as flash_attention_ref  # noqa: F401
from repro.models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, a, b, c, d, chunk: int = 64):
    return ssd_chunked(x, dt, a, b, c, d, chunk=chunk)


def _bit_set(mask: jnp.ndarray, n: int) -> jnp.ndarray:
    """bool[n]: is bit v of the packed uint32[w] mask set?"""
    vid = jnp.arange(n)
    word = vid // 32
    bit = (vid % 32).astype(jnp.uint32)
    return ((mask[word] >> bit) & jnp.uint32(1)) == jnp.uint32(1)


def _count_stats_one(table: jnp.ndarray, mask: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    n = table.shape[0]
    rows = jnp.bitwise_and(table, mask[None, :])
    cnts = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
    cnts = jnp.where(_bit_set(valid, n), cnts, jnp.int32(-1))
    best = jnp.max(cnts)
    arg = jnp.argmax(cnts).astype(jnp.int32)     # first max = smallest id
    total = jnp.sum(jnp.maximum(cnts, 0))
    mcount = jax.lax.population_count(mask).sum().astype(jnp.int32)
    return jnp.stack([best, jnp.where(best < 0, jnp.int32(-1), arg),
                      total, mcount])


def count_stats_ref(table: jnp.ndarray, mask: jnp.ndarray,
                    valid: jnp.ndarray) -> jnp.ndarray:
    """table uint32[n, w]; mask/valid uint32[L, w] -> int32[L, 4] per the
    masked-popcount contract (DESIGN.md §5.2)."""
    return jax.vmap(lambda m, v: _count_stats_one(table, m, v))(mask, valid)


def stacked_count_stats_ref(tables: jnp.ndarray, inst: jnp.ndarray,
                            mask: jnp.ndarray,
                            valid: jnp.ndarray) -> jnp.ndarray:
    """tables uint32[K, n, w]; inst int32[L]; mask/valid uint32[L, w] ->
    int32[L, 4], lane l reduced against tables[inst[l]].  Idle lanes
    (inst < 0, the service's NO_INSTANCE) are parked: their masks are
    zeroed so they return the no-valid row (-1, -1, 0, 0) instead of
    being clipped onto instance 0's table."""
    k = tables.shape[0]
    inst = inst.astype(jnp.int32)
    idle = inst < 0
    mask = jnp.where(idle[:, None], jnp.uint32(0), mask)
    valid = jnp.where(idle[:, None], jnp.uint32(0), valid)
    inst = jnp.clip(inst, 0, k - 1)
    return jax.vmap(
        lambda i, m, v: _count_stats_one(tables[i], m, v))(inst, mask, valid)


def popcount_reduce_ref(rows: jnp.ndarray) -> jnp.ndarray:
    """uint32[L, w] -> int32[L]."""
    return jax.lax.population_count(rows).sum(axis=-1).astype(jnp.int32)


def masked_row_reduce_ref(table: jnp.ndarray, select: jnp.ndarray, *,
                          op: str = "or") -> jnp.ndarray:
    """table uint32[n, w]; select uint32[L, w] -> uint32[L, w]: OR/AND of
    the selected rows (identity for an empty selection)."""
    if op not in ("or", "and"):
        raise ValueError(f"unknown reduce op {op!r}")
    n = table.shape[0]
    ident = jnp.uint32(0) if op == "or" else jnp.uint32(0xFFFFFFFF)
    bitop = jnp.bitwise_or if op == "or" else jnp.bitwise_and

    def one(sel):
        rows = jnp.where(_bit_set(sel, n)[:, None], table, ident)
        return jax.lax.reduce(rows, ident, bitop, (0,))

    return jax.vmap(one)(select)


def domination_stats_ref(cadj: jnp.ndarray, dominated: jnp.ndarray,
                         cand: jnp.ndarray, fullm: jnp.ndarray) -> jnp.ndarray:
    """Dominating set's (best_coverage, branch_vertex, undominated) in plain
    jnp — the oracle for ``bitset_ops.domination_stats``."""
    mask = jnp.bitwise_and(fullm[None, :], jnp.bitwise_not(dominated))
    out = count_stats_ref(cadj, mask, cand)
    return jnp.stack([out[:, 0], out[:, 1], out[:, 3]], axis=1)


def degree_stats_ref(adj: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """adj uint32[n, w]; alive uint32[L, w] -> int32[L, 3] of
    (best_degree, best_vertex, degree_sum); (-1, -1, 0) when nothing is
    alive.  ``degree_sum`` = twice the residual edge count."""
    n, w = adj.shape

    def one(mask):
        rows = jnp.bitwise_and(adj, mask[None, :])
        degs = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
        vid = jnp.arange(n)
        word = vid // 32
        bit = (vid % 32).astype(jnp.uint32)
        is_alive = ((mask[word] >> bit) & jnp.uint32(1)) == jnp.uint32(1)
        degs = jnp.where(is_alive, degs, jnp.int32(-1))
        best = jnp.max(degs)
        arg = jnp.argmax(degs).astype(jnp.int32)   # first max = smallest id
        total = jnp.sum(jnp.maximum(degs, 0))
        return jnp.stack([best, jnp.where(best < 0, jnp.int32(-1), arg),
                          total])

    return jax.vmap(one)(alive)


def degree_argmax_ref(adj: jnp.ndarray, alive: jnp.ndarray) -> jnp.ndarray:
    """adj uint32[n, w]; alive uint32[L, w] -> int32[L, 2]."""
    return degree_stats_ref(adj, alive)[:, :2]
