"""jit'd dispatch wrappers for the Pallas kernels.

On TPU (``jax.default_backend() == "tpu"``) the Pallas kernels compile
natively; elsewhere the pure-jnp oracles run (CPU smoke/benchmarks) and
``interpret=True`` executes the kernel bodies for correctness tests.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitset_degree import degree_argmax as _degree_pallas
from repro.kernels.bitset_degree import degree_stats as _degree_stats_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window", "softcap", "query_scale",
                                   "block_q", "block_k", "use_pallas",
                                   "interpret"))
def flash_attention(q, k, v, *, window: Optional[int] = None,
                    softcap: float = 0.0,
                    query_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_pallas(q, k, v, window=window, softcap=softcap,
                             query_scale=query_scale, block_q=block_q,
                             block_k=block_k,
                             interpret=(not _on_tpu()) if interpret is None
                             else interpret)
    return ref.flash_attention_ref(q, k, v, window=window, softcap=softcap,
                                   query_scale=query_scale,
                                   block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_scan(x, dt, a, b, c, d, *, chunk: int = 64,
             use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _ssd_pallas(x, dt, a, b, c, d, chunk=chunk,
                           interpret=(not _on_tpu()) if interpret is None
                           else interpret)
    return ref.ssd_scan_ref(x, dt, a, b, c, d, chunk=chunk)


@partial(jax.jit, static_argnames=("tile", "use_pallas", "interpret"))
def degree_stats(adj, alive, *, tile: int = 128,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """(best_degree, best_vertex, degree_sum) per lane — the fused
    vertex-cover node statistics (see problems.vertex_cover)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _degree_stats_pallas(adj, alive, tile=tile,
                                    interpret=(not _on_tpu()) if interpret
                                    is None else interpret)
    return ref.degree_stats_ref(adj, alive)


@partial(jax.jit, static_argnames=("tile", "use_pallas", "interpret"))
def degree_argmax(adj, alive, *, tile: int = 128,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _degree_pallas(adj, alive, tile=tile,
                              interpret=(not _on_tpu()) if interpret is None
                              else interpret)
    return ref.degree_argmax_ref(adj, alive)
