"""jit'd dispatch wrappers for the Pallas kernels.

On TPU (``jax.default_backend() == "tpu"``) the Pallas kernels compile
natively; elsewhere the pure-jnp oracles run (CPU smoke/benchmarks) and
``interpret=True`` executes the kernel bodies for correctness tests.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import bitset_ops, ref
from repro.kernels.bitset_degree import degree_argmax as _degree_pallas
from repro.kernels.bitset_degree import degree_stats as _degree_stats_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window", "softcap", "query_scale",
                                   "block_q", "block_k", "use_pallas",
                                   "interpret"))
def flash_attention(q, k, v, *, window: Optional[int] = None,
                    softcap: float = 0.0,
                    query_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_pallas(q, k, v, window=window, softcap=softcap,
                             query_scale=query_scale, block_q=block_q,
                             block_k=block_k,
                             interpret=(not _on_tpu()) if interpret is None
                             else interpret)
    return ref.flash_attention_ref(q, k, v, window=window, softcap=softcap,
                                   query_scale=query_scale,
                                   block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_scan(x, dt, a, b, c, d, *, chunk: int = 64,
             use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _ssd_pallas(x, dt, a, b, c, d, chunk=chunk,
                           interpret=(not _on_tpu()) if interpret is None
                           else interpret)
    return ref.ssd_scan_ref(x, dt, a, b, c, d, chunk=chunk)


@partial(jax.jit, static_argnames=("tile", "stages", "use_pallas",
                                   "interpret"))
def degree_stats(adj, alive, *, tile: Optional[int] = None,
                 stages: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """(best_degree, best_vertex, degree_sum) per lane — the fused
    vertex-cover node statistics (see problems.vertex_cover)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _degree_stats_pallas(adj, alive, tile=tile, stages=stages,
                                    interpret=interpret)
    return ref.degree_stats_ref(adj, alive)


@partial(jax.jit, static_argnames=("tile", "stages", "use_pallas",
                                   "interpret"))
def degree_argmax(adj, alive, *, tile: Optional[int] = None,
                  stages: Optional[int] = None,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _degree_pallas(adj, alive, tile=tile, stages=stages,
                              interpret=interpret)
    return ref.degree_argmax_ref(adj, alive)


def _dispatch(pallas_fn, ref_fn, args, *, use_pallas, interpret,
              kernel_kw=None, ref_kw=None):
    """Shared backend resolution for the bitset_ops dispatchers: Pallas on
    TPU (or when forced), jnp oracle elsewhere; ``interpret=None`` is
    resolved by the kernel itself (compiled on TPU, interpret off-TPU)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return pallas_fn(*args, interpret=interpret, **(kernel_kw or {}))
    return ref_fn(*args, **(ref_kw or {}))


@partial(jax.jit, static_argnames=("tile", "stages", "use_pallas",
                                   "interpret"))
def count_stats(table, mask, valid, *, tile: Optional[int] = None,
                stages: Optional[int] = None,
                use_pallas: Optional[bool] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """The universal masked-popcount pass (DESIGN.md §5.2):
    (best_count, best_vertex, count_sum, mask_count) per lane.
    ``tile``/``stages`` default to the autotuner (DESIGN.md §5.6)."""
    return _dispatch(bitset_ops.count_stats, ref.count_stats_ref,
                     (table, mask, valid), use_pallas=use_pallas,
                     interpret=interpret,
                     kernel_kw={"tile": tile, "stages": stages})


@partial(jax.jit, static_argnames=("tile", "stages", "use_pallas",
                                   "interpret"))
def stacked_count_stats(tables, inst, mask, valid, *,
                        tile: Optional[int] = None,
                        stages: Optional[int] = None,
                        use_pallas: Optional[bool] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Batched uint32[K, n, w] masked-popcount pass (DESIGN.md §5.3) —
    each lane reduced against its instance's table; idle (inst < 0)
    lanes park on the (-1, -1, 0, 0) row."""
    return _dispatch(bitset_ops.stacked_count_stats,
                     ref.stacked_count_stats_ref,
                     (tables, inst, mask, valid), use_pallas=use_pallas,
                     interpret=interpret,
                     kernel_kw={"tile": tile, "stages": stages})


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def popcount_reduce(rows, *, use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """uint32[L, w] -> int32[L] packed-set cardinalities."""
    return _dispatch(bitset_ops.popcount_reduce, ref.popcount_reduce_ref,
                     (rows,), use_pallas=use_pallas, interpret=interpret)


@partial(jax.jit, static_argnames=("op", "tile", "use_pallas", "interpret"))
def masked_row_reduce(table, select, *, op: str = "or", tile: int = 128,
                      use_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """OR/AND-accumulate of table rows selected by a bitset."""
    return _dispatch(bitset_ops.masked_row_reduce, ref.masked_row_reduce_ref,
                     (table, select), use_pallas=use_pallas,
                     interpret=interpret,
                     kernel_kw={"op": op, "tile": tile}, ref_kw={"op": op})


@partial(jax.jit, static_argnames=("tile", "stages", "use_pallas",
                                   "interpret"))
def domination_stats(cadj, dominated, cand, fullm, *,
                     tile: Optional[int] = None,
                     stages: Optional[int] = None,
                     use_pallas: Optional[bool] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """(best_coverage, branch_vertex, undominated) per lane — the fused
    dominating-set node statistics (see problems.dominating_set)."""
    return _dispatch(bitset_ops.domination_stats, ref.domination_stats_ref,
                     (cadj, dominated, cand, fullm), use_pallas=use_pallas,
                     interpret=interpret,
                     kernel_kw={"tile": tile, "stages": stages})
