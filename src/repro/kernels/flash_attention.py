"""Pallas TPU flash attention (blocked online softmax, causal/SWA/softcap).

TPU-native adaptation (not a CUDA port): the kernel is expressed over a
``(batch*kv_head, q_block, kv_block)`` grid where the *last* axis is
sequential on TPU — the running (max, denom, accum) state lives in VMEM
scratch across kv-block steps, and the output block is written once on the
final step.  Block shapes are multiples of (128, 128) so the QK^T and PV
contractions land on the MXU; masks are built from 2-D iotas (TPU requires
>=2-D iota).

Validated on CPU via ``interpret=True`` against the pure-jnp oracle
(repro.models.attention.blocked_attention re-exported in ref.py); selected
at runtime by ops.flash_attention(use_pallas=...).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float, window: Optional[int],
            block_q: int, block_k: int, seq_len: int, r: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                               # [r*block_q, hd]
    k = k_ref[0]                                  # [block_k, hd]
    v = v_ref[0]                                  # [block_k, hd]

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [r*bq, bk]
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    # Positions: q rows are r repeats of block_q query positions.
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    q_pos = qi * block_q + rows % block_q
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                           # [r*bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    window: Optional[int] = None, softcap: float = 0.0,
                    query_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, S, H, hd]; k, v: [B, S, G, hd] -> [B, S, H, hd].

    The GQA group dim folds into the q block: each grid cell handles one
    (batch, kv-head) pair with r = H // G query heads stacked block-wise.
    """
    b, s, h, hd = q.shape
    g = k.shape[2]
    r = h // g
    scale = query_scale if query_scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k

    # Layout: fold (B, G) into the grid's first axis; queries as
    # [B*G, nq, r*block_q, hd] so one q block covers all r group heads.
    qf = (q.reshape(b, s, g, r, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * g, r, s, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(b * g, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * g, s, hd)

    def kv_index(bg, qi, kj):
        return (bg, kj, 0)

    # Queries pre-arranged as [B*G, nq, r*block_q, hd]: one VMEM q block
    # covers all r heads of the group (keeps the MXU M-dim >= 128 even for
    # small block_q).
    qf2 = (qf.reshape(b * g, r, nq, block_q, hd).transpose(0, 2, 1, 3, 4)
           .reshape(b * g, nq, r * block_q, hd))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          window=window, block_q=block_q, block_k=block_k,
                          seq_len=s, r=r),
        grid=(b * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, r * block_q, hd),
                         lambda bg, qi, kj: (bg, qi, 0, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, r * block_q, hd),
                               lambda bg, qi, kj: (bg, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * g, nq, r * block_q, hd),
                                       q.dtype),
        scratch_shapes=[
            pltpu.VMEM((r * block_q, 1), jnp.float32),
            pltpu.VMEM((r * block_q, 1), jnp.float32),
            pltpu.VMEM((r * block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf2, kf, vf)

    # out: [B*G, nq, r*block_q, hd] -> [B, S, H, hd]
    o = (out.reshape(b, g, nq, r, block_q, hd).transpose(0, 2, 4, 1, 3, 5)
         .reshape(b, s, h, hd))
    return o
