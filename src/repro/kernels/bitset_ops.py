"""Universal Pallas bitset-kernel library (DESIGN.md §5).

Every problem family in this repo funnels its per-search-node work through
one shape: a ``uint32[n, w]`` table of packed bitset rows (adjacency for
vertex cover, closed neighborhoods for dominating set, one table per slot
for the stacked service), ANDed against a per-lane ``uint32[w]`` mask,
popcounted per row, and reduced to a handful of scalars (max count,
argmax with smallest-id tie-break, count sum, mask popcount).  This module
is that machinery ONCE, as a small kernel library every problem binds to
instead of forking its own kernel:

  ``count_stats``         — THE masked-popcount pass over one table
                            (DESIGN.md §5.2: the contract);
  ``stacked_count_stats`` — the batched ``uint32[K, n, w]`` variant for the
                            multi-tenant service: each lane's table is
                            selected by its instance id via scalar
                            prefetch (DESIGN.md §5.3);
  ``popcount_reduce``     — per-row popcount sum (set cardinalities);
  ``masked_row_reduce``   — OR/AND-accumulate of table rows selected by a
                            bitset (e.g. neighborhoods of a chosen set).

Problem bindings (DESIGN.md §5.4): ``bitset_degree.degree_stats`` (vertex
cover) and ``domination_stats`` (dominating set) below are thin argument
adapters over ``count_stats``; ``service/batch_problem.py`` binds
``stacked_count_stats`` directly.  Grid/block choices, memory spaces and
the determinism rules are documented in DESIGN.md §5.1 — in short: grid
``(lanes, vertex_tiles)`` with the tile axis innermost/sequential so a
``(1, ·)`` output block accumulates in VMEM, ascending tile order plus a
strict ``>`` update for the paper's smallest-id tie-break, and
``jax.lax.population_count`` on uint32 words (VPU bitwise ops, no MXU).

Validated with ``interpret=True`` against the jnp oracles in ``ref.py``
and the numpy oracles in ``tests/test_bitset_ops.py``; ``vmap`` over lane
operands (as the engine applies per-lane ``evaluate``) lifts the lane axis
into the kernel grid, scalar-prefetch operands included.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Column layout of the ``count_stats`` / ``stacked_count_stats`` output —
#: the whole per-node reduction that leaves VMEM (DESIGN.md §5.2).
BEST, ARG, SUM, MASK_COUNT = 0, 1, 2, 3


def _valid_bits(mask_row: jnp.ndarray, base: int, tile: int, n: int):
    """bool[tile]: is bit ``base + i`` of ``mask_row`` (uint32[w]) set, for
    a real vertex (``vid < n``)?  The per-tile membership test shared by
    every kernel below."""
    vid = base + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    word_ix = vid // 32
    bit_ix = (vid % 32).astype(jnp.uint32)
    row = jnp.take(mask_row, word_ix, axis=0)
    return (((row >> bit_ix) & jnp.uint32(1)) == jnp.uint32(1)) & (vid < n)


# ---------------------------------------------------------------------------
# count_stats: the masked-popcount contract (DESIGN.md §5.2)
# ---------------------------------------------------------------------------

def _count_stats_body(table, mask_ref, valid_ref, out_ref, *,
                      tile: int, n: int):
    """Shared kernel body; ``table`` is the loaded [tile, w] block."""
    t = pl.program_id(1)
    neg = jnp.int32(-1)
    mask = mask_ref[...]                         # [1, w] uint32

    @pl.when(t == 0)
    def _init():
        out_ref[0, BEST] = neg                   # max count (-1: none valid)
        out_ref[0, ARG] = neg                    # its vertex id
        out_ref[0, SUM] = jnp.int32(0)           # Σ max(count, 0)
        out_ref[0, MASK_COUNT] = jax.lax.population_count(
            mask).astype(jnp.int32).sum()        # |mask| (e.g. undominated)

    rows = jnp.bitwise_and(table, mask)          # [tile, w]
    cnts = jax.lax.population_count(rows).astype(jnp.int32).sum(axis=1)
    base = t * tile
    cnts = jnp.where(_valid_bits(valid_ref[...][0], base, tile, n),
                     cnts, neg)

    tile_best = jnp.max(cnts)
    tile_arg = base + jnp.argmax(cnts).astype(jnp.int32)
    best = out_ref[0, BEST]
    better = tile_best > best                    # strict: earlier tile wins
    out_ref[0, BEST] = jnp.where(better, tile_best, best)
    out_ref[0, ARG] = jnp.where(better, tile_arg, out_ref[0, ARG])
    out_ref[0, SUM] = out_ref[0, SUM] + jnp.sum(jnp.maximum(cnts, 0))


def _pad_rows(table: jnp.ndarray, tile: int) -> jnp.ndarray:
    pad = (-table.shape[-2]) % tile
    if pad:
        width = [(0, 0)] * (table.ndim - 2) + [(0, pad), (0, 0)]
        table = jnp.pad(table, width)
    return table


def count_stats(table: jnp.ndarray, mask: jnp.ndarray, valid: jnp.ndarray,
                *, tile: int = 128, interpret: bool = True) -> jnp.ndarray:
    """The masked-popcount pass (DESIGN.md §5.2).

    ``table``: uint32[n, w] packed bitset rows; ``mask``/``valid``:
    uint32[L, w] per-lane masks.  Returns int32[L, 4] =
    ``(best_count, best_vertex, count_sum, mask_count)`` where
    ``count[v] = popcount(table[v] & mask)`` for vertices whose bit is set
    in ``valid`` (all others count -1), ``best_vertex`` breaks ties toward
    the smallest id (-1 when nothing is valid), ``count_sum`` is
    ``Σ max(count, 0)`` and ``mask_count = popcount(mask)``.
    """
    n, w = table.shape
    lanes = mask.shape[0]
    table = _pad_rows(table, tile)
    tiles = table.shape[0] // tile

    def kernel(table_ref, mask_ref, valid_ref, out_ref):
        _count_stats_body(table_ref[...], mask_ref, valid_ref, out_ref,
                          tile=tile, n=n)

    return pl.pallas_call(
        kernel,
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((tile, w), lambda l, t: (t, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda l, t: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.int32),
        interpret=interpret,
    )(table, mask, valid)


# ---------------------------------------------------------------------------
# stacked_count_stats: the batched uint32[K, n, w] variant (DESIGN.md §5.3)
# ---------------------------------------------------------------------------

def _stacked_kernel(inst_ref, tables_ref, mask_ref, valid_ref, out_ref, *,
                    tile: int, n: int):
    del inst_ref                                  # consumed by the index map
    _count_stats_body(tables_ref[0], mask_ref, valid_ref, out_ref,
                      tile=tile, n=n)


def stacked_count_stats(tables: jnp.ndarray, inst: jnp.ndarray,
                        mask: jnp.ndarray, valid: jnp.ndarray, *,
                        tile: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    """``count_stats`` over stacked tables: uint32[K, n, w] + int32[L]
    instance ids -> int32[L, 4], lane ``l`` reduced against
    ``tables[inst[l]]``.

    ``inst`` is a scalar-prefetch operand (DESIGN.md §5.3): the table
    BlockSpec's index map reads it, so each grid step DMAs exactly ONE
    instance's ``(tile, w)`` block into VMEM — the kernel never sees the
    other K-1 tables, and table traffic is independent of K.  Out-of-range
    ids are clipped (the service parks idle lanes on ``NO_INSTANCE`` = -1).
    """
    k, n, w = tables.shape
    lanes = mask.shape[0]
    inst = jnp.clip(inst.astype(jnp.int32), 0, k - 1)
    tables = _pad_rows(tables, tile)
    tiles = tables.shape[1] // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((1, tile, w),
                         lambda l, t, inst_ref: (inst_ref[l], t, 0)),
            pl.BlockSpec((1, w), lambda l, t, inst_ref: (l, 0)),
            pl.BlockSpec((1, w), lambda l, t, inst_ref: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda l, t, inst_ref: (l, 0)),
    )
    return pl.pallas_call(
        functools.partial(_stacked_kernel, tile=tile, n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.int32),
        interpret=interpret,
    )(inst, tables, mask, valid)


# ---------------------------------------------------------------------------
# popcount_reduce: per-lane set cardinalities
# ---------------------------------------------------------------------------

def _popcount_kernel(rows_ref, out_ref):
    out_ref[0, 0] = jax.lax.population_count(
        rows_ref[...]).astype(jnp.int32).sum()


def popcount_reduce(rows: jnp.ndarray, *,
                    interpret: bool = True) -> jnp.ndarray:
    """uint32[L, w] -> int32[L]: popcount of each packed row (set sizes)."""
    lanes, w = rows.shape
    out = pl.pallas_call(
        _popcount_kernel,
        grid=(lanes,),
        in_specs=[pl.BlockSpec((1, w), lambda l: (l, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 1), jnp.int32),
        interpret=interpret,
    )(rows)
    return out[:, 0]


# ---------------------------------------------------------------------------
# masked_row_reduce: OR/AND-accumulate of selected table rows
# ---------------------------------------------------------------------------

def _row_reduce_kernel(table_ref, sel_ref, out_ref, *, tile: int, n: int,
                       op: str):
    t = pl.program_id(1)
    ident = jnp.uint32(0) if op == "or" else jnp.uint32(0xFFFFFFFF)
    bitop = jnp.bitwise_or if op == "or" else jnp.bitwise_and

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], ident)

    selected = _valid_bits(sel_ref[...][0], t * tile, tile, n)
    rows = jnp.where(selected[:, None], table_ref[...], ident)  # [tile, w]
    while rows.shape[0] > 1:                     # static log2 tree reduce
        half = rows.shape[0] // 2
        rows = bitop(rows[:half], rows[half:half * 2])
    out_ref[...] = bitop(out_ref[...], rows)


def masked_row_reduce(table: jnp.ndarray, select: jnp.ndarray, *,
                      op: str = "or", tile: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """Bitwise OR (or AND) of the rows of ``table`` (uint32[n, w]) whose
    bit is set in ``select`` (uint32[L, w]) -> uint32[L, w].  The OR form
    with an adjacency table is ``N(S)`` for the selected set S; the AND
    form intersects constraint rows.  Empty selection yields the identity
    (all-zeros / all-ones)."""
    if op not in ("or", "and"):
        raise ValueError(f"unknown reduce op {op!r}")
    n, w = table.shape
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    lanes = select.shape[0]
    table = _pad_rows(table, tile)
    tiles = table.shape[0] // tile
    return pl.pallas_call(
        functools.partial(_row_reduce_kernel, tile=tile, n=n, op=op),
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((tile, w), lambda l, t: (t, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, w), jnp.uint32),
        interpret=interpret,
    )(table, select)


# ---------------------------------------------------------------------------
# problem-facing bindings (DESIGN.md §5.4)
# ---------------------------------------------------------------------------

def domination_stats(cadj: jnp.ndarray, dominated: jnp.ndarray,
                     cand: jnp.ndarray, fullm: jnp.ndarray, *,
                     tile: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Dominating set's node statistics as a ``count_stats`` binding:
    mask = the undominated set, valid = the candidate set.  ``cadj``:
    uint32[n, w] CLOSED adjacency; ``dominated``/``cand``: uint32[L, w];
    ``fullm``: uint32[w] real-vertex mask.  Returns int32[L, 3] =
    ``(best_coverage, branch_vertex, undominated)`` — coverage is
    ``|N[v] \\ dominated|`` per candidate, the tie-break is smallest-id and
    ``undominated`` comes free as the pass's mask popcount."""
    mask = jnp.bitwise_and(fullm[None, :], jnp.bitwise_not(dominated))
    out = count_stats(cadj, mask, cand, tile=tile, interpret=interpret)
    return jnp.stack([out[:, BEST], out[:, ARG], out[:, MASK_COUNT]], axis=1)
