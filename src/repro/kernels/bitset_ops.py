"""Universal Pallas bitset-kernel library (DESIGN.md §5).

Every problem family in this repo funnels its per-search-node work through
one shape: a ``uint32[n, w]`` table of packed bitset rows (adjacency for
vertex cover, closed neighborhoods for dominating set, one table per slot
for the stacked service), ANDed against a per-lane ``uint32[w]`` mask,
popcounted per row, and reduced to a handful of scalars (max count,
argmax with smallest-id tie-break, count sum, mask popcount).  This module
is that machinery ONCE, as a small kernel library every problem binds to
instead of forking its own kernel:

  ``count_stats``         — THE masked-popcount pass over one table
                            (DESIGN.md §5.2: the contract);
  ``stacked_count_stats`` — the batched ``uint32[K, n, w]`` variant for the
                            multi-tenant service (DESIGN.md §5.3);
  ``popcount_reduce``     — per-row popcount sum (set cardinalities);
  ``masked_row_reduce``   — OR/AND-accumulate of table rows selected by a
                            bitset (e.g. neighborhoods of a chosen set).

Two kernel layouts implement the contract (selected by ``stages``, chosen
per shape by ``repro.kernels.autotune`` when left ``None``):

  stages=2 — SPLIT-PHASE (DESIGN.md §5.5, the production path): stage 1
             is a grid over vertex tile-blocks only, every lane batched
             inside the block body, writing per-block partial stats to a
             ``[blocks, L, 4]`` scratch; stage 2 is one small combine
             kernel whose cross-block argmax keeps the smallest-id
             tie-break (block args ascend with block index, so
             ``min(arg | partial best == global best)`` is exact).  No
             sequential grid axis, no ``@pl.when`` init/accumulate
             dependency — every stage-1 step is independent.
  stages=1 — the legacy grid ``(lanes, tiles)`` with the tile axis
             innermost/sequential accumulating into a ``(1, 4)`` block
             (kept as the cross-check and for degenerate shapes).

``interpret=None`` (the default) auto-detects the platform: compiled on
TPU, interpret fallback elsewhere — the same rule as ``ops.py`` dispatch.
Validated against the jnp oracles in ``ref.py`` and the numpy oracles in
``tests/test_bitset_ops.py`` / ``tests/test_split_phase.py``; ``vmap``
over lane operands (as the engine applies per-lane ``evaluate``) lifts
the lane axis into the kernel grid for either layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Column layout of the ``count_stats`` / ``stacked_count_stats`` output —
#: the whole per-node reduction that leaves VMEM (DESIGN.md §5.2).
BEST, ARG, SUM, MASK_COUNT = 0, 1, 2, 3


def _auto_interpret(interpret: Optional[bool]) -> bool:
    """Platform default: compiled on TPU, interpret everywhere else."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _validate_tile(tile: int, stages: int) -> None:
    """The ISSUE-6 tile contract: positive everywhere; power-of-two where
    the split-phase combine requires it (block args must ascend uniformly
    for the smallest-id tie-break arithmetic)."""
    if stages not in (1, 2):
        raise ValueError(f"stages must be 1 or 2, got {stages!r}")
    if not isinstance(tile, int) or isinstance(tile, bool) or tile < 1:
        raise ValueError(f"tile must be a positive int, got {tile!r}")
    if stages == 2 and tile & (tile - 1):
        raise ValueError(
            f"tile must be a power of two for the split-phase (stages=2) "
            f"kernels, got {tile}")


def _resolve_shape(n: int, w: int, lanes: int, k: int,
                   tile: Optional[int], stages: Optional[int]):
    """Fill unset (tile, stages) from the per-shape autotuner cache."""
    if tile is None or stages is None:
        from repro.kernels import autotune
        choice = autotune.choose(n, w, lanes=lanes, k=k)
        tile = choice.tile if tile is None else tile
        stages = choice.stages if stages is None else stages
    return tile, stages


def _valid_bits(mask_row: jnp.ndarray, base, tile: int, n: int):
    """bool[tile]: is bit ``base + i`` of ``mask_row`` (uint32[w]) set, for
    a real vertex (``vid < n``)?  The per-tile membership test shared by
    the sequential kernels."""
    vid = base + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    word_ix = vid // 32
    bit_ix = (vid % 32).astype(jnp.uint32)
    row = jnp.take(mask_row, word_ix, axis=0)
    return (((row >> bit_ix) & jnp.uint32(1)) == jnp.uint32(1)) & (vid < n)


def _valid_bits_batch(valid: jnp.ndarray, base, tile: int, n: int):
    """bool[L, tile]: the batched-lane form of ``_valid_bits`` used by the
    split-phase stage-1 body (``valid`` is the whole uint32[L, w] block)."""
    vid = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    word_ix = vid[0] // 32
    bit_ix = (vid % 32).astype(jnp.uint32)           # [1, tile]
    rows = jnp.take(valid, word_ix, axis=1)          # [L, tile]
    return (((rows >> bit_ix) & jnp.uint32(1)) == jnp.uint32(1)) & (vid < n)


def _pad_rows(table: jnp.ndarray, tile: int) -> jnp.ndarray:
    pad = (-table.shape[-2]) % tile
    if pad:
        width = [(0, 0)] * (table.ndim - 2) + [(0, pad), (0, 0)]
        table = jnp.pad(table, width)
    return table


# ---------------------------------------------------------------------------
# count_stats: the masked-popcount contract (DESIGN.md §5.2)
# ---------------------------------------------------------------------------

def _count_stats_body(table, mask_ref, valid_ref, out_ref, *,
                      tile: int, n: int):
    """stages=1 kernel body; ``table`` is the loaded [tile, w] block."""
    t = pl.program_id(1)
    neg = jnp.int32(-1)
    mask = mask_ref[...]                         # [1, w] uint32

    @pl.when(t == 0)
    def _init():
        out_ref[0, BEST] = neg                   # max count (-1: none valid)
        out_ref[0, ARG] = neg                    # its vertex id
        out_ref[0, SUM] = jnp.int32(0)           # Σ max(count, 0)
        out_ref[0, MASK_COUNT] = jax.lax.population_count(
            mask).astype(jnp.int32).sum()        # |mask| (e.g. undominated)

    rows = jnp.bitwise_and(table, mask)          # [tile, w]
    cnts = jax.lax.population_count(rows).astype(jnp.int32).sum(axis=1)
    base = t * tile
    cnts = jnp.where(_valid_bits(valid_ref[...][0], base, tile, n),
                     cnts, neg)

    tile_best = jnp.max(cnts)
    tile_arg = base + jnp.argmax(cnts).astype(jnp.int32)
    best = out_ref[0, BEST]
    better = tile_best > best                    # strict: earlier tile wins
    out_ref[0, BEST] = jnp.where(better, tile_best, best)
    out_ref[0, ARG] = jnp.where(better, tile_arg, out_ref[0, ARG])
    out_ref[0, SUM] = out_ref[0, SUM] + jnp.sum(jnp.maximum(cnts, 0))


def _count_stats_seq(table, mask, valid, *, tile: int, n: int,
                     interpret: bool) -> jnp.ndarray:
    """stages=1: the legacy sequential-accumulate grid (lanes, tiles)."""
    w = table.shape[1]
    lanes = mask.shape[0]
    table = _pad_rows(table, tile)
    tiles = table.shape[0] // tile

    def kernel(table_ref, mask_ref, valid_ref, out_ref):
        _count_stats_body(table_ref[...], mask_ref, valid_ref, out_ref,
                          tile=tile, n=n)

    return pl.pallas_call(
        kernel,
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((tile, w), lambda l, t: (t, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda l, t: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.int32),
        interpret=interpret,
    )(table, mask, valid)


def _partial_stats(table, mask, valid, base, *, tile: int, n: int):
    """Split-phase stage-1 math: stats of one [tile, w] block against ALL
    lanes at once.  ``table`` [tile, w]; ``mask``/``valid`` [L, w];
    returns int32[L, 4] with block-local best/arg (arg already offset by
    ``base``) and the block's partial count sum.  ``mask_count`` is the
    full popcount(mask) — block-invariant, combined with max."""
    rows = jnp.bitwise_and(table[None, :, :], mask[:, None, :])  # [L,tile,w]
    cnts = jax.lax.population_count(rows).astype(jnp.int32).sum(axis=2)
    cnts = jnp.where(_valid_bits_batch(valid, base, tile, n),
                     cnts, jnp.int32(-1))
    best = jnp.max(cnts, axis=1)
    arg = base + jnp.argmax(cnts, axis=1).astype(jnp.int32)
    arg = jnp.where(best < 0, jnp.int32(-1), arg)
    ssum = jnp.sum(jnp.maximum(cnts, 0), axis=1)
    mc = jax.lax.population_count(mask).astype(jnp.int32).sum(axis=1)
    return jnp.stack([best, arg, ssum, mc], axis=1)


def _combine_body(part_ref, out_ref):
    """Split-phase stage 2 (DESIGN.md §5.5): reduce [B, L, 4] partials to
    the final [L, 4].  Cross-block smallest-id tie-break: every block's
    args lie in its own ascending id range, so the minimum arg among the
    blocks achieving the global best IS the first global argmax."""
    part = part_ref[...]                             # [B, L, 4] int32
    best = jnp.max(part[:, :, BEST], axis=0)
    big = jnp.int32(2**30)
    args = jnp.where(part[:, :, BEST] == best[None, :], part[:, :, ARG], big)
    arg = jnp.min(args, axis=0)
    arg = jnp.where(best < 0, jnp.int32(-1), arg)
    ssum = jnp.sum(part[:, :, SUM], axis=0)
    mc = jnp.max(part[:, :, MASK_COUNT], axis=0)
    out_ref[...] = jnp.stack([best, arg, ssum, mc], axis=1)


def _combine(part: jnp.ndarray, *, interpret: bool) -> jnp.ndarray:
    b, lanes, _ = part.shape
    return pl.pallas_call(
        _combine_body,
        grid=(1,),
        in_specs=[pl.BlockSpec((b, lanes, 4), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((lanes, 4), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.int32),
        interpret=interpret,
    )(part)


def _count_stats_split(table, mask, valid, *, tile: int, n: int,
                       interpret: bool) -> jnp.ndarray:
    """stages=2: grid over tile-blocks only, lanes batched in-block, then
    one combine launch (elided when a single block covers the table)."""
    w = table.shape[1]
    lanes = mask.shape[0]
    table = _pad_rows(table, tile)
    blocks = table.shape[0] // tile

    def stage1(table_ref, mask_ref, valid_ref, out_ref):
        b = pl.program_id(0)
        out_ref[0] = _partial_stats(table_ref[...], mask_ref[...],
                                    valid_ref[...], b * tile,
                                    tile=tile, n=n)

    part = pl.pallas_call(
        stage1,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((tile, w), lambda b: (b, 0)),
            pl.BlockSpec((lanes, w), lambda b: (0, 0)),
            pl.BlockSpec((lanes, w), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lanes, 4), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, lanes, 4), jnp.int32),
        interpret=interpret,
    )(table, mask, valid)
    if blocks == 1:
        return part[0]
    return _combine(part, interpret=interpret)


def count_stats(table: jnp.ndarray, mask: jnp.ndarray, valid: jnp.ndarray,
                *, tile: Optional[int] = None, stages: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """The masked-popcount pass (DESIGN.md §5.2).

    ``table``: uint32[n, w] packed bitset rows; ``mask``/``valid``:
    uint32[L, w] per-lane masks.  Returns int32[L, 4] =
    ``(best_count, best_vertex, count_sum, mask_count)`` where
    ``count[v] = popcount(table[v] & mask)`` for vertices whose bit is set
    in ``valid`` (all others count -1), ``best_vertex`` breaks ties toward
    the smallest id (-1 when nothing is valid), ``count_sum`` is
    ``Σ max(count, 0)`` and ``mask_count = popcount(mask)``.

    ``tile``/``stages`` default to the autotuner's per-shape choice
    (DESIGN.md §5.6); ``interpret=None`` compiles on TPU and interprets
    elsewhere.
    """
    n, w = table.shape
    lanes = mask.shape[0]
    tile, stages = _resolve_shape(n, w, lanes, 1, tile, stages)
    _validate_tile(tile, stages)
    interpret = _auto_interpret(interpret)
    if stages == 1:
        return _count_stats_seq(table, mask, valid, tile=tile, n=n,
                                interpret=interpret)
    return _count_stats_split(table, mask, valid, tile=tile, n=n,
                              interpret=interpret)


# ---------------------------------------------------------------------------
# stacked_count_stats: the batched uint32[K, n, w] variant (DESIGN.md §5.3)
# ---------------------------------------------------------------------------

def _stacked_kernel(inst_ref, tables_ref, mask_ref, valid_ref, out_ref, *,
                    tile: int, n: int):
    del inst_ref                                  # consumed by the index map
    _count_stats_body(tables_ref[0], mask_ref, valid_ref, out_ref,
                      tile=tile, n=n)


def _stacked_seq(tables, inst, mask, valid, *, tile: int, n: int,
                 interpret: bool) -> jnp.ndarray:
    """stages=1: one lane per outer grid step, table block selected by
    scalar prefetch.  Idle (inst < 0) lanes are parked before the call:
    their masks are zeroed (so the output is the (-1, -1, 0, 0) no-valid
    row) and their prefetch id is clipped only to keep the DMA in range."""
    k, n_, w = tables.shape
    lanes = mask.shape[0]
    idle = inst.astype(jnp.int32) < 0
    mask = jnp.where(idle[:, None], jnp.uint32(0), mask)
    valid = jnp.where(idle[:, None], jnp.uint32(0), valid)
    inst = jnp.clip(inst.astype(jnp.int32), 0, k - 1)
    tables = _pad_rows(tables, tile)
    tiles = tables.shape[1] // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((1, tile, w),
                         lambda l, t, inst_ref: (inst_ref[l], t, 0)),
            pl.BlockSpec((1, w), lambda l, t, inst_ref: (l, 0)),
            pl.BlockSpec((1, w), lambda l, t, inst_ref: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda l, t, inst_ref: (l, 0)),
    )
    return pl.pallas_call(
        functools.partial(_stacked_kernel, tile=tile, n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.int32),
        interpret=interpret,
    )(inst, tables, mask, valid)


def _stacked_split(tables, inst, mask, valid, *, tile: int, n: int,
                   interpret: bool) -> jnp.ndarray:
    """stages=2: grid (K, blocks) — each step loads ONE instance's tile
    block and reduces it against every lane bound to that instance (other
    lanes' masks are zeroed in-body, so their partials stay the neutral
    (-1, -1, 0, 0) row).  Table traffic is K × blocks DMAs regardless of
    the lane count or how many lanes are idle: an unbound (inst < 0) lane
    matches no instance step, causes no table traffic of its own, and
    combines to the parked (-1, -1, 0, 0) output."""
    k, n_, w = tables.shape
    lanes = mask.shape[0]
    tables = _pad_rows(tables, tile)
    blocks = tables.shape[1] // tile
    inst2 = inst.astype(jnp.int32).reshape(1, lanes)

    def stage1(tables_ref, inst_ref, mask_ref, valid_ref, out_ref):
        ki = pl.program_id(0)
        b = pl.program_id(1)
        bound = inst_ref[0, :] == ki                 # [L]
        m = jnp.where(bound[:, None], mask_ref[...], jnp.uint32(0))
        v = jnp.where(bound[:, None], valid_ref[...], jnp.uint32(0))
        out_ref[0, 0] = _partial_stats(tables_ref[0], m, v, b * tile,
                                       tile=tile, n=n)

    part = pl.pallas_call(
        stage1,
        grid=(k, blocks),
        in_specs=[
            pl.BlockSpec((1, tile, w), lambda ki, b: (ki, b, 0)),
            pl.BlockSpec((1, lanes), lambda ki, b: (0, 0)),
            pl.BlockSpec((lanes, w), lambda ki, b: (0, 0)),
            pl.BlockSpec((lanes, w), lambda ki, b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, lanes, 4), lambda ki, b: (ki, b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, blocks, lanes, 4), jnp.int32),
        interpret=interpret,
    )(tables, inst2, mask, valid)
    part = part.reshape(k * blocks, lanes, 4)
    if k * blocks == 1:
        return part[0]
    return _combine(part, interpret=interpret)


def stacked_count_stats(tables: jnp.ndarray, inst: jnp.ndarray,
                        mask: jnp.ndarray, valid: jnp.ndarray, *,
                        tile: Optional[int] = None,
                        stages: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """``count_stats`` over stacked tables: uint32[K, n, w] + int32[L]
    instance ids -> int32[L, 4], lane ``l`` reduced against
    ``tables[inst[l]]``.

    Idle lanes (``inst < 0``, the service's ``NO_INSTANCE``) are PARKED:
    they bind to no table, generate no table traffic of their own, and
    return the no-valid row ``(-1, -1, 0, 0)`` — the engine ignores their
    outputs, and this contract makes that safe by construction (the old
    behavior clipped them onto instance 0's table).

    Layouts (DESIGN.md §5.3/§5.5): stages=2 runs a grid over
    ``(instance, tile-block)`` with every lane batched in-body — table
    traffic is K·blocks DMAs, independent of the lane count; stages=1 is
    the legacy per-lane scalar-prefetch grid — L·blocks DMAs, one
    instance block per lane-step.  Defaults come from the autotuner.
    """
    k, n, w = tables.shape
    lanes = mask.shape[0]
    tile, stages = _resolve_shape(n, w, lanes, k, tile, stages)
    _validate_tile(tile, stages)
    interpret = _auto_interpret(interpret)
    if stages == 1:
        return _stacked_seq(tables, inst, mask, valid, tile=tile, n=n,
                            interpret=interpret)
    return _stacked_split(tables, inst, mask, valid, tile=tile, n=n,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# popcount_reduce: per-lane set cardinalities
# ---------------------------------------------------------------------------

def _popcount_kernel(rows_ref, out_ref):
    out_ref[0, 0] = jax.lax.population_count(
        rows_ref[...]).astype(jnp.int32).sum()


def popcount_reduce(rows: jnp.ndarray, *,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """uint32[L, w] -> int32[L]: popcount of each packed row (set sizes)."""
    lanes, w = rows.shape
    out = pl.pallas_call(
        _popcount_kernel,
        grid=(lanes,),
        in_specs=[pl.BlockSpec((1, w), lambda l: (l, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 1), jnp.int32),
        interpret=_auto_interpret(interpret),
    )(rows)
    return out[:, 0]


# ---------------------------------------------------------------------------
# masked_row_reduce: OR/AND-accumulate of selected table rows
# ---------------------------------------------------------------------------

def _row_reduce_kernel(table_ref, sel_ref, out_ref, *, tile: int, n: int,
                       op: str):
    t = pl.program_id(1)
    ident = jnp.uint32(0) if op == "or" else jnp.uint32(0xFFFFFFFF)
    bitop = jnp.bitwise_or if op == "or" else jnp.bitwise_and

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], ident)

    selected = _valid_bits(sel_ref[...][0], t * tile, tile, n)
    rows = jnp.where(selected[:, None], table_ref[...], ident)  # [tile, w]
    while rows.shape[0] > 1:                     # static log2 tree reduce
        half = rows.shape[0] // 2
        rows = bitop(rows[:half], rows[half:half * 2])
    out_ref[...] = bitop(out_ref[...], rows)


def masked_row_reduce(table: jnp.ndarray, select: jnp.ndarray, *,
                      op: str = "or", tile: int = 128,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Bitwise OR (or AND) of the rows of ``table`` (uint32[n, w]) whose
    bit is set in ``select`` (uint32[L, w]) -> uint32[L, w].  The OR form
    with an adjacency table is ``N(S)`` for the selected set S; the AND
    form intersects constraint rows.  Empty selection yields the identity
    (all-zeros / all-ones)."""
    if op not in ("or", "and"):
        raise ValueError(f"unknown reduce op {op!r}")
    n, w = table.shape
    if not isinstance(tile, int) or isinstance(tile, bool) or tile < 1:
        raise ValueError(f"tile must be a positive int, got {tile!r}")
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    lanes = select.shape[0]
    table = _pad_rows(table, tile)
    tiles = table.shape[0] // tile
    return pl.pallas_call(
        functools.partial(_row_reduce_kernel, tile=tile, n=n, op=op),
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((tile, w), lambda l, t: (t, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, w), jnp.uint32),
        interpret=_auto_interpret(interpret),
    )(table, select)


# ---------------------------------------------------------------------------
# problem-facing bindings (DESIGN.md §5.4)
# ---------------------------------------------------------------------------

def domination_stats(cadj: jnp.ndarray, dominated: jnp.ndarray,
                     cand: jnp.ndarray, fullm: jnp.ndarray, *,
                     tile: Optional[int] = None,
                     stages: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Dominating set's node statistics as a ``count_stats`` binding:
    mask = the undominated set, valid = the candidate set.  ``cadj``:
    uint32[n, w] CLOSED adjacency; ``dominated``/``cand``: uint32[L, w];
    ``fullm``: uint32[w] real-vertex mask.  Returns int32[L, 3] =
    ``(best_coverage, branch_vertex, undominated)`` — coverage is
    ``|N[v] \\ dominated|`` per candidate, the tie-break is smallest-id and
    ``undominated`` comes free as the pass's mask popcount."""
    mask = jnp.bitwise_and(fullm[None, :], jnp.bitwise_not(dominated))
    out = count_stats(cadj, mask, cand, tile=tile, stages=stages,
                      interpret=interpret)
    return jnp.stack([out[:, BEST], out[:, ARG], out[:, MASK_COUNT]], axis=1)
