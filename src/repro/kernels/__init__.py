"""Pallas kernels with jnp oracles.

Layout (DESIGN.md §5):

  ``bitset_ops.py``     — the universal bitset-kernel library: the
                          masked-popcount pass (``count_stats``), its
                          batched ``uint32[K, n, w]`` variant, and the
                          popcount/row-reduce primitives every problem
                          family binds to;
  ``bitset_degree.py``  — vertex cover's binding of that library;
  ``flash_attention.py``/``ssd_scan.py`` — model-side kernels;
  ``ops.py``            — jitted dispatchers (Pallas on TPU, jnp oracle
                          elsewhere, interpret-mode for off-TPU kernel
                          execution);
  ``ref.py``            — the pure-jnp oracles each kernel is validated
                          against.
"""
