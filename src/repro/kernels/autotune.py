"""Block-shape autotuner for the bitset kernels (DESIGN.md §5.6).

The masked-popcount kernels (``bitset_ops.count_stats`` and friends) are
parameterized by a vertex ``tile`` (rows of the table DMA'd per grid step)
and a ``stages`` mode (1 = the legacy sequential-accumulate grid, 2 = the
split-phase partial/combine layout of DESIGN.md §5.5).  The right choice
depends on the problem shape ``(n, w, L, K)`` and the platform:

  * compiled TPU — the ``[L, tile, w]`` broadcast intermediate must fit in
    VMEM next to the table block and the partial-stats scratch, and within
    that budget fewer, larger grid steps amortize DMA issue;
  * interpret / CPU — every grid step is a Python-level iteration of the
    interpreter's scan, so per-step overhead dominates by orders of
    magnitude and the winner is simply the fewest grid steps.

Rather than hand-tuning per call site, :func:`choose` scores every
power-of-two candidate with an analytic cost model built from
``repro.roofline.RooflineCounts.terms`` (the same compute/memory roofline
used by the HLO analyzer) plus a per-grid-step launch overhead, and caches
the winner per ``(n, w, L, K, platform)``.  :func:`measured_choice` is the
optional measured sweep: it times the real kernel on synthetic operands
and overrides the analytic pick in the same cache, so a deployment can
replace the model with measurements without touching call sites.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax

from repro.roofline import RooflineCounts

#: TPU v5e-class per-chip peaks used to scale the roofline terms.  Bitset
#: kernels are integer/VPU work, so "flops" here are uint32 word-ops.
PEAK_WORD_OPS = 4e12
HBM_BW = 8.0e11
ICI_BW = 4.5e10

#: Per-grid-step launch overhead (seconds).  The interpret path executes
#: the grid as a host-level sequential scan — measured O(10µs) per step —
#: while a compiled TPU grid step costs well under a microsecond.
GRID_STEP_OVERHEAD_S = {"tpu": 2e-7}
_DEFAULT_STEP_OVERHEAD_S = 1.5e-5

#: VMEM working-set budget for one grid step (bytes).  The dominant term
#: is the [L, tile, w] uint32 broadcast of the split-phase stage-1 body.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024

_MIN_TILE = 8
_MAX_TILE = 1024


class KernelChoice(NamedTuple):
    """One autotuner decision: the vertex tile and the kernel layout."""

    tile: int
    stages: int          # 1 = sequential accumulate, 2 = split-phase


_CACHE: Dict[Tuple[int, int, int, int, str], KernelChoice] = {}


def _next_pow2(x: int) -> int:
    p = _MIN_TILE
    while p < x:
        p *= 2
    return p


def candidate_tiles(n: int) -> Tuple[int, ...]:
    """Power-of-two tiles from 8 up to the first one covering ``n``."""
    top = min(_next_pow2(n), _MAX_TILE)
    out, t = [], _MIN_TILE
    while t <= top:
        out.append(t)
        t *= 2
    return tuple(out)


def _blocks(n: int, tile: int) -> int:
    return -(-n // tile)


def predict_cost(n: int, w: int, lanes: int, k: int, *, tile: int,
                 stages: int, platform: str) -> Optional[float]:
    """Modeled seconds for one kernel invocation, or None if infeasible.

    The roofline part (word-ops vs HBM bytes) comes from
    ``RooflineCounts.terms``; the grid term is ``steps × per-step
    overhead`` — negligible compiled, dominant interpreted.
    """
    blocks = _blocks(n, tile)
    padded = blocks * tile
    word_bytes = 4

    if stages == 2:
        # Stage-1 working set: table block + [L, tile, w] broadcast + the
        # lane masks + a [blocks|K·blocks, L, 4] partial scratch.
        working = (tile * w + lanes * tile * w + 2 * lanes * w) * word_bytes
        if working > VMEM_BUDGET_BYTES:
            return None
        steps = k * blocks + (1 if k * blocks > 1 else 0)
        hbm = (k * padded * w                      # table blocks, once each
               + k * blocks * 2 * lanes * w        # masks re-read per step
               + 2 * k * blocks * lanes * 4        # partials out + back in
               + lanes * 4) * word_bytes
    else:
        # Legacy grid (lanes, tiles): the table is re-streamed per lane.
        steps = lanes * blocks
        hbm = (lanes * padded * w + 2 * lanes * w * blocks
               + lanes * 4) * word_bytes
    # ~4 word-ops per (lane, vertex, word): and, popcount, compare, add.
    ops = 4.0 * k * lanes * padded * w
    terms = RooflineCounts(flops=ops, hbm_bytes=float(hbm)).terms(
        PEAK_WORD_OPS, HBM_BW, ICI_BW)
    roof = max(terms["compute_s"], terms["memory_s"])
    overhead = GRID_STEP_OVERHEAD_S.get(platform, _DEFAULT_STEP_OVERHEAD_S)
    return roof + steps * overhead


def choose(n: int, w: int, lanes: int = 1, k: int = 1,
           platform: Optional[str] = None) -> KernelChoice:
    """Pick (tile, stages) for a ``(n, w, L, K)`` kernel shape.

    Cached per shape and platform; a prior :func:`measured_choice` sweep
    for the same key takes precedence over the analytic model.
    """
    platform = platform or jax.default_backend()
    key = (n, w, lanes, k, platform)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    best_cost, best = None, None
    for tile in candidate_tiles(n):
        for stages in (2, 1):
            cost = predict_cost(n, w, lanes, k, tile=tile, stages=stages,
                                platform=platform)
            if cost is None:
                continue
            if best_cost is None or cost < best_cost:
                best_cost, best = cost, KernelChoice(tile, stages)
    if best is None:                       # every candidate over budget
        best = KernelChoice(_MIN_TILE, 1)
    _CACHE[key] = best
    return best


def measured_choice(n: int, w: int, lanes: int = 1, k: int = 1, *,
                    repeat: int = 3,
                    platform: Optional[str] = None) -> KernelChoice:
    """Measured sweep: time the real kernel per candidate and cache the
    winner under the same key :func:`choose` consults.

    Synthetic uint32 operands; the sweep exercises ``count_stats`` for
    K = 1 and ``stacked_count_stats`` otherwise.  Intended for offline
    tuning (benchmarks) — per-candidate compile + run is far too slow for
    a hot path.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import bitset_ops

    platform = platform or jax.default_backend()
    rng = np.random.default_rng(0)

    def bits(shape):
        return jnp.asarray(
            rng.integers(0, 2**32, shape, dtype=np.uint64).astype(np.uint32))

    tables = bits((k, n, w)) if k > 1 else bits((n, w))
    mask, valid = bits((lanes, w)), bits((lanes, w))
    inst = jnp.asarray(rng.integers(0, k, lanes).astype(np.int32))

    best_t, best = None, None
    for tile in candidate_tiles(n):
        for stages in (2, 1):
            if predict_cost(n, w, lanes, k, tile=tile, stages=stages,
                            platform=platform) is None:
                continue
            if k > 1:
                fn = jax.jit(lambda t_, i, m, v, _tl=tile, _st=stages:
                             bitset_ops.stacked_count_stats(
                                 t_, i, m, v, tile=_tl, stages=_st))
                args = (tables, inst, mask, valid)
            else:
                fn = jax.jit(lambda t_, m, v, _tl=tile, _st=stages:
                             bitset_ops.count_stats(t_, m, v, tile=_tl,
                                                    stages=_st))
                args = (tables, mask, valid)
            jax.block_until_ready(fn(*args))           # compile + warm
            t = min(_time_once(fn, args) for _ in range(repeat))
            if best_t is None or t < best_t:
                best_t, best = t, KernelChoice(tile, stages)
    if best is None:
        best = choose(n, w, lanes, k, platform)
    _CACHE[(n, w, lanes, k, platform)] = best
    return best


def _time_once(fn, args) -> float:
    import time
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def clear_cache() -> None:
    """Drop every cached decision (tests / re-tuning)."""
    _CACHE.clear()
