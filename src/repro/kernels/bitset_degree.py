"""Vertex-cover degree statistics as a ``bitset_ops`` binding (DESIGN.md §5.4).

The solver's hot spot (paper §V): at every search-node, compute the degree
of every alive vertex in the residual graph — popcount(adj[v] & alive) —
then (a) pick the max-degree vertex with smallest-id tie-break (the branch
rule) and (b) sum the alive degrees (= 2·m_alive, the bound's numerator).
That is exactly the universal masked-popcount pass of
``repro.kernels.bitset_ops.count_stats`` with mask = valid = the alive
set, so this module is a thin argument adapter — the kernel body, grid and
block shapes live in ``bitset_ops`` and are documented in DESIGN.md
§5.1/§5.5; the per-column contract is §5.2.  ``tile``/``stages`` default
to the per-shape autotuner (DESIGN.md §5.6) and ``interpret=None``
compiles on TPU / interprets elsewhere.

Kept as a module (rather than folding the call sites into
``problems/vertex_cover.py``) so the kernel library's problem bindings
stay enumerable in one place per problem family, mirroring
``bitset_ops.domination_stats`` for dominating set and
``bitset_ops.stacked_count_stats`` for the stacked service.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import bitset_ops


def degree_stats(adj: jnp.ndarray, alive: jnp.ndarray, *,
                 tile: Optional[int] = None, stages: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """adj: uint32[n, w] packed adjacency; alive: uint32[L, w] per-lane
    masks.  Returns int32[L, 3] = (best_degree, best_vertex, degree_sum);
    (-1, -1, 0) when no vertex is alive.  ``degree_sum`` is the sum of
    alive-vertex degrees, i.e. twice the residual edge count."""
    return bitset_ops.count_stats(adj, alive, alive, tile=tile,
                                  stages=stages, interpret=interpret)[:, :3]


def degree_argmax(adj: jnp.ndarray, alive: jnp.ndarray, *,
                  tile: Optional[int] = None, stages: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Compatibility wrapper: int32[L, 2] = (best_degree, best_vertex)."""
    return degree_stats(adj, alive, tile=tile, stages=stages,
                        interpret=interpret)[:, :2]
