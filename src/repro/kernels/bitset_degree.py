"""Pallas TPU kernel: fused masked-popcount degree stats for vertex cover.

The solver's hot spot (paper §V): at every search-node, compute the degree
of every alive vertex in the residual graph — popcount(adj[v] & alive) —
then (a) pick the max-degree vertex with smallest-id tie-break (the branch
rule) and (b) sum the alive degrees (= 2·m_alive, the bound's numerator).
The jnp form (repro.problems.vertex_cover) materializes an [n, w] masked
matrix per lane; this kernel fuses mask+popcount+argmax+sum over vertex
tiles so only the running (best_degree, best_vertex, degree_sum) triple
leaves VMEM.  One kernel launch per fused ``Problem.evaluate`` — the whole
per-node degree work in a single pass (DESIGN.md §3).

Grid: ``(lanes, vertex_tiles)`` — tile axis sequential, accumulating into
the output ref.  Ascending tile order + strict ">" update preserves the
paper's determinism rule (ties -> smallest id).  Popcount is
``jax.lax.population_count`` on uint32 words (VPU-friendly bitwise ops).

Validated interpret=True against ref.degree_stats_ref; batching (vmap over
lane masks, as the engine does) lifts into an extra grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _kernel(adj_ref, alive_ref, out_ref, *, tile: int, n: int, words: int):
    t = pl.program_id(1)

    neg = jnp.int32(-1)

    @pl.when(t == 0)
    def _init():
        out_ref[0, 0] = neg          # best degree (-1: no alive vertex)
        out_ref[0, 1] = neg          # best vertex
        out_ref[0, 2] = jnp.int32(0)  # sum of alive degrees (2 * m_alive)

    adj = adj_ref[...]               # [tile, words] uint32
    alive = alive_ref[...]           # [1, words] uint32

    masked = jnp.bitwise_and(adj, alive)
    degs = jax.lax.population_count(masked).astype(jnp.int32).sum(
        axis=1)                      # [tile]

    # A vertex is alive iff its own bit is set in the alive mask.
    base = t * tile
    vid = base + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    word_ix = vid // 32
    bit_ix = (vid % 32).astype(jnp.uint32)
    row = jnp.take(alive[0], word_ix, axis=0)
    is_alive = ((row >> bit_ix) & jnp.uint32(1)) == jnp.uint32(1)
    degs = jnp.where(is_alive & (vid < n), degs, neg)

    tile_best = jnp.max(degs)
    tile_arg = base + jnp.argmax(degs).astype(jnp.int32)

    best = out_ref[0, 0]
    better = tile_best > best        # strict: earlier tile wins ties
    out_ref[0, 0] = jnp.where(better, tile_best, best)
    out_ref[0, 1] = jnp.where(better, tile_arg, out_ref[0, 1])
    out_ref[0, 2] = out_ref[0, 2] + jnp.sum(jnp.maximum(degs, 0))


def degree_stats(adj: jnp.ndarray, alive: jnp.ndarray, *,
                 tile: int = 128, interpret: bool = True) -> jnp.ndarray:
    """adj: uint32[n, w] packed adjacency; alive: uint32[L, w] per-lane
    masks.  Returns int32[L, 3] = (best_degree, best_vertex, degree_sum);
    (-1, -1, 0) when no vertex is alive.  ``degree_sum`` is the sum of
    alive-vertex degrees, i.e. twice the residual edge count."""
    n, w = adj.shape
    lanes = alive.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        adj = jnp.pad(adj, ((0, n_pad), (0, 0)))
    tiles = (n + n_pad) // tile

    out = pl.pallas_call(
        functools.partial(_kernel, tile=tile, n=n, words=w),
        grid=(lanes, tiles),
        in_specs=[
            pl.BlockSpec((tile, w), lambda l, t: (t, 0)),
            pl.BlockSpec((1, w), lambda l, t: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda l, t: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 3), jnp.int32),
        interpret=interpret,
    )(adj, alive)
    return out


def degree_argmax(adj: jnp.ndarray, alive: jnp.ndarray, *,
                  tile: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Compatibility wrapper: int32[L, 2] = (best_degree, best_vertex)."""
    return degree_stats(adj, alive, tile=tile, interpret=interpret)[:, :2]
