"""Pallas TPU kernel for the Mamba-2 SSD chunk scan.

Grid: ``(batch*head_tile, n_chunks)`` with the chunk axis sequential — the
running inter-chunk state [N, P] per head lives in VMEM scratch, exactly
the paper's "state passing" form of SSD.  Each grid step computes the
intra-chunk quadratic term (decay-masked C B^T on the MXU), adds the
contribution of the carried state, and updates the state — so the
quadratic [Q, Q] block never leaves VMEM (the memory behavior the roofline
kernel-adjustment models).

Layout notes (TPU): heads are tiled so the trailing dims of every VMEM
block are (multiple-of-8, 128)-friendly: Q (chunk) and N/P are 64–128 in
the assigned configs.  Validated with interpret=True against
repro.models.ssm.ssd_chunked (re-exported in ref.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
            st_scr, *, chunk: int):
    cj = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(cj == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, 1]
    a = a_ref[0]                              # [1, 1] f32 (negative)
    b = b_ref[0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0].astype(jnp.float32)          # [Q, N]
    d = d_ref[0]                              # [1, 1] f32

    da = dt * a[0, 0]                         # [Q, 1]
    cum = jnp.cumsum(da, axis=0)              # [Q, 1]
    total = cum[chunk - 1, 0]

    # Intra-chunk: w[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, i >= j.
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = jnp.exp(cum - cum[:, 0][None, :])   # [Q(i), Q(j)]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(ii >= jj, scores * seg * dt[:, 0][None, :], 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Inter-chunk: y += exp(cum) * (C @ state_prev).
    cs = c * jnp.exp(cum)                     # [Q, N]
    y = y + jax.lax.dot_general(cs, st_scr[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + x * d[0, 0]
    y_ref[0] = y.astype(y_ref.dtype)

    # State update: S = exp(total) * S + sum_j exp(total - cum_j) dt_j B_j x_j^T.
    sb = b * (jnp.exp(total - cum) * dt)      # [Q, N]
    upd = jax.lax.dot_general(sb, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    st_scr[...] = st_scr[...] * jnp.exp(total) + upd

    @pl.when(cj == nc - 1)
    def _emit_state():
        state_ref[0] = st_scr[...]


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray,
             chunk: int = 64, interpret: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,H,P]; dt: [B,S,H] (f32, post-softplus); a,d: [H] f32;
    b,c: [B,S,G,N].  Returns (y [B,S,H,P], state [B,H,N,P])."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    # Flatten (B, H) into the grid's first axis; expand B/C per head.
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S, 1).astype(jnp.float32)
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)
    bf = bh.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cf = ch.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    af = jnp.tile(a.astype(jnp.float32), B).reshape(B * H, 1, 1)
    df = jnp.tile(d.astype(jnp.float32), B).reshape(B * H, 1, 1)

    seq_spec = pl.BlockSpec((1, chunk, None), lambda bh_, cj: (bh_, cj, 0))
    scal_spec = pl.BlockSpec((1, 1, 1), lambda bh_, cj: (bh_, 0, 0))

    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda g, cj: (g, cj, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, cj: (g, cj, 0)),
            scal_spec,
            pl.BlockSpec((1, chunk, N), lambda g, cj: (g, cj, 0)),
            pl.BlockSpec((1, chunk, N), lambda g, cj: (g, cj, 0)),
            scal_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda g, cj: (g, cj, 0)),
            pl.BlockSpec((1, N, P), lambda g, cj: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf, df)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    state = state.reshape(B, H, N, P)
    return y, state
