"""Request-lifecycle types for the solver service (DESIGN.md §7).

The batch-era service front door returned a bare int rid from ``submit()``
and blocked in ``run()`` until the whole queue drained — no way to express
what a request-serving deployment actually needs: admission priorities,
latency deadlines, per-request work budgets (mts-style subtree budgets),
cancellation, and anytime results.  This module holds the types of the
redesigned surface:

* :class:`SolveRequest` — one tenant's instance, now carrying ``priority``
  (admission order under :class:`~repro.service.scheduler.PriorityFifo`),
  ``deadline_rounds`` (service rounds after submission before the request
  is expired) and ``node_budget`` (search nodes before eviction);
* :class:`Ticket` — the future-like handle ``submit()`` returns: status
  machine QUEUED → RUNNING → DONE | CANCELLED | EXPIRED, blocking
  ``result(timeout=)`` that drives the owning service's rounds, and
  ``cancel()`` which frees the slot and reclaims its lanes within one
  round;
* :class:`RequestResult` — the per-request outcome, extended with a
  ``status`` field so evicted requests keep their best-so-far as an
  *anytime* result instead of vanishing;
* the typed errors: :class:`AdmissionError` (request the service can never
  run, raised at ``submit()`` after a ``reject`` ProgressEvent) and
  :class:`TicketCancelled` (raised by ``result()`` on a cancelled ticket).

Everything here is host-side bookkeeping — no jax imports, no engine
state.  The policy deciding WHICH queued request is admitted next lives in
:mod:`repro.service.scheduler`; the lane/slot mechanics stay in
:mod:`repro.service.driver`.
"""

from __future__ import annotations

import dataclasses
import enum
import time
import warnings
from typing import Any, Optional

import numpy as np

from repro.problems.graphs import Graph

__all__ = [
    "AdmissionError",
    "RequestResult",
    "SolveRequest",
    "Ticket",
    "TicketCancelled",
    "TicketStatus",
]


class AdmissionError(ValueError):
    """A request the service can never run: unregistered family, family
    without service packing, instance larger than the deployment's
    ``max_n``, a duplicate rid, or nonsensical lifecycle fields.  Raised at
    ``submit()`` time — never deep inside packing — after a ``reject``
    :class:`~repro.solver.ProgressEvent` has been emitted."""


class TicketCancelled(RuntimeError):
    """``Ticket.result()`` on a cancelled request.  The best-so-far anytime
    snapshot (if the request ever ran) stays available under
    ``SolverService.results[rid]`` with ``status == "cancelled"``."""


class TicketStatus(enum.Enum):
    """The request lifecycle.  QUEUED and RUNNING are live; DONE, CANCELLED
    and EXPIRED are terminal (EXPIRED = deadline or node-budget eviction,
    with the best-so-far recorded as an anytime result)."""

    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    CANCELLED = "cancelled"
    EXPIRED = "expired"


#: Terminal states: a ticket in one of these never changes again.
TERMINAL = frozenset(
    {TicketStatus.DONE, TicketStatus.CANCELLED, TicketStatus.EXPIRED})


@dataclasses.dataclass
class SolveRequest:
    """One tenant's instance plus its lifecycle contract.

    ``family`` is any *servable* registered problem family
    (``repro.registry.get(family).servable``).  ``priority`` orders
    admission under the default scheduler (higher admits first, ties FIFO);
    ``deadline_rounds`` (>= 1) expires the request that many service rounds
    after submission; ``node_budget`` (>= 1) evicts it once its slot has
    explored that many search nodes.  Both evictions record the best
    incumbent so far as an anytime result.
    """

    rid: int
    graph: Graph
    family: str
    priority: int = 0
    deadline_rounds: Optional[int] = None
    node_budget: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    """Outcome of one request.  ``status`` is "done" for a drained search,
    "expired" / "cancelled" for an eviction — then ``optimum`` is the
    anytime incumbent at eviction time (``INF_VALUE`` when no solution had
    been found yet) and ``payload`` its solution bitset."""

    rid: int
    optimum: int
    payload: np.ndarray        # uint32[w] solution bitset (padded width)
    admitted_round: int        # -1 when the request expired while queued
    retired_round: int
    status: str = "done"       # "done" | "expired" | "cancelled"


@dataclasses.dataclass(eq=False)
class Ticket:
    """Future-like handle for one submitted request.

    Returned by ``SolverService.submit``; holds the request's lifecycle
    state (the service mutates it as rounds advance) and drives the service
    on demand: ``result()`` steps rounds until this ticket is terminal.
    ``deadline_round`` is the ABSOLUTE service round at which the request
    expires (submission round + ``deadline_rounds``).
    """

    rid: int
    priority: int = 0
    deadline_round: Optional[int] = None
    node_budget: Optional[int] = None
    status: TicketStatus = TicketStatus.QUEUED
    submitted_round: int = 0
    admitted_round: Optional[int] = None
    finished_round: Optional[int] = None
    nodes_used: int = 0        # round-granular (see driver node accounting)
    _service: Any = dataclasses.field(default=None, repr=False)

    def done(self) -> bool:
        """True once the ticket is terminal (DONE, CANCELLED or EXPIRED)."""
        return self.status in TERMINAL

    @property
    def wait_rounds(self) -> Optional[int]:
        """Rounds spent QUEUED before admission.

        For a ticket that left the queue without ever running (queue-expired
        or cancelled while queued) this is the full submitted→finished span.
        None while the ticket is still queued.
        """
        if self.admitted_round is not None:
            return self.admitted_round - self.submitted_round
        if self.finished_round is not None:
            return self.finished_round - self.submitted_round
        return None

    @property
    def run_rounds(self) -> Optional[int]:
        """Rounds spent RUNNING (admission → terminal); None until both
        endpoints are known (never-admitted tickets stay None)."""
        if self.admitted_round is None or self.finished_round is None:
            return None
        return self.finished_round - self.admitted_round

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Drive the owning service until this ticket resolves.

        Steps service rounds (admitting / retiring every other tenant as a
        side effect — the service is cooperatively scheduled) until this
        ticket is terminal.  Raises ``TimeoutError`` after ``timeout``
        wall-clock seconds, :class:`TicketCancelled` if the ticket was
        cancelled; an EXPIRED ticket *returns* its anytime
        :class:`RequestResult` (``status == "expired"``).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.status not in TERMINAL:
            if self._service is None:
                raise RuntimeError(
                    f"ticket {self.rid} is not bound to a service")
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"ticket {self.rid} unresolved after {timeout}s "
                    f"(status={self.status.value})")
            self._service.step_round()
        if self.status is TicketStatus.CANCELLED:
            raise TicketCancelled(f"request {self.rid} was cancelled")
        return self._service.results[self.rid]

    def cancel(self) -> bool:
        """Cancel the request; True if this call cancelled it.

        A QUEUED ticket is removed from the admission queue; a RUNNING one
        has its slot freed and its lanes reclaimed immediately (within one
        round — the driver's eviction path), with the best-so-far recorded
        as an anytime result.  Terminal tickets return False.
        """
        if self.status in TERMINAL or self._service is None:
            return False
        return self._service.cancel(self.rid)

    def __int__(self) -> int:
        # The pre-ticket submit() returned a bare int rid; treating the
        # ticket AS that int is the legacy surface.
        warnings.warn(
            "treating a Ticket as its int rid is deprecated; use "
            "ticket.rid / ticket.result()", DeprecationWarning, stacklevel=2)
        return self.rid
