"""Pluggable admission scheduling for the solver service (DESIGN.md §7).

The semi-centralized strategy of Pastrana-Cruz et al. (2023) — a light
central scheduler over branching workers — maps onto our service cleanly:
the driver (:mod:`repro.service.driver`) stays a pure round-stepping
engine over lanes and slots, and ALL policy lives here:

* :class:`SchedulingPolicy` — the pluggable queue contract.  A policy is a
  priority queue of :class:`QueueItem`\\ s; the driver pops one per free
  slot per round and never looks at priorities, sizes or deadlines itself.
  Implementations: :class:`PriorityFifo` (default — higher ``priority``
  admits first, ties FIFO), :class:`ShortestJobFirst` (smallest registered
  ``size()`` first — the registry feeds the key) and :class:`Fifo`
  (pure arrival order, the pre-ticket behavior and the benchmark
  baseline).  ``SCHEDULERS`` / :func:`make_policy` resolve config names;
  any object satisfying the protocol can be passed to the driver directly,
  so new policies never touch the engine.

* :class:`Scheduler` — the bookkeeping layer over one policy instance:
  owns the ticket table, the admission sequence counter, and the
  deadline / node-budget eviction decisions (mts-style per-subtree
  budgets, Avis & Jordan 2017).  The driver asks ``overdue(round)`` each
  round and performs the lane/slot surgery; the scheduler never touches
  device state.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, NamedTuple, Optional, Protocol, Tuple

from repro import registry
from repro.service.ticket import (TERMINAL, SolveRequest, Ticket,
                                  TicketStatus)

__all__ = [
    "AutoscalePolicy",
    "Fifo",
    "PriorityFifo",
    "QueueItem",
    "SCHEDULERS",
    "Scheduler",
    "SchedulingPolicy",
    "ShortestJobFirst",
    "make_policy",
]


class QueueItem(NamedTuple):
    """One queued request: ``seq`` is the admission sequence number (the
    FIFO tie-breaker, preserved across checkpoints so restored queues pop
    in the same order)."""

    seq: int
    request: SolveRequest


class SchedulingPolicy(Protocol):
    """The admission-queue contract the driver consumes.

    ``pop()`` returns the next request to admit (None when empty);
    ``remove(rid)`` drops a queued request (cancellation / queue expiry);
    ``pending()`` is a non-destructive snapshot in pop order (checkpoints,
    introspection).  The driver never inspects requests' policy fields —
    subclass :class:`_HeapPolicy` with a ``key`` to add a policy without
    touching the engine.
    """

    name: str

    def push(self, item: QueueItem) -> None: ...

    def pop(self) -> Optional[QueueItem]: ...

    def remove(self, rid: int) -> bool: ...

    def pending(self) -> Tuple[QueueItem, ...]: ...

    def __len__(self) -> int: ...


class _HeapPolicy:
    """Heap-ordered policy base: orders by ``key(request) + (seq,)`` —
    subclasses supply the key, ties always break FIFO.  Removal is lazy
    (dead entries stay in the heap until popped over) with a live-rid set,
    so cancellation of a queued request is O(1)."""

    name = "heap"

    def __init__(self):
        self._heap: List[Tuple[tuple, QueueItem]] = []
        self._live: set = set()       # rids queued and not removed

    def key(self, request: SolveRequest) -> tuple:
        return ()

    def push(self, item: QueueItem) -> None:
        heapq.heappush(self._heap,
                       (self.key(item.request) + (item.seq,), item))
        self._live.add(item.request.rid)

    def pop(self) -> Optional[QueueItem]:
        while self._heap:
            _, item = heapq.heappop(self._heap)
            if item.request.rid in self._live:
                self._live.discard(item.request.rid)
                return item
        return None

    def remove(self, rid: int) -> bool:
        if rid in self._live:
            self._live.discard(rid)
            # Compact once dead entries dominate, so cancelled requests'
            # QueueItems (and their instance arrays) don't accumulate under
            # a policy that never pops them.
            if len(self._heap) > 8 and len(self._live) < len(self._heap) // 2:
                self._heap = [e for e in self._heap
                              if e[1].request.rid in self._live]
                heapq.heapify(self._heap)
            return True
        return False

    def pending(self) -> Tuple[QueueItem, ...]:
        return tuple(item for _, item in sorted(self._heap)
                     if item.request.rid in self._live)

    def __len__(self) -> int:
        return len(self._live)


class Fifo(_HeapPolicy):
    """Pure arrival order — the pre-ticket ``deque`` behavior, kept as the
    explicit baseline for ``benchmarks/service_latency.py``."""

    name = "fifo"


class PriorityFifo(_HeapPolicy):
    """Higher ``SolveRequest.priority`` admits first; equal priorities are
    FIFO — which makes the default policy bitwise-identical to the legacy
    queue when every request carries the default priority."""

    name = "priority"

    def key(self, request: SolveRequest) -> tuple:
        return (-int(request.priority),)


class ShortestJobFirst(_HeapPolicy):
    """Smallest instance first, keyed on the family's registered ``size()``
    (``repro.registry.instance_size``); ties FIFO.  The classic tail-latency
    heuristic when sizes predict work."""

    name = "sjf"

    def key(self, request: SolveRequest) -> tuple:
        return (registry.instance_size(request.family, request.graph),)


#: Config-name -> policy class (the ``SolverConfig.scheduler`` values).
SCHEDULERS: Dict[str, type] = {
    "fifo": Fifo,
    "priority": PriorityFifo,
    "sjf": ShortestJobFirst,
}


def make_policy(name: str) -> SchedulingPolicy:
    """Instantiate a registered policy by config name."""
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {name!r} (known: "
            f"{', '.join(sorted(SCHEDULERS))})") from None


@dataclasses.dataclass
class AutoscalePolicy:
    """Semi-centralized elasticity decisions, keyed on
    :meth:`Scheduler.queue_depth` (DESIGN.md §9).

    The sharded service driver asks :meth:`decide` once per round and
    performs the mechanics itself (``SolverService.resize`` — an in-memory
    elastic W' ≠ W checkpoint/restore cycle onto a different device
    count).  Like every policy in this module, the decision layer never
    touches device state.

    * grow when the admission queue has backed up to ``grow_at`` or more;
    * shrink when it has drained to ``shrink_below`` or fewer AND the run
      is not using its open capacity (the driver passes ``busy=False``
      when live slots leave lanes idle);
    * never outside [min_devices, max_devices], never within
      ``cooldown_rounds`` of the previous change (resizing re-jits the
      round, so flapping is the failure mode this guards).
    """

    grow_at: int = 2
    shrink_below: int = 0
    min_devices: int = 1
    max_devices: int = 1
    cooldown_rounds: int = 8
    _last_change: int = dataclasses.field(default=-(10 ** 9), repr=False)

    def decide(self, *, queue_depth: int, devices: int, now_round: int,
               busy: bool = True) -> Optional[int]:
        """Target device count, or None to stay put."""
        if now_round - self._last_change < self.cooldown_rounds:
            return None
        if queue_depth >= self.grow_at and devices < self.max_devices:
            self._last_change = now_round
            return min(self.max_devices, devices * 2)
        if (queue_depth <= self.shrink_below and not busy
                and devices > self.min_devices):
            self._last_change = now_round
            return max(self.min_devices, devices // 2)
        return None


class Scheduler:
    """Ticket table + one policy instance + eviction decisions.

    The driver delegates every "which request, when" question here and
    keeps the "how" (table writes, lane seeding, eviction surgery) to
    itself.  All state is host-side and checkpointable
    (``driver.SolverService.save`` persists the pending items, ticket
    states and ``seq`` counter so a restored queue pops identically).
    """

    def __init__(self, policy: SchedulingPolicy):
        self.policy = policy
        self.tickets: Dict[int, Ticket] = {}
        self.seq = 0                      # admission sequence counter
        # Live rids carrying a deadline or node budget: the per-round
        # eviction sweep and the node-readback decision scan ONLY this set,
        # not every ticket the service ever issued.
        self._limited: set = set()

    def __len__(self) -> int:
        return len(self.policy)

    def queue_depth(self) -> int:
        """Number of requests waiting for admission (telemetry gauge)."""
        return len(self.policy)

    def adopt(self, ticket: Ticket) -> None:
        """Index an externally built ticket (checkpoint restore)."""
        self.tickets[ticket.rid] = ticket
        if ticket.status not in TERMINAL and (
                ticket.deadline_round is not None
                or ticket.node_budget is not None):
            self._limited.add(ticket.rid)

    def resolve(self, rid: int, status: TicketStatus,
                now_round: int) -> None:
        """Move a ticket to a terminal state (rids without tickets — legacy
        checkpoints — are a no-op)."""
        ticket = self.tickets.get(rid)
        if ticket is not None:
            ticket.status = status
            ticket.finished_round = now_round
        self._limited.discard(rid)

    def enqueue(self, request: SolveRequest, *, now_round: int,
                service) -> Ticket:
        """Create the QUEUED ticket and push the request onto the policy.
        Validation (registry, sizes, duplicate rids) is the driver's job —
        it owns the ``reject`` event stream."""
        deadline_round = (None if request.deadline_rounds is None
                          else now_round + int(request.deadline_rounds))
        ticket = Ticket(
            rid=request.rid, priority=int(request.priority),
            deadline_round=deadline_round,
            node_budget=request.node_budget,
            submitted_round=now_round, _service=service)
        self.adopt(ticket)
        self.policy.push(QueueItem(self.seq, request))
        self.seq += 1
        return ticket

    def pop_admission(self) -> Optional[QueueItem]:
        return self.policy.pop()

    def remove_queued(self, rid: int) -> bool:
        return self.policy.remove(rid)

    def pending(self) -> Tuple[QueueItem, ...]:
        return self.policy.pending()

    # -- eviction policy ----------------------------------------------------

    def note_nodes(self, rid: int, delta: int) -> None:
        ticket = self.tickets.get(rid)
        if ticket is not None:
            ticket.nodes_used += int(delta)

    def track_nodes(self) -> bool:
        """True while any live ticket carries a node budget — the driver
        only pays the per-round node readback when this is set.  QUEUED
        tickets count too: admission happens inside the same round that
        would otherwise skip the pre-round snapshot."""
        return any(self.tickets[rid].node_budget is not None
                   for rid in self._limited)

    def overdue(self, now_round: int) -> Tuple[List[int], List[int]]:
        """(queued rids past their deadline, running rids past deadline or
        node budget) at the end of round ``now_round``.  O(live limited
        tickets), not O(all tickets ever issued)."""
        queued, running = [], []
        for rid in sorted(self._limited):
            ticket = self.tickets[rid]
            late = (ticket.deadline_round is not None
                    and now_round >= ticket.deadline_round)
            if ticket.status is TicketStatus.QUEUED:
                if late:
                    queued.append(rid)
            elif late or (ticket.node_budget is not None
                          and ticket.nodes_used >= ticket.node_budget):
                running.append(rid)
        return queued, running
