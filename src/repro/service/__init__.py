"""Multi-tenant solver service: continuous batching of many instances.

``batch_problem`` stacks K padded instances (vertex cover and/or dominating
set) into one ``BinaryProblem`` whose per-lane state carries an instance
id; ``driver`` is the pure round-stepping engine that streams requests
through a fixed pool of W lanes with admission, instance-scoped stealing,
per-instance retirement/eviction and elastic checkpointing; ``scheduler``
is the pluggable policy layer deciding admission order and deadline /
node-budget evictions; ``ticket`` holds the request-lifecycle types —
``submit()`` returns a :class:`Ticket` future (DESIGN.md §7).
"""

from repro.service.batch_problem import (FAMILY_DS, FAMILY_VC,
                                         STACKED_BACKENDS, StackedSpec,
                                         StackedTables, SvcState)
from repro.service.driver import SolverService
from repro.service.scheduler import (SCHEDULERS, AutoscalePolicy, Fifo,
                                     PriorityFifo, Scheduler,
                                     SchedulingPolicy, ShortestJobFirst,
                                     make_policy)
from repro.service.ticket import (AdmissionError, RequestResult,
                                  SolveRequest, Ticket, TicketCancelled,
                                  TicketStatus)

__all__ = [
    "AdmissionError", "AutoscalePolicy", "FAMILY_DS", "FAMILY_VC",
    "Fifo", "PriorityFifo",
    "RequestResult", "SCHEDULERS", "STACKED_BACKENDS", "Scheduler",
    "SchedulingPolicy", "ShortestJobFirst", "SolveRequest", "SolverService",
    "StackedSpec", "StackedTables", "SvcState", "Ticket", "TicketCancelled",
    "TicketStatus", "make_policy",
]
