"""Multi-tenant solver service: continuous batching of many instances.

``batch_problem`` stacks K padded instances (vertex cover and/or dominating
set) into one ``BinaryProblem`` whose per-lane state carries an instance
id; ``driver`` streams solve requests through a fixed pool of W lanes with
admission, instance-scoped stealing, per-instance retirement and elastic
checkpointing.
"""

from repro.service.batch_problem import (FAMILY_DS, FAMILY_VC,
                                         STACKED_BACKENDS, StackedSpec,
                                         StackedTables, SvcState)
from repro.service.driver import (AdmissionError, SolveRequest,
                                  SolverService)

__all__ = [
    "AdmissionError", "FAMILY_DS", "FAMILY_VC", "STACKED_BACKENDS",
    "StackedSpec", "StackedTables", "SvcState", "SolveRequest",
    "SolverService",
]
