"""Continuous-batching solver service over one lane pool.

The serving pattern of ``repro.serve.driver`` (fixed slot pool, lockstep
ticks, admission/retirement at tick boundaries) applied to backtracking:

  * the *pool* is W engine lanes advancing in lockstep under one jitted
    round (expand → instance-scoped steal → per-instance termination);
  * a *slot* is one of K stacked-instance table entries
    (``batch_problem.StackedSpec``); a request occupies a slot from
    admission to retirement or eviction;
  * *admission* pops the next request from the pluggable scheduling policy
    (:mod:`repro.service.scheduler` — priority heap by default, NOT the
    submission order), resolves its family through the
    :mod:`repro.registry` and writes the padded instance into the stacked
    tables (they are jit ARGUMENTS, so no recompilation), resets the
    slot's incumbent and seeds the instance root onto one idle lane —
    every other lane the instance ever uses arrives via stealing, the
    same bootstrap the paper uses for its virtual topology;
  * *retirement* fires when the per-instance open-work counter reaches
    zero: the slot's optimum + payload are recorded, the ticket resolves
    DONE and the slot is free for the next queued request;
  * *eviction* fires on ``Ticket.cancel()``, a missed ``deadline_rounds``
    or an exhausted ``node_budget``: the slot's best-so-far is recorded
    as an anytime result, its lanes are deactivated and unbound within
    one round, and the ticket resolves CANCELLED / EXPIRED.

This module is the PURE ROUND-STEPPING ENGINE of the request lifecycle:
it owns lanes, tables and the admit → round → retire → evict mechanics.
Every "which request, when" decision (admission order, deadlines,
budgets) is delegated to the :class:`~repro.service.scheduler.Scheduler`
policy layer, so scheduling policies plug in without touching this file.

``submit()`` returns a :class:`~repro.service.ticket.Ticket` — a
future-like handle with ``status`` / ``result(timeout=)`` / ``cancel()``.
Lifecycle transitions stream through the typed
:class:`~repro.solver.ProgressEvent` stream (kinds ``admit``, ``retire``,
``incumbent`` — per-request anytime incumbents — ``reject``, ``cancel``,
``expire``).  The legacy surface (``run()``, int-rid tickets) remains as
DeprecationWarning shims, bitwise-identical on the default policy.

Tenant isolation: stealing (intra- and cross-device) never pairs lanes
across instances, and per-instance incumbents mean one tenant's bound
never prunes another's tree — a slot's result is bitwise identical to a
dedicated single-instance solve (asserted against the serial oracle by
``tests/test_service.py``).

Elastic operation: ``save``/``restore`` persist the whole service (lane
control state + slot tables + the queued-request heap + ticket states)
through ``repro.core.checkpoint``; restoring onto W' ≠ W lanes parks
surplus tasks in an instance-tagged pending pool that drains at round
boundaries, and a restored queue pops in exactly the saved order.

The shared evaluate's masked-popcount pass is backend-pluggable
(``backend="jnp" | "pallas"``, forwarded to ``StackedSpec.bind`` —
DESIGN.md §5.3); the search is bitwise-identical under either, so the
backend is an execution choice like the lane count, not checkpoint state.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat, registry
from repro.core import checkpoint as ckpt
from repro.core.api import INF_VALUE, UNVISITED
from repro.core.distributed import (_gather_lanes, lane_partition_specs,
                                    make_round)
from repro.core.engine import NO_INSTANCE, init_lanes
from repro.problems.graphs import Graph, num_words
from repro.service.batch_problem import StackedSpec, StackedTables
from repro.service.scheduler import (AutoscalePolicy, Scheduler,
                                     SchedulingPolicy, QueueItem,
                                     make_policy)
from repro.service.ticket import (TERMINAL, AdmissionError, RequestResult,
                                  SolveRequest, Ticket, TicketStatus)

__all__ = [
    "AdmissionError",
    "RequestResult",
    "SolveRequest",
    "SolverService",
]


class _ResultMap(dict):
    """Results keyed by int rid; lookups normalize Tickets through
    ``int()`` so pre-ticket code (``results[svc.submit(r)]``) keeps
    working — via the Ticket.__int__ deprecation shim."""

    def __getitem__(self, key):
        return super().__getitem__(int(key))

    def __contains__(self, key):
        return super().__contains__(int(key))

    def get(self, key, default=None):
        return super().get(int(key), default)


class SolverService:
    """Fixed pool of W lanes continuously batched over streamed requests.

    Construct through :meth:`repro.solver.Solver.serve` (or
    :meth:`from_config`); direct ``SolverService(...)`` construction is the
    deprecated pre-facade surface and emits ``DeprecationWarning``.
    """

    def __init__(self, *, max_n: int, slots: int, num_lanes: int,
                 steps_per_round: int = 64, backend: str = "jnp",
                 scheduler: Union[str, SchedulingPolicy] = "priority",
                 fused_steps: int = 1):
        warnings.warn(
            "direct SolverService(...) construction is deprecated; use "
            "repro.solver.Solver(SolverConfig(...)).serve(max_n=..., "
            "slots=...)", DeprecationWarning, stacklevel=2)
        self._init(max_n=max_n, slots=slots, num_lanes=num_lanes,
                   steps_per_round=steps_per_round, backend=backend,
                   scheduler=scheduler, fused_steps=fused_steps)

    @classmethod
    def from_config(cls, config, *, max_n: int, slots: int,
                    on_event: Optional[Callable[[Any], None]] = None
                    ) -> "SolverService":
        """The facade constructor: lanes / steps_per_round / backend /
        scheduler / fused_steps / telemetry come from a
        :class:`repro.solver.SolverConfig`."""
        return cls._create(max_n=max_n, slots=slots,
                           num_lanes=config.lanes,
                           steps_per_round=config.steps_per_round,
                           backend=config.backend,
                           scheduler=config.scheduler,
                           fused_steps=getattr(config, "fused_steps", 1),
                           mesh=getattr(config, "mesh", None),
                           max_ship=getattr(config, "max_ship", 16),
                           autoscale=getattr(config, "autoscale", None),
                           trace_path=getattr(config, "trace_path", None),
                           metrics=getattr(config, "metrics", False),
                           on_event=on_event)

    @classmethod
    def _create(cls, **kwargs) -> "SolverService":
        svc = object.__new__(cls)
        svc._init(**kwargs)
        return svc

    def _init(self, *, max_n: int, slots: int, num_lanes: int,
              steps_per_round: int = 64, backend: str = "jnp",
              scheduler: Union[str, SchedulingPolicy] = "priority",
              fused_steps: int = 1, mesh: Optional[Mesh] = None,
              max_ship: int = 16,
              autoscale: Optional[AutoscalePolicy] = None,
              trace_path: Optional[str] = None, metrics: bool = False,
              on_event: Optional[Callable[[Any], None]] = None):
        self.spec = StackedSpec(n=max_n, k=slots)
        self.steps_per_round = steps_per_round
        self.backend = backend                # shared-evaluate kernel backend
        self.fused_steps = fused_steps        # S steps per expand iteration
        self.max_ship = max_ship              # cross-device ship cap / round
        self.on_event = on_event              # ProgressEvent stream (§6)
        self.autoscale = autoscale            # elasticity policy, or None
        self.tables = self.spec.empty_tables()           # host numpy
        self._tables_dev: Optional[StackedTables] = None

        # Mesh layout (DESIGN.md §9): ``num_lanes`` is the PER-DEVICE lane
        # count (SolverConfig.lanes semantics); the pool is partitioned
        # over the mesh and the round runs under shard_map with the
        # stacked tables and incumbent state replicated per device.
        self.mesh = mesh
        self.n_devices = (int(np.prod(mesh.devices.shape))
                          if mesh is not None else 1)
        self.lanes_per_device = num_lanes
        self.num_lanes = num_lanes * self.n_devices
        self._build_round_fns()

        proto = self.spec.bind(self._tables_jnp())
        self.lanes = init_lanes(proto, self.num_lanes, seed_root=False,
                                bind_instance=False)

        policy = (scheduler if not isinstance(scheduler, str)
                  else make_policy(scheduler))
        self.sched = Scheduler(policy)
        self.slot_rid: List[int] = [-1] * slots          # -1 = free slot
        self.slot_admitted: List[int] = [0] * slots
        self._slot_best_seen: List[int] = [int(INF_VALUE)] * slots
        self.results: Dict[int, RequestResult] = _ResultMap()
        self.pool: List[ckpt.PendingTask] = []
        self.rounds = 0
        # True when the steady-state placement check last passed with at
        # most one live slot — _admit_and_place then skips its device
        # readback entirely until the next placement-changing event
        # (admission, retire/evict, resize, pool install) clears it.
        self._placement_clean = False

        # Telemetry (DESIGN.md §8): one RoundCollector rides the service,
        # fed host-side at round boundaries — no extra device syncs.
        self.metrics_enabled = bool(metrics)
        self._collector = None
        if metrics or trace_path is not None:
            from repro import obs
            self._collector = obs.RoundCollector(
                mode="service", lanes=self.num_lanes, slots=slots,
                steps_per_round=steps_per_round, fused_steps=fused_steps,
                backend=backend, devices=self.n_devices,
                trace=obs.TraceWriter(trace_path) if trace_path else None)
            self._collector.start(self.lanes)

    def _build_round_fns(self) -> None:
        """(Re)jit the round + stack-rebuild closures for the current mesh
        — called at construction and after every :meth:`resize`."""
        spec, backend = self.spec, self.backend
        steps, fused = self.steps_per_round, self.fused_steps
        mesh = self.mesh

        def _rebuild(lanes, tables):
            return ckpt.rebuild_stacks(spec.bind(tables, backend), lanes)

        self._rebuild = jax.jit(_rebuild)
        if mesh is None:
            def _round(lanes, tables):
                return make_round(spec.bind(tables, backend), steps,
                                  fused_steps=fused)(lanes)

            self._round = jax.jit(_round)
            return
        axes = tuple(mesh.axis_names)
        max_ship = self.max_ship

        def _round(lanes, tables):
            return make_round(spec.bind(tables, backend), steps, axes,
                              max_ship, fused)(lanes)

        lane_specs = lane_partition_specs(
            spec.bind(self._tables_jnp(), backend), axes)
        table_specs = StackedTables(P(), P(), P())    # replicated per device
        self._round = jax.jit(compat.shard_map(
            _round, mesh=mesh, in_specs=(lane_specs, table_specs),
            out_specs=(lane_specs, P()), check=False))

    def metrics(self):
        """``repro.obs.MetricsSnapshot`` of this service's registry, or
        None when telemetry is off (enable via
        ``SolverConfig(metrics=True)`` or ``trace_path=...``)."""
        return (self._collector.snapshot()
                if self._collector is not None else None)

    def finalize_trace(self) -> None:
        """Append a trace ``summary`` record (per-lane / per-instance
        totals so far).  Called automatically by :meth:`drain`; call it
        directly when stepping rounds by hand.  Idempotent — readers use
        the last summary."""
        if self._collector is not None:
            self._collector.finish(
                rounds=self.rounds,
                best=[int(b) for b in np.asarray(self.lanes.best)])

    # -- host/device plumbing ----------------------------------------------

    def _tables_jnp(self) -> StackedTables:
        if self._tables_dev is None:
            self._tables_dev = StackedTables(
                *(jnp.asarray(t) for t in self.tables))
        return self._tables_dev

    def _touch_tables(self) -> None:
        self._tables_dev = None

    # -- the ticketed front door -------------------------------------------

    @property
    def queue(self) -> Tuple[SolveRequest, ...]:
        """Queued (not yet admitted) requests, in pop order."""
        return tuple(item.request for item in self.sched.pending())

    @property
    def tickets(self) -> Dict[int, Ticket]:
        """Every ticket this service has issued, by rid."""
        return self.sched.tickets

    def submit(self, request: SolveRequest) -> Ticket:
        """Queue a request after full admission validation; returns its
        :class:`~repro.service.ticket.Ticket`.

        Any registered family with service packing is admissible — there is
        no per-family name table here; new families become servable the
        moment their ``@register_problem`` call supplies ``pack`` +
        ``family_id``.  Anything the service can never run raises
        :class:`AdmissionError` (never a deep packing failure), after a
        ``reject`` ProgressEvent so observers see refusals too.
        """
        reason = None
        try:
            spec = registry.get(request.family)
        except registry.UnknownProblemError as e:
            reason = str(e)
        else:
            n = spec.size(request.graph)
            if not spec.servable:
                reason = (f"problem family {request.family!r} is registered "
                          f"but not servable (no service packing in its "
                          f"@register_problem call)")
            elif n > self.spec.n:
                reason = (f"request n={n} exceeds service "
                          f"max_n={self.spec.n}")
            elif (request.rid in self.sched.tickets
                  or request.rid in self.slot_rid
                  or request.rid in self.results):
                # slot_rid/results cover in-flight and finished rids from
                # pre-ticket checkpoints, which carry no ticket table.
                reason = f"duplicate request id {request.rid}"
            elif (request.deadline_rounds is not None
                  and request.deadline_rounds < 1):
                reason = (f"deadline_rounds must be >= 1, got "
                          f"{request.deadline_rounds}")
            elif request.node_budget is not None and request.node_budget < 1:
                reason = f"node_budget must be >= 1, got {request.node_budget}"
        if reason is not None:
            self._emit("reject", rid=request.rid, reason=reason)
            if self._collector is not None:
                self._collector.lifecycle("reject", round_no=self.rounds,
                                          rid=request.rid, reason=reason)
            raise AdmissionError(reason)
        return self.sched.enqueue(request, now_round=self.rounds,
                                  service=self)

    def cancel(self, rid: int) -> bool:
        """Cancel request ``rid`` (the ``Ticket.cancel`` implementation).

        QUEUED: removed from the admission queue.  RUNNING: the slot is
        freed and its lanes reclaimed immediately — within one round — and
        the best-so-far is recorded as an anytime result.  Returns False
        for unknown or already-terminal rids.
        """
        ticket = self.sched.tickets.get(rid)
        if ticket is None or ticket.status in TERMINAL:
            return False
        best = None
        if ticket.status is TicketStatus.QUEUED:
            self.sched.remove_queued(rid)
        else:
            result = self._evict_slot(self.slot_rid.index(rid), "cancelled")
            best = result.optimum
        self.sched.resolve(rid, TicketStatus.CANCELLED, self.rounds)
        self._emit("cancel", rid=rid, best=best)
        self._note_lifecycle("cancel", rid, best=best)
        return True

    def _emit(self, kind: str, **kw) -> None:
        # One emission path for both drivers (repro.solver.emit): kind is
        # validated against EVENT_KINDS, so typos raise instead of flowing.
        from repro.solver import emit
        emit(self.on_event, kind, round=self.rounds, **kw)

    def _note_lifecycle(self, kind: str, rid: int,
                        best: Optional[int] = None) -> None:
        """Trace a terminal request transition with its wait/run rounds."""
        if self._collector is None:
            return
        ticket = self.sched.tickets.get(rid)
        self._collector.lifecycle(
            kind, round_no=self.rounds, rid=rid, best=best,
            waited=ticket.wait_rounds if ticket is not None else None,
            ran=ticket.run_rounds if ticket is not None else None)

    def _host_lane_fields(self):
        l = self.lanes
        return {
            "idx": np.asarray(l.idx).copy(),
            "depth": np.asarray(l.depth).copy(),
            "base": np.asarray(l.base).copy(),
            "inst": np.asarray(l.inst).copy(),
            "active": np.asarray(l.active).copy(),
            "t_s": np.asarray(l.t_s).copy(),
            "best": np.asarray(l.best).copy(),
        }

    def _admit_and_place(self) -> bool:
        """Admit queued requests into free slots and (re)target idle lanes.

        Admission ORDER is the scheduling policy's (priority heap by
        default); this method only supplies the mechanics.  Returns True
        when lane control state changed (stacks need replay).
        """
        # Steady-state fast path: nothing to drain/admit and every idle
        # lane already points at its round-robin live slot — skip the full
        # host round-trip (only ``active``/``inst`` are needed to decide).
        if not self.pool and not (len(self.sched)
                                  and any(r < 0 for r in self.slot_rid)):
            if self._placement_clean:
                return False             # no device readback at all
            live = [s for s in range(self.spec.k) if self.slot_rid[s] >= 0]
            # One-time validation readback after a placement-changing
            # event; with ≤1 live slot the jitted round can only move
            # lanes between idle-on and active-on that slot, so a passed
            # check stays true until the next host-side event.
            # repro-lint: disable=trace-safety -- event-driven: guarded by _placement_clean, not per-round
            active = np.asarray(self.lanes.active)
            # repro-lint: disable=trace-safety -- event-driven: guarded by _placement_clean, not per-round
            inst = np.asarray(self.lanes.inst)
            idle = np.flatnonzero(~active)
            wants = [live[j % len(live)] if live else NO_INSTANCE
                     for j in range(len(idle))]
            if all(inst[lane] == want for lane, want in zip(idle, wants)):
                self._placement_clean = len(live) <= 1
                return False

        h = self._host_lane_fields()
        idle = [i for i in range(self.num_lanes) if not h["active"][i]]
        changed = False

        # Pending-pool drain first: restored tasks have priority over fresh
        # roots for idle lanes (they are already-owned subtrees).
        while self.pool and idle:
            task = self.pool.pop(0)
            lane = idle.pop(0)
            il = h["idx"].shape[1]
            width = min(il, task.idx.shape[0])
            h["idx"][lane, :] = int(UNVISITED)
            h["idx"][lane, :width] = task.idx[:width]
            h["depth"][lane], h["base"][lane] = task.depth, task.base
            h["inst"][lane], h["active"][lane] = task.inst, True
            h["t_s"][lane] += 1
            changed = True

        # Admission: one free slot + one idle lane per popped request.
        free = [s for s in range(self.spec.k) if self.slot_rid[s] < 0]
        payload_host = None
        while len(self.sched) and free and idle:
            item = self.sched.pop_admission()
            if item is None:
                break
            req = item.request
            slot = free.pop(0)
            lane = idle.pop(0)
            # Family-oblivious packing: the registered spec carries the
            # stacked-table encoding (family id included in its return).
            adj, fm, fam = registry.get(req.family).pack(req.graph,
                                                         self.spec.n)
            self.tables.adj[slot] = adj
            self.tables.fullm[slot] = fm
            self.tables.family[slot] = fam
            self._touch_tables()
            self.slot_rid[slot] = req.rid
            self.slot_admitted[slot] = self.rounds
            self._slot_best_seen[slot] = int(INF_VALUE)
            ticket = self.sched.tickets.get(req.rid)
            if ticket is not None:
                ticket.status = TicketStatus.RUNNING
                ticket.admitted_round = self.rounds
            # Reset the slot incumbent, seed the root on the chosen lane.
            h["best"][slot] = int(INF_VALUE)
            if payload_host is None:
                payload_host = jax.tree_util.tree_map(
                    lambda p: np.asarray(p).copy(), self.lanes.best_payload)
            payload_host = jax.tree_util.tree_map(
                lambda p: _zero_row(p, slot), payload_host)
            h["idx"][lane, :] = int(UNVISITED)
            h["depth"][lane] = h["base"][lane] = 0
            h["inst"][lane], h["active"][lane] = slot, True
            h["t_s"][lane] += 1
            changed = True
            self._emit("admit", rid=req.rid)
            if self._collector is not None:
                self._collector.lifecycle(
                    "admit", round_no=self.rounds, rid=req.rid, slot=slot,
                    waited=(ticket.wait_rounds if ticket is not None
                            else None))

        # Retarget remaining idle lanes round-robin over live slots so the
        # next steal round can feed them (instance-scoped thieves).
        live = [s for s in range(self.spec.k) if self.slot_rid[s] >= 0]
        retargeted = False
        for j, lane in enumerate(idle):
            want = live[j % len(live)] if live else NO_INSTANCE
            if h["inst"][lane] != want:
                h["inst"][lane] = want   # no stack impact: lane stays idle
                retargeted = True

        if not changed and not retargeted:
            # Placement verified against the full host mirror: single-
            # tenant steady state can skip even the validation readback.
            self._placement_clean = len(live) <= 1
            return False                 # steady state: no host->device copy
        self.lanes = self.lanes._replace(
            idx=jnp.asarray(h["idx"]), depth=jnp.asarray(h["depth"]),
            base=jnp.asarray(h["base"]), inst=jnp.asarray(h["inst"]),
            active=jnp.asarray(h["active"]), t_s=jnp.asarray(h["t_s"]),
            best=jnp.asarray(h["best"]),
            best_payload=(self.lanes.best_payload if payload_host is None
                          else jax.tree_util.tree_map(jnp.asarray,
                                                      payload_host)))
        if changed:
            # CONVERTINDEX replay rebuilds the stacks of seeded/installed
            # lanes (replaying untouched active lanes is a no-op by the
            # determinism contract).
            self.lanes = self._rebuild(self.lanes, self._tables_jnp())
        # The host mirror h was just written to device, with idle lanes
        # retargeted to their round-robin wants by construction.
        self._placement_clean = len(live) <= 1
        return changed

    # -- retirement / eviction ----------------------------------------------

    def _retire(self, open_vec: np.ndarray) -> None:
        h_inst = None
        for slot in range(self.spec.k):
            rid = self.slot_rid[slot]
            if rid < 0 or open_vec[slot] != 0:
                continue
            if any(t.inst == slot for t in self.pool):
                continue                      # restored work still pending
            payload = jax.tree_util.tree_map(
                lambda p: np.asarray(p[slot]), self.lanes.best_payload)
            self.results[rid] = RequestResult(
                rid=rid,
                optimum=int(np.asarray(self.lanes.best)[slot]),
                payload=payload,
                admitted_round=self.slot_admitted[slot],
                retired_round=self.rounds)
            self.sched.resolve(rid, TicketStatus.DONE, self.rounds)
            self._emit("retire", rid=rid, best=self.results[rid].optimum)
            self._note_lifecycle("retire", rid,
                                 best=self.results[rid].optimum)
            self.slot_rid[slot] = -1
            # Unbind the retired slot's (now idle) lanes.
            if h_inst is None:
                # repro-lint: disable=trace-safety -- event-driven: only when a slot actually retires this round
                h_inst = np.asarray(self.lanes.inst).copy()
            h_inst[h_inst == slot] = NO_INSTANCE
        if h_inst is not None:
            self.lanes = self.lanes._replace(inst=jnp.asarray(h_inst))
            self._placement_clean = False

    def _evict_slot(self, slot: int, status: str) -> RequestResult:
        """Free a slot mid-flight: record the best-so-far as an anytime
        result, then reclaim its lanes through the retire path's unbinding
        — extended to still-active lanes, which are deactivated (their
        subtrees are abandoned with the request) — and drop its
        pending-pool tasks.  The slot is reusable by the very next
        admission, i.e. eviction frees capacity within one round."""
        rid = self.slot_rid[slot]
        payload = jax.tree_util.tree_map(
            lambda p: np.asarray(p[slot]).copy(), self.lanes.best_payload)
        result = RequestResult(
            rid=rid,
            optimum=int(np.asarray(self.lanes.best)[slot]),
            payload=payload,
            admitted_round=self.slot_admitted[slot],
            retired_round=self.rounds,
            status=status)
        self.results[rid] = result
        self.slot_rid[slot] = -1
        self._placement_clean = False
        # repro-lint: disable=trace-safety -- event-driven: eviction only, not on the per-round path
        inst = np.asarray(self.lanes.inst).copy()
        # repro-lint: disable=trace-safety -- event-driven: eviction only, not on the per-round path
        active = np.asarray(self.lanes.active).copy()
        mine = inst == slot
        active[mine] = False
        inst[mine] = NO_INSTANCE
        self.lanes = self.lanes._replace(inst=jnp.asarray(inst),
                                         active=jnp.asarray(active))
        self.pool = [t for t in self.pool if t.inst != slot]
        return result

    def _expire(self) -> None:
        """End-of-round deadline/budget sweep (the scheduler decides WHO,
        this method does the surgery)."""
        queued, running = self.sched.overdue(self.rounds)
        for rid in queued:
            self.sched.remove_queued(rid)
            self.sched.resolve(rid, TicketStatus.EXPIRED, self.rounds)
            # Never admitted: the anytime result is the empty incumbent.
            self.results[rid] = RequestResult(
                rid=rid, optimum=int(INF_VALUE),
                payload=jax.tree_util.tree_map(
                    lambda p: np.zeros_like(np.asarray(p)[0]),
                    self.lanes.best_payload),
                admitted_round=-1, retired_round=self.rounds,
                status="expired")
            self._emit("expire", rid=rid)
            self._note_lifecycle("expire", rid)
        for rid in running:
            result = self._evict_slot(self.slot_rid.index(rid), "expired")
            self.sched.resolve(rid, TicketStatus.EXPIRED, self.rounds)
            self._emit("expire", rid=rid, best=result.optimum)
            self._note_lifecycle("expire", rid, best=result.optimum)

    def _emit_incumbents(self) -> None:
        """Per-request anytime incumbent stream: one ``incumbent`` event
        each time a slot's bound improves.  Only costs the device readback
        when someone is listening."""
        if self.on_event is None:
            return
        best = np.asarray(self.lanes.best)
        for slot in range(self.spec.k):
            rid = self.slot_rid[slot]
            if rid >= 0 and int(best[slot]) < self._slot_best_seen[slot]:
                self._slot_best_seen[slot] = int(best[slot])
                self._emit("incumbent", rid=rid, best=int(best[slot]))

    # -- the service loop ---------------------------------------------------

    def _has_work(self) -> bool:
        return (len(self.sched) > 0 or bool(self.pool)
                or any(r >= 0 for r in self.slot_rid))

    def step_round(self) -> np.ndarray:
        """One service cycle: admit → round → retire → evict.
        Returns the per-slot open-work vector."""
        track = self.sched.track_nodes()
        col = self._collector
        changed = self._admit_and_place()
        if col is not None:
            # Host-side surgery (admission seeds, pool installs) bumps t_s
            # — refresh the baseline so steal deltas cover the jitted
            # round only.
            col.before_round(self.lanes, dirty=changed)
            nodes_before = None
        else:
            nodes_before = (np.asarray(self.lanes.nodes).copy()
                            if track else None)
        lanes, open_vec = self._round(self.lanes, self._tables_jnp())
        self.lanes = lanes
        self.rounds += 1
        open_np = np.asarray(open_vec)
        inst_delta = None
        if col is not None:
            inst_delta = col.after_round(
                self.rounds, self.lanes, int(open_np.sum()),
                queue_depth=self.sched.queue_depth(),
                slot_rids=self.slot_rid)
        if track:
            # Round-granular attribution: a lane's node delta this round is
            # charged to the instance it serves at the round boundary.
            # The collector computes exactly this delta already — reuse it
            # rather than paying a second readback.
            if inst_delta is None:
                delta = np.asarray(self.lanes.nodes) - nodes_before
                # repro-lint: disable=trace-safety -- deliberate: node-attribution fallback only when tracking without a collector
                inst = np.asarray(self.lanes.inst)
                inst_delta = np.zeros((self.spec.k,), np.int64)
                for slot in range(self.spec.k):
                    inst_delta[slot] = int(delta[inst == slot].sum())
            for slot in range(self.spec.k):
                rid = self.slot_rid[slot]
                if rid >= 0 and inst_delta[slot]:
                    self.sched.note_nodes(rid, int(inst_delta[slot]))
        self._emit("round", open_work=int(open_np.sum()),
                   metrics=(col.snapshot()
                            if col is not None and self.metrics_enabled
                            and self.on_event is not None else None))
        self._emit_incumbents()
        self._retire(open_np)
        self._expire()
        self.maybe_autoscale()
        return open_np

    def drain(self, max_rounds: int = 100000) -> Dict[int, RequestResult]:
        """Step rounds until every submitted request is terminal."""
        start = self.rounds
        while self._has_work():
            if self.rounds - start >= max_rounds:
                raise RuntimeError(
                    f"service did not drain in {max_rounds} rounds; "
                    f"slots={self.slot_rid} queue={len(self.queue)}")
            self.step_round()
        self.finalize_trace()
        return self.results

    def run(self, requests: Optional[List[SolveRequest]] = None,
            max_rounds: int = 100000) -> Dict[int, RequestResult]:
        """Deprecated batch-era drain: admit ``requests`` plus anything
        queued, solve them all.  ``submit()`` now returns a Ticket — use
        ``Ticket.result()`` per request or :meth:`drain` for the pool.
        Bitwise-identical to the ticketed path on the default policy."""
        warnings.warn(
            "SolverService.run() is deprecated; submit() returns a Ticket "
            "— use Ticket.result(), or SolverService.drain()",
            DeprecationWarning, stacklevel=2)
        for r in requests or []:
            self.submit(r)
        return self.drain(max_rounds)

    # -- elastic mesh membership --------------------------------------------

    def resize(self, *, mesh: Optional[Mesh] = None,
               num_lanes: Optional[int] = None) -> None:
        """Re-layout the live pool onto a different mesh / per-device lane
        count mid-run (the join-leave half of paper §VII, in memory).

        Goes through the elastic W' ≠ W checkpoint/restore machinery
        (``repro.core.checkpoint.repartition``): the first W' in-flight
        tasks land on the new lanes, surplus parks in the instance-tagged
        pending pool, per-instance incumbents and aggregate counters are
        carried over exactly.  Tickets, results, queue and tables stay
        live in place — outstanding :class:`Ticket` handles keep working.
        The round closure is re-jitted for the new mesh (the one real cost
        — which is why :class:`AutoscalePolicy` carries a cooldown).
        """
        per_dev = (self.lanes_per_device if num_lanes is None
                   else int(num_lanes))
        n_dev = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
        total = per_dev * n_dev
        if total < 1:
            raise ValueError(f"resize to {total} lanes")
        old_dev, old_total = self.n_devices, self.num_lanes
        problem = self.spec.bind(self._tables_jnp(), self.backend)
        lanes_host = _gather_lanes(self.lanes)
        new_lanes, surplus = ckpt.repartition(problem, lanes_host, total)
        self.mesh = mesh
        self.n_devices = n_dev
        self.lanes_per_device = per_dev
        self.num_lanes = total
        self.lanes = new_lanes
        self.pool.extend(surplus)
        self._placement_clean = False
        self._build_round_fns()
        if self._collector is not None:
            self._collector.resize(total, devices=n_dev,
                                   round_no=self.rounds)
        self._emit("resize", reason=f"devices {old_dev}->{n_dev}, "
                                    f"lanes {old_total}->{total}")

    def maybe_autoscale(self) -> bool:
        """Ask the :class:`AutoscalePolicy` (when configured) whether to
        change the device count; perform the :meth:`resize` if so.  Runs
        once per round from :meth:`step_round` — the semi-centralized
        scheduler layer's elasticity hook."""
        if self.autoscale is None:
            return False
        target = self.autoscale.decide(
            queue_depth=self.sched.queue_depth(), devices=self.n_devices,
            now_round=self.rounds,
            busy=any(r >= 0 for r in self.slot_rid) or bool(self.pool))
        if target is None or target == self.n_devices:
            return False
        devices = jax.devices()
        if target > len(devices):
            return False
        mesh = (jax.make_mesh((target,), ("workers",),
                              devices=devices[:target])
                if target > 1 else None)
        self.resize(mesh=mesh)
        return True

    # -- elastic checkpoint -------------------------------------------------

    def save(self, path: str) -> None:
        """Persist lanes + slot tables + pending pool + the queued-request
        heap + ticket states in one atomic file.

        An un-drained service round-trips: queued (never-admitted)
        requests are stored with their graphs and admission sequence
        numbers so the restored policy heap pops in the saved order, and
        every ticket's lifecycle state (status, deadlines, budgets, node
        usage) is carried in a JSON sidecar array
        (``repro.core.checkpoint.pack_json``).

        Queued-instance persistence assumes graph-shaped instances (the
        same assumption the stacked tables themselves make — ``pack``
        returns adjacency rows); a future non-graph servable family needs
        a registry-provided encode/decode hook here.
        """
        pool_n = len(self.pool)
        il = self.lanes.idx.shape[1]
        pool_idx = np.full((pool_n, il), int(UNVISITED), np.int8)
        pool_meta = np.zeros((pool_n, 3), np.int32)     # depth, base, inst
        for i, t in enumerate(self.pool):
            width = min(il, t.idx.shape[0])
            pool_idx[i, :width] = t.idx[:width]
            pool_meta[i] = (t.depth, t.base, t.inst)

        pending = self.sched.pending()
        queue_adj = np.zeros((len(pending), self.spec.n,
                              num_words(self.spec.n)), np.uint32)
        queue_meta = []
        for i, item in enumerate(pending):
            g = item.request.graph
            queue_adj[i, :g.n, :g.words] = g.adj
            queue_meta.append({
                "rid": item.request.rid, "family": item.request.family,
                "name": g.name, "n": g.n, "seq": item.seq,
                "priority": item.request.priority,
                "deadline_rounds": item.request.deadline_rounds,
                "node_budget": item.request.node_budget,
            })
        done = sorted(self.results.values(), key=lambda r: r.rid)
        result_payload = (np.stack([np.asarray(r.payload) for r in done])
                          if done else np.zeros((0,), np.uint32))
        sched_meta = {
            "scheduler": self.sched.policy.name,
            "seq": self.sched.seq,
            "queue": queue_meta,
            "tickets": [{
                "rid": t.rid, "status": t.status.value,
                "priority": t.priority, "deadline_round": t.deadline_round,
                "node_budget": t.node_budget,
                "submitted_round": t.submitted_round,
                "admitted_round": t.admitted_round,
                "finished_round": t.finished_round,
                "nodes_used": t.nodes_used,
            } for t in self.sched.tickets.values()],
            "results": [{
                "rid": r.rid, "optimum": r.optimum,
                "admitted_round": r.admitted_round,
                "retired_round": r.retired_round, "status": r.status,
            } for r in done],
        }
        extra = {
            "adj": self.tables.adj, "fullm": self.tables.fullm,
            "family": self.tables.family,
            "slot_rid": np.asarray(self.slot_rid, np.int32),
            "slot_admitted": np.asarray(self.slot_admitted, np.int32),
            "spec": np.asarray([self.spec.n, self.spec.k], np.int32),
            "rounds": np.asarray(self.rounds, np.int32),
            "slot_best_seen": np.asarray(self._slot_best_seen, np.int32),
            "pool_idx": pool_idx, "pool_meta": pool_meta,
            "queue_adj": queue_adj,
            "result_payload": result_payload,
            "sched_meta": ckpt.pack_json(sched_meta),
        }
        ckpt.save(path, self.lanes, extra=extra)

    @classmethod
    def restore(cls, path: str, *, num_lanes: int,
                steps_per_round: int = 64, backend: str = "jnp",
                scheduler: Optional[Union[str, SchedulingPolicy]] = None,
                mesh: Optional[Mesh] = None, max_ship: int = 16,
                trace_path: Optional[str] = None, metrics: bool = False
                ) -> "SolverService":
        """Rebuild the service onto ``num_lanes`` lanes per device
        (elastic W' ≠ W; ``mesh`` — like the lane count and backend — is
        an execution choice, so a service saved single-device restores
        sharded and vice versa).

        Surplus in-flight tasks wait in the pending pool and are installed
        as lanes free up.  Queued (never-admitted) requests ARE persisted
        with their admission sequence, so the restored policy heap pops in
        the saved order; every ticket's state (including terminal ones)
        round-trips, with restored tickets re-bound to the new service.
        ``backend`` (like ``num_lanes``) is an execution choice, not
        checkpoint state: a service saved under one backend restores under
        any other with a bitwise-identical search (DESIGN.md §5.3), and
        ``scheduler`` defaults to the checkpointed policy but may be
        overridden — the queue is re-pushed through the new policy.
        """
        extra = ckpt.read_extra(path)
        n, k = (int(x) for x in extra["spec"])
        meta = (ckpt.unpack_json(extra["sched_meta"])
                if "sched_meta" in extra else
                {"scheduler": "priority", "seq": 0, "queue": [],
                 "tickets": [], "results": []})
        svc = cls._create(max_n=n, slots=k, num_lanes=num_lanes,
                          steps_per_round=steps_per_round, backend=backend,
                          scheduler=(meta["scheduler"] if scheduler is None
                                     else scheduler),
                          mesh=mesh, max_ship=max_ship,
                          trace_path=trace_path, metrics=metrics)
        svc.tables = StackedTables(
            adj=extra["adj"].copy(), fullm=extra["fullm"].copy(),
            family=extra["family"].copy())
        svc._touch_tables()
        problem = svc.spec.bind(svc._tables_jnp(), backend)
        svc.lanes, svc.pool = ckpt.restore(path, problem, svc.num_lanes)
        for i in range(extra["pool_idx"].shape[0]):
            d, b, inst = (int(x) for x in extra["pool_meta"][i])
            svc.pool.append(ckpt.PendingTask(extra["pool_idx"][i].copy(),
                                             d, b, inst))
        svc.slot_rid = [int(r) for r in extra["slot_rid"]]
        svc.slot_admitted = [int(r) for r in extra["slot_admitted"]]
        svc.rounds = int(extra["rounds"])
        if svc._collector is not None:
            # Re-baseline on the restored lanes so the first round's deltas
            # exclude the carried checkpoint totals.
            svc._collector.start(svc.lanes)
        if "slot_best_seen" in extra:     # keep the incumbent stream exact
            svc._slot_best_seen = [int(b) for b in extra["slot_best_seen"]]

        for t in meta["tickets"]:
            svc.sched.adopt(Ticket(
                rid=t["rid"], priority=t["priority"],
                deadline_round=t["deadline_round"],
                node_budget=t["node_budget"],
                status=TicketStatus(t["status"]),
                submitted_round=t["submitted_round"],
                admitted_round=t["admitted_round"],
                finished_round=t["finished_round"],
                nodes_used=t["nodes_used"], _service=svc))
        for i, q in enumerate(meta["queue"]):
            graph = Graph(n=q["n"],
                          adj=extra["queue_adj"][i, :q["n"],
                                                 :num_words(q["n"])].copy(),
                          name=q["name"])
            svc.sched.policy.push(QueueItem(q["seq"], SolveRequest(
                rid=q["rid"], graph=graph, family=q["family"],
                priority=q["priority"],
                deadline_rounds=q["deadline_rounds"],
                node_budget=q["node_budget"])))
        svc.sched.seq = int(meta["seq"])
        for i, r in enumerate(meta["results"]):
            svc.results[r["rid"]] = RequestResult(
                rid=r["rid"], optimum=r["optimum"],
                payload=extra["result_payload"][i].copy(),
                admitted_round=r["admitted_round"],
                retired_round=r["retired_round"], status=r["status"])
        return svc


def _zero_row(arr: np.ndarray, row: int) -> np.ndarray:
    arr[row] = np.zeros_like(arr[row])
    return arr
