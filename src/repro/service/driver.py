"""Continuous-batching solver service over one lane pool.

The serving pattern of ``repro.serve.driver`` (fixed slot pool, lockstep
ticks, admission/retirement at tick boundaries) applied to backtracking:

  * the *pool* is W engine lanes advancing in lockstep under one jitted
    round (expand → instance-scoped steal → per-instance termination);
  * a *slot* is one of K stacked-instance table entries
    (``batch_problem.StackedSpec``); a request occupies a slot from
    admission to retirement;
  * *admission* resolves the request's family through the
    :mod:`repro.registry` (any registered family with service packing is
    admissible — no name table here; invalid requests raise a typed
    :class:`AdmissionError` at ``submit()`` time) and writes the padded
    instance into the stacked tables (they are jit ARGUMENTS, so no
    recompilation), resets the slot's incumbent and seeds the instance
    root onto one idle lane — every other lane the instance ever uses
    arrives via stealing, the same bootstrap the paper uses for its
    virtual topology;
  * *retirement* fires when the per-instance open-work counter reaches
    zero: the slot's optimum + payload are recorded and the slot is free
    for the next queued request.

Tenant isolation: stealing (intra- and cross-device) never pairs lanes
across instances, and per-instance incumbents mean one tenant's bound
never prunes another's tree — a slot's result is bitwise identical to a
dedicated single-instance solve (asserted against the serial oracle by
``tests/test_service.py``).

Elastic operation: ``save``/``restore`` persist the whole service (lane
control state + slot tables + queue-of-record metadata) through
``repro.core.checkpoint``; restoring onto W' ≠ W lanes parks surplus tasks
in an instance-tagged pending pool that drains at round boundaries.

The shared evaluate's masked-popcount pass is backend-pluggable
(``backend="jnp" | "pallas"``, forwarded to ``StackedSpec.bind`` —
DESIGN.md §5.3); the search is bitwise-identical under either, so the
backend is an execution choice like the lane count, not checkpoint state.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.core import checkpoint as ckpt
from repro.core.api import INF_VALUE, UNVISITED
from repro.core.distributed import make_round
from repro.core.engine import NO_INSTANCE, init_lanes
from repro.problems.graphs import Graph
from repro.service.batch_problem import StackedSpec, StackedTables


class AdmissionError(ValueError):
    """A request the service can never run: unregistered family, family
    without service packing, or instance larger than the deployment's
    ``max_n``.  Raised at ``submit()`` time — never deep inside packing."""


@dataclasses.dataclass
class SolveRequest:
    """One tenant's instance.  ``family`` is any *servable* registered
    problem family (``repro.registry.get(family).servable``)."""

    rid: int
    graph: Graph
    family: str


@dataclasses.dataclass
class RequestResult:
    rid: int
    optimum: int
    payload: np.ndarray        # uint32[w] solution bitset (padded width)
    admitted_round: int
    retired_round: int


class SolverService:
    """Fixed pool of W lanes continuously batched over streamed requests.

    Construct through :meth:`repro.solver.Solver.serve` (or
    :meth:`from_config`); direct ``SolverService(...)`` construction is the
    deprecated pre-facade surface and emits ``DeprecationWarning``.
    """

    def __init__(self, *, max_n: int, slots: int, num_lanes: int,
                 steps_per_round: int = 64, backend: str = "jnp"):
        warnings.warn(
            "direct SolverService(...) construction is deprecated; use "
            "repro.solver.Solver(SolverConfig(...)).serve(max_n=..., "
            "slots=...)", DeprecationWarning, stacklevel=2)
        self._init(max_n=max_n, slots=slots, num_lanes=num_lanes,
                   steps_per_round=steps_per_round, backend=backend)

    @classmethod
    def from_config(cls, config, *, max_n: int, slots: int,
                    on_event: Optional[Callable[[Any], None]] = None
                    ) -> "SolverService":
        """The facade constructor: lanes / steps_per_round / backend come
        from a :class:`repro.solver.SolverConfig`."""
        return cls._create(max_n=max_n, slots=slots,
                           num_lanes=config.lanes,
                           steps_per_round=config.steps_per_round,
                           backend=config.backend, on_event=on_event)

    @classmethod
    def _create(cls, **kwargs) -> "SolverService":
        svc = object.__new__(cls)
        svc._init(**kwargs)
        return svc

    def _init(self, *, max_n: int, slots: int, num_lanes: int,
              steps_per_round: int = 64, backend: str = "jnp",
              on_event: Optional[Callable[[Any], None]] = None):
        self.spec = StackedSpec(n=max_n, k=slots)
        self.num_lanes = num_lanes
        self.steps_per_round = steps_per_round
        self.backend = backend                # shared-evaluate kernel backend
        self.on_event = on_event              # ProgressEvent stream (§6)
        self.tables = self.spec.empty_tables()           # host numpy
        self._tables_dev: Optional[StackedTables] = None

        spec = self.spec

        def _round(lanes, tables):
            return make_round(spec.bind(tables, backend), steps_per_round)(
                lanes)

        def _rebuild(lanes, tables):
            return ckpt.rebuild_stacks(spec.bind(tables, backend), lanes)

        self._round = jax.jit(_round)
        self._rebuild = jax.jit(_rebuild)

        proto = spec.bind(self._tables_jnp())
        lanes = init_lanes(proto, num_lanes, seed_root=False)
        self.lanes = lanes._replace(
            inst=jnp.full((num_lanes,), NO_INSTANCE, jnp.int32))

        self.queue: Deque[SolveRequest] = deque()
        self.slot_rid: List[int] = [-1] * slots          # -1 = free slot
        self.slot_admitted: List[int] = [0] * slots
        self.results: Dict[int, RequestResult] = {}
        self.pool: List[ckpt.PendingTask] = []
        self.rounds = 0

    # -- host/device plumbing ----------------------------------------------

    def _tables_jnp(self) -> StackedTables:
        if self._tables_dev is None:
            self._tables_dev = StackedTables(
                *(jnp.asarray(t) for t in self.tables))
        return self._tables_dev

    def _touch_tables(self) -> None:
        self._tables_dev = None

    # -- admission / lane placement ----------------------------------------

    def submit(self, request: SolveRequest) -> int:
        """Queue a request after full admission validation.

        Any registered family with service packing is admissible — there is
        no per-family name table here; new families become servable the
        moment their ``@register_problem`` call supplies ``pack`` +
        ``family_id``.  Raises :class:`AdmissionError` (never a deep
        packing failure) for anything the service can never run.
        """
        try:
            spec = registry.get(request.family)
        except registry.UnknownProblemError as e:
            raise AdmissionError(str(e)) from None
        if not spec.servable:
            raise AdmissionError(
                f"problem family {request.family!r} is registered but not "
                f"servable (no service packing in its @register_problem "
                f"call)")
        n = spec.size(request.graph)
        if n > self.spec.n:
            raise AdmissionError(
                f"request n={n} exceeds service max_n={self.spec.n}")
        self.queue.append(request)
        return request.rid

    def _emit(self, kind: str, **kw) -> None:
        if self.on_event is not None:
            from repro.solver import ProgressEvent
            self.on_event(ProgressEvent(kind=kind, round=self.rounds, **kw))

    def _host_lane_fields(self):
        l = self.lanes
        return {
            "idx": np.asarray(l.idx).copy(),
            "depth": np.asarray(l.depth).copy(),
            "base": np.asarray(l.base).copy(),
            "inst": np.asarray(l.inst).copy(),
            "active": np.asarray(l.active).copy(),
            "t_s": np.asarray(l.t_s).copy(),
            "best": np.asarray(l.best).copy(),
        }

    def _admit_and_place(self) -> bool:
        """Admit queued requests into free slots and (re)target idle lanes.

        Returns True when lane control state changed (stacks need replay).
        """
        # Steady-state fast path: nothing to drain/admit and every idle
        # lane already points at its round-robin live slot — skip the full
        # host round-trip (only ``active``/``inst`` are needed to decide).
        if not self.pool and not (self.queue
                                  and any(r < 0 for r in self.slot_rid)):
            active = np.asarray(self.lanes.active)
            inst = np.asarray(self.lanes.inst)
            idle = np.flatnonzero(~active)
            live = [s for s in range(self.spec.k) if self.slot_rid[s] >= 0]
            wants = [live[j % len(live)] if live else NO_INSTANCE
                     for j in range(len(idle))]
            if all(inst[lane] == want for lane, want in zip(idle, wants)):
                return False

        h = self._host_lane_fields()
        idle = [i for i in range(self.num_lanes) if not h["active"][i]]
        changed = False

        # Pending-pool drain first: restored tasks have priority over fresh
        # roots for idle lanes (they are already-owned subtrees).
        while self.pool and idle:
            task = self.pool.pop(0)
            lane = idle.pop(0)
            il = h["idx"].shape[1]
            width = min(il, task.idx.shape[0])
            h["idx"][lane, :] = int(UNVISITED)
            h["idx"][lane, :width] = task.idx[:width]
            h["depth"][lane], h["base"][lane] = task.depth, task.base
            h["inst"][lane], h["active"][lane] = task.inst, True
            h["t_s"][lane] += 1
            changed = True

        # Admission: one free slot + one idle lane per queued request.
        free = [s for s in range(self.spec.k) if self.slot_rid[s] < 0]
        payload_host = None
        while self.queue and free and idle:
            req = self.queue.popleft()
            slot = free.pop(0)
            lane = idle.pop(0)
            # Family-oblivious packing: the registered spec carries the
            # stacked-table encoding (family id included in its return).
            adj, fm, fam = registry.get(req.family).pack(req.graph,
                                                         self.spec.n)
            self.tables.adj[slot] = adj
            self.tables.fullm[slot] = fm
            self.tables.family[slot] = fam
            self._touch_tables()
            self.slot_rid[slot] = req.rid
            self.slot_admitted[slot] = self.rounds
            # Reset the slot incumbent, seed the root on the chosen lane.
            h["best"][slot] = int(INF_VALUE)
            if payload_host is None:
                payload_host = jax.tree_util.tree_map(
                    lambda p: np.asarray(p).copy(), self.lanes.best_payload)
            payload_host = jax.tree_util.tree_map(
                lambda p: _zero_row(p, slot), payload_host)
            h["idx"][lane, :] = int(UNVISITED)
            h["depth"][lane] = h["base"][lane] = 0
            h["inst"][lane], h["active"][lane] = slot, True
            h["t_s"][lane] += 1
            changed = True
            self._emit("admit", rid=req.rid)

        # Retarget remaining idle lanes round-robin over live slots so the
        # next steal round can feed them (instance-scoped thieves).
        live = [s for s in range(self.spec.k) if self.slot_rid[s] >= 0]
        retargeted = False
        for j, lane in enumerate(idle):
            want = live[j % len(live)] if live else NO_INSTANCE
            if h["inst"][lane] != want:
                h["inst"][lane] = want   # no stack impact: lane stays idle
                retargeted = True

        if not changed and not retargeted:
            return False                 # steady state: no host->device copy
        self.lanes = self.lanes._replace(
            idx=jnp.asarray(h["idx"]), depth=jnp.asarray(h["depth"]),
            base=jnp.asarray(h["base"]), inst=jnp.asarray(h["inst"]),
            active=jnp.asarray(h["active"]), t_s=jnp.asarray(h["t_s"]),
            best=jnp.asarray(h["best"]),
            best_payload=(self.lanes.best_payload if payload_host is None
                          else jax.tree_util.tree_map(jnp.asarray,
                                                      payload_host)))
        if changed:
            # CONVERTINDEX replay rebuilds the stacks of seeded/installed
            # lanes (replaying untouched active lanes is a no-op by the
            # determinism contract).
            self.lanes = self._rebuild(self.lanes, self._tables_jnp())
        return changed

    # -- retirement ---------------------------------------------------------

    def _retire(self, open_vec: np.ndarray) -> None:
        h_inst = None
        for slot in range(self.spec.k):
            rid = self.slot_rid[slot]
            if rid < 0 or open_vec[slot] != 0:
                continue
            if any(t.inst == slot for t in self.pool):
                continue                      # restored work still pending
            payload = jax.tree_util.tree_map(
                lambda p: np.asarray(p[slot]), self.lanes.best_payload)
            self.results[rid] = RequestResult(
                rid=rid,
                optimum=int(np.asarray(self.lanes.best)[slot]),
                payload=payload,
                admitted_round=self.slot_admitted[slot],
                retired_round=self.rounds)
            self._emit("retire", rid=rid, best=self.results[rid].optimum)
            self.slot_rid[slot] = -1
            # Unbind the retired slot's (now idle) lanes.
            if h_inst is None:
                h_inst = np.asarray(self.lanes.inst).copy()
            h_inst[h_inst == slot] = NO_INSTANCE
        if h_inst is not None:
            self.lanes = self.lanes._replace(inst=jnp.asarray(h_inst))

    # -- the service loop ---------------------------------------------------

    def _has_work(self) -> bool:
        return (bool(self.queue) or bool(self.pool)
                or any(r >= 0 for r in self.slot_rid))

    def step_round(self) -> np.ndarray:
        """One service cycle: admit → round → retire.  Returns open-work."""
        self._admit_and_place()
        lanes, open_vec = self._round(self.lanes, self._tables_jnp())
        self.lanes = lanes
        self.rounds += 1
        open_np = np.asarray(open_vec)
        self._emit("round", open_work=int(open_np.sum()))
        self._retire(open_np)
        return open_np

    def run(self, requests: Optional[List[SolveRequest]] = None,
            max_rounds: int = 100000) -> Dict[int, RequestResult]:
        """Drain: admit ``requests`` plus anything queued, solve them all."""
        for r in requests or []:
            self.submit(r)
        start = self.rounds
        while self._has_work():
            if self.rounds - start >= max_rounds:
                raise RuntimeError(
                    f"service did not drain in {max_rounds} rounds; "
                    f"slots={self.slot_rid} queue={len(self.queue)}")
            self.step_round()
        return self.results

    # -- elastic checkpoint -------------------------------------------------

    def save(self, path: str) -> None:
        """Persist lanes + slot tables + pending pool in one atomic file."""
        pool_n = len(self.pool)
        il = self.lanes.idx.shape[1]
        pool_idx = np.full((pool_n, il), int(UNVISITED), np.int8)
        pool_meta = np.zeros((pool_n, 3), np.int32)     # depth, base, inst
        for i, t in enumerate(self.pool):
            width = min(il, t.idx.shape[0])
            pool_idx[i, :width] = t.idx[:width]
            pool_meta[i] = (t.depth, t.base, t.inst)
        extra = {
            "adj": self.tables.adj, "fullm": self.tables.fullm,
            "family": self.tables.family,
            "slot_rid": np.asarray(self.slot_rid, np.int32),
            "slot_admitted": np.asarray(self.slot_admitted, np.int32),
            "spec": np.asarray([self.spec.n, self.spec.k], np.int32),
            "rounds": np.asarray(self.rounds, np.int32),
            "pool_idx": pool_idx, "pool_meta": pool_meta,
        }
        ckpt.save(path, self.lanes, extra=extra)

    @classmethod
    def restore(cls, path: str, *, num_lanes: int,
                steps_per_round: int = 64,
                backend: str = "jnp") -> "SolverService":
        """Rebuild the service onto ``num_lanes`` lanes (elastic W' ≠ W).

        Surplus in-flight tasks wait in the pending pool and are installed
        as lanes free up; unstarted queued requests are NOT persisted —
        resubmit them.  Results for slots still in flight are produced
        under the same rids recorded at save time.  ``backend`` (like
        ``num_lanes``) is an execution choice, not checkpoint state: a
        service saved under one backend restores under any other with a
        bitwise-identical search (DESIGN.md §5.3).
        """
        extra = ckpt.read_extra(path)
        n, k = (int(x) for x in extra["spec"])
        svc = cls._create(max_n=n, slots=k, num_lanes=num_lanes,
                          steps_per_round=steps_per_round, backend=backend)
        svc.tables = StackedTables(
            adj=extra["adj"].copy(), fullm=extra["fullm"].copy(),
            family=extra["family"].copy())
        svc._touch_tables()
        problem = svc.spec.bind(svc._tables_jnp(), backend)
        svc.lanes, svc.pool = ckpt.restore(path, problem, num_lanes)
        for i in range(extra["pool_idx"].shape[0]):
            d, b, inst = (int(x) for x in extra["pool_meta"][i])
            svc.pool.append(ckpt.PendingTask(extra["pool_idx"][i].copy(),
                                             d, b, inst))
        svc.slot_rid = [int(r) for r in extra["slot_rid"]]
        svc.slot_admitted = [int(r) for r in extra["slot_admitted"]]
        svc.rounds = int(extra["rounds"])
        return svc


def _zero_row(arr: np.ndarray, row: int) -> np.ndarray:
    arr[row] = np.zeros_like(arr[row])
    return arr
