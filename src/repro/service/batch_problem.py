"""Stacked multi-instance problems for the solver service.

The paper's framework turns one hard instance into thousands of tiny
indexed tasks; the service inverts the workload — MANY instances share one
lane pool.  The enabler is the same compact encoding: a lane's identity is
O(D) int8 plus one int32 instance id, so pointing a lane at a different
instance is an index swap plus CONVERTINDEX replay.

``StackedSpec`` describes K instance *slots*, each a graph padded to a
common vertex count ``n`` (padding vertices are isolated and start dead,
which provably leaves the branch-and-bound tree of the unpadded instance
untouched — every padded vertex has count -1 in the shared
coverage/degree pass, so max/argmax/bound are unchanged).  Two problem
families share the slots:

  FAMILY_VC — minimum vertex cover (``adj`` row block = adjacency);
  FAMILY_DS — minimum dominating set (``adj`` row block = CLOSED adjacency).

Both families funnel their per-node work through ONE masked-popcount pass
(DESIGN.md §1): for VC the mask is the alive set and the counts are
residual degrees; for DS the mask is the undominated set and the counts are
coverage.  The fused ``evaluate`` computes that pass once on
``tables.adj[state.inst]`` and blends the family-specific solution test,
bound, children and payload branchlessly — so a vmapped engine step over
lanes serving different tenants stays a single fused kernel.

The pass itself is backend-pluggable (``StackedSpec.bind(..., backend)``,
same seam as the single-instance problems):

  backend="jnp"     — gather ``tables.adj[inst]`` and materialize the
                      [n, w] masked matrix per lane;
  backend="pallas"  — ``repro.kernels.bitset_ops.stacked_count_stats``,
                      the batched uint32[K, n, w] variant of the universal
                      masked-popcount kernel: each lane's table block is
                      selected by instance id via scalar prefetch, so the
                      kernel never touches the other K-1 tables
                      (DESIGN.md §5.3; interpret-mode off-TPU).

``StackedTables`` is runtime DATA, not a trace-time constant: the service
driver passes it as an argument to the jitted round, so admitting a new
instance is a host-side table write with NO recompilation — under either
backend (the stacked tables are kernel *operands*, never constants).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem, NodeEval, tree_select
from repro.problems.graphs import Graph, full_mask, num_words

FAMILY_VC = 0
FAMILY_DS = 1

#: Kernel backends the stacked shared-evaluate accepts (``StackedSpec.bind``)
#: — the service-side capability surface (DESIGN.md §5.3/§6).
STACKED_BACKENDS = ("jnp", "pallas")


class StackedTables(NamedTuple):
    """Per-slot instance data (leaves are device arrays inside the jit)."""

    adj: jnp.ndarray      # uint32[K, n, w] — adjacency (vc) / closed adj (ds)
    fullm: jnp.ndarray    # uint32[K, w]    — the slot's real-vertex mask
    family: jnp.ndarray   # int32[K]        — FAMILY_VC | FAMILY_DS


class SvcState(NamedTuple):
    """Union state: (a, b, c) mean (alive, cover, -) for VC and
    (dominated, cand, chosen) for DS.  ``inst`` rides in the state so that
    ``evaluate`` can index the stacked tables without an engine-protocol
    change; ``Lanes.inst`` is the engine-side authority and the two are
    kept equal by construction (roots embed it, children inherit it)."""

    inst: jnp.ndarray     # int32 []
    a: jnp.ndarray        # uint32[w]
    b: jnp.ndarray        # uint32[w]
    c: jnp.ndarray        # uint32[w]
    size: jnp.ndarray     # int32 []


def pack_instance(graph: Graph, family: int, n: int
                  ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad one instance to ``n`` vertices: (adj[n, w], fullm[w], family).

    For FAMILY_DS the row block is the CLOSED adjacency (N[v]), matching
    ``repro.problems.dominating_set``.
    """
    if graph.n > n:
        raise ValueError(f"instance n={graph.n} exceeds slot size n={n}")
    w = num_words(n)
    adj = np.zeros((n, w), np.uint32)
    adj[:graph.n, :graph.words] = graph.adj
    if family == FAMILY_DS:
        for v in range(graph.n):
            adj[v, v // 32] |= np.uint32(1) << np.uint32(v % 32)
    elif family != FAMILY_VC:
        raise ValueError(f"unknown family {family!r}")
    fm = np.zeros(w, np.uint32)
    fm[:graph.words] = full_mask(graph.n)
    return adj, fm, family


@dataclasses.dataclass(frozen=True)
class StackedSpec:
    """Static shape of a service deployment: K slots of up-to-n vertices."""

    n: int          # padded vertex count (max instance size)
    k: int          # instance slots multiplexed over the lane pool

    @property
    def words(self) -> int:
        return num_words(self.n)

    def empty_tables(self) -> StackedTables:
        """Host-side numpy tables with every slot free (edgeless VC —
        instantly solved if ever seeded, but free slots are never seeded)."""
        return StackedTables(
            adj=np.zeros((self.k, self.n, self.words), np.uint32),
            fullm=np.zeros((self.k, self.words), np.uint32),
            family=np.zeros((self.k,), np.int32))

    def bind(self, tables: StackedTables, backend: str = "jnp", *,
             tile: Optional[int] = None,
             interpret: Optional[bool] = None) -> BinaryProblem:
        """Build the K-instance BinaryProblem over (possibly traced) tables.

        ``backend`` routes the shared masked-popcount pass (see module
        docstring) — "jnp" or "pallas"; both are NodeEval-identical.
        Under "pallas" the problem also carries ``evaluate_batch``, the
        fused-round fast path: all W lanes' masked-popcount passes become
        ONE ``stacked_count_stats`` launch per engine step (DESIGN.md
        §5.5).  ``tile=None`` defers the block shape to the per-shape
        autotuner (DESIGN.md §5.6).
        """
        n, w, k = self.n, self.words, self.k
        word = jnp.asarray(np.arange(n, dtype=np.int32) // 32)
        shift = jnp.asarray((np.arange(n, dtype=np.int32) % 32)
                            .astype(np.uint32))
        one = jnp.uint32(1)
        zero_mask = jnp.zeros((w,), jnp.uint32)

        if backend == "pallas":
            from repro.kernels import ops

            def shared_stats(i, mask, validm, undom):
                # undom is recomputed by the kernel as the pass's mask
                # popcount (== |undominated| for DS lanes, whose mask IS
                # the undominated set; VC lanes never consume it).
                out = ops.stacked_count_stats(
                    tables.adj, i[None], mask[None, :], validm[None, :],
                    tile=tile, use_pallas=True, interpret=interpret)[0]
                return out[0], jnp.maximum(out[1], 0), out[2], out[3]
        elif backend == "jnp":
            def shared_stats(i, mask, validm, undom):
                rows = jnp.bitwise_and(tables.adj[i], mask[None, :])
                cnt = jax.lax.population_count(rows).sum(axis=1).astype(
                    jnp.int32)
                valid_f = ((validm[word] >> shift) & one) == one
                cnt = jnp.where(valid_f, cnt, jnp.int32(-1))
                u = jax.lax.population_count(undom).sum().astype(jnp.int32)
                return (jnp.max(cnt), jnp.argmax(cnt).astype(jnp.int32),
                        jnp.sum(jnp.maximum(cnt, 0)), u)
        else:
            raise ValueError(f"unknown stacked-service backend {backend!r}")

        def vbit(v):
            return jnp.where(jnp.arange(w) == (v // 32),
                             one << (v.astype(jnp.uint32) % 32),
                             jnp.uint32(0))

        def instance_root(inst) -> SvcState:
            i = jnp.clip(jnp.asarray(inst, jnp.int32), 0, k - 1)
            is_vc = tables.family[i] == FAMILY_VC
            fm = tables.fullm[i]
            return SvcState(
                inst=jnp.asarray(inst, jnp.int32),
                a=jnp.where(is_vc, fm, zero_mask),   # alive / dominated
                b=jnp.where(is_vc, zero_mask, fm),   # cover / cand
                c=zero_mask,
                size=jnp.int32(0))

        def _stats_inputs(state: SvcState):
            """The shared pass's operands, per lane (clipped instance id —
            idle lanes evaluate against slot 0 and are discarded, so the
            scalar and batched paths agree bitwise).

            VC: mask = alive set       → counts = residual degrees.
            DS: mask = undominated set → counts = coverage |N[v] \\ dom|.
            """
            i = jnp.clip(state.inst, 0, k - 1)
            is_vc = tables.family[i] == FAMILY_VC
            undom = jnp.bitwise_and(tables.fullm[i],
                                    jnp.bitwise_not(state.a))
            mask = jnp.where(is_vc, state.a, undom)
            validm = jnp.where(is_vc, state.a, state.b)   # alive / candidates
            return i, mask, validm, undom

        def _finish(state: SvcState, best: jnp.ndarray, cmax, v, csum,
                    u) -> NodeEval:
            """Everything after the shared pass: family-specific solution
            test, admissible bound, and both children."""
            i = jnp.clip(state.inst, 0, k - 1)
            is_vc = tables.family[i] == FAMILY_VC
            vc_sol = cmax <= 0
            d_eff = jnp.maximum(cmax, 1)
            vc_lb = state.size + (csum + 2 * d_eff - 1) // (2 * d_eff)

            ds_sol = u == 0
            infeasible = (u > 0) & (cmax <= 0)
            bc = jnp.maximum(cmax, 1)
            ds_lb = jnp.where(infeasible, INF_VALUE,
                              state.size + (u + bc - 1) // bc)

            # Children from the shared branch vertex.
            bv = vbit(v)
            row_v = tables.adj[i, v]
            nb = jnp.bitwise_and(row_v, state.a)          # vc: alive N(v)
            nb_count = jax.lax.population_count(nb).sum().astype(jnp.int32)
            new_cand = jnp.bitwise_and(state.b, jnp.bitwise_not(bv))

            vc_left = SvcState(
                inst=state.inst,
                a=jnp.bitwise_and(state.a, jnp.bitwise_not(bv)),
                b=jnp.bitwise_or(state.b, bv), c=state.c,
                size=state.size + 1)
            vc_right = SvcState(
                inst=state.inst,
                a=jnp.bitwise_and(state.a,
                                  jnp.bitwise_not(jnp.bitwise_or(nb, bv))),
                b=jnp.bitwise_or(state.b, nb), c=state.c,
                size=state.size + nb_count)
            ds_left = SvcState(
                inst=state.inst,
                a=jnp.bitwise_or(state.a, row_v),
                b=new_cand,
                c=jnp.bitwise_or(state.c, bv),
                size=state.size + 1)
            ds_right = SvcState(
                inst=state.inst, a=state.a, b=new_cand, c=state.c,
                size=state.size)

            return NodeEval(
                is_solution=jnp.where(is_vc, vc_sol, ds_sol),
                value=state.size,
                lower_bound=jnp.where(is_vc, vc_lb, ds_lb),
                left=tree_select(is_vc, vc_left, ds_left),
                right=tree_select(is_vc, vc_right, ds_right),
                payload=jnp.where(is_vc, state.b, state.c))

        def evaluate(state: SvcState, best: jnp.ndarray) -> NodeEval:
            i, mask, validm, undom = _stats_inputs(state)
            cmax, v, csum, u = shared_stats(i, mask, validm, undom)
            return _finish(state, best, cmax, v, csum, u)

        evaluate_batch = None
        if backend == "pallas":
            def evaluate_batch(states: SvcState,
                               best: jnp.ndarray) -> NodeEval:
                # ONE kernel launch covers every lane's shared pass: the
                # stacked kernel batches the whole [L, w] mask block into
                # each grid step instead of one pallas_call per lane.
                i, mask, validm, _ = jax.vmap(_stats_inputs)(states)
                out = ops.stacked_count_stats(
                    tables.adj, i, mask, validm, tile=tile,
                    use_pallas=True, interpret=interpret)
                return jax.vmap(_finish)(
                    states, best, out[:, 0], jnp.maximum(out[:, 1], 0),
                    out[:, 2], out[:, 3])

        return BinaryProblem(
            name=f"stacked[k={k},n={n}]",
            max_depth=n,
            root=lambda: instance_root(jnp.int32(0)),
            evaluate=evaluate,
            payload_zero=lambda: jnp.zeros((w,), jnp.uint32),
            num_instances=k,
            instance_root=instance_root,
            evaluate_batch=evaluate_batch,
        )
