"""trace-safety: no host↔device syncs inside jitted round-loop code.

The engine's BSP contract (DESIGN.md §3, §6) is that the only host
syncs are the *deliberate* ones at round boundaries (the driver reading
``open_work`` / admission bookkeeping).  Anything that forces a device
readback *inside* traced code — ``.item()``, ``int()/bool()/float()``
of a traced value, ``np.asarray`` of a device array, a Python
``if``/``while`` branching on a traced operand — either breaks tracing
outright or, worse, silently re-traces / re-syncs every round.

The pass works in three stages, all purely static:

1. **Traced-context discovery.**  Any function object passed to a
   tracing primitive (``jax.jit``, ``compat.shard_map``, ``jax.vmap``,
   ``jax.lax.while_loop/fori_loop/cond/scan/switch``,
   ``pl.pallas_call``, ``pl.when`` — call or decorator form, including
   ``partial(jax.jit, ...)``) is traced.  Builders are propagated one
   level: ``jax.jit(make_round(...))`` marks the functions *returned
   by* ``make_round`` as traced (the repo's round/expand/step closures
   are all built this way).  Resolution follows module-level names,
   ``from repro.x import y`` symbols and ``import repro.x as m``
   aliases across every analyzed file.
2. **Closure propagation.**  Functions *called by name* from traced
   bodies are traced transitively (``round_fn`` → ``expand`` → ``step``
   → ``steal.balance_device`` → ...).  Methods and attribute calls that
   do not resolve to an analyzed function are out of scope (v1
   limitation, documented in DESIGN.md §10).
3. **Taint + hazard scan** per traced function: positional parameters
   (minus those with static scalar annotations — ``int``, ``bool``,
   ``Optional[int]`` etc. declare compile-time values) and results of
   ``jnp.``/``jax.``/``lax.``/``pl.``-rooted calls are traced values;
   taint flows through assignments, tuple unpacking and ``for``
   targets to a fixpoint.  Hazards are reported where a tainted value
   reaches a sync construct.  ``x.shape``/``.ndim``/``.dtype``/``.size``
   are static metadata and ``is None``/``isinstance`` tests are
   host-side by construction, so neither taints a branch.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, RepoContext, Rule, register

# Attribute-form tracing primitives: X.<name>(fn, ...) marks fn traced.
_PRIMITIVE_ATTRS = {
    "jit", "vmap", "pmap", "shard_map", "pallas_call",
    "while_loop", "fori_loop", "cond", "scan", "switch", "when",
    "checkpoint", "remat", "custom_jvp", "custom_vjp",
}
# Bare-name forms accepted (unambiguous enough to match without a root).
_PRIMITIVE_NAMES = {"jit", "vmap", "shard_map", "pallas_call"}

#: Annotations declaring a parameter static (host-side) by contract.
_STATIC_ANNOTATIONS = {
    "int", "bool", "float", "str", "bytes",
    "Optional[int]", "Optional[bool]", "Optional[float]", "Optional[str]",
    "Sequence[str]", "Tuple[str, ...]", "Tuple[str,...]", "List[str]",
}

#: Attribute reads that are static metadata, not device values.
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

#: Roots whose call results are traced arrays.
_TRACED_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}

#: jax.* functions that return *host* values, not traced arrays.
_HOST_API = {
    "default_backend", "devices", "local_devices", "device_count",
    "local_device_count", "process_index", "process_count",
}

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


class _FuncInfo:
    __slots__ = ("node", "mod", "parent", "local_funcs",
                 "builder_values", "lambdas", "traced")

    def __init__(self, node, mod: Module, parent: Optional["_FuncInfo"]):
        self.node = node              # FunctionDef | AsyncFunctionDef | Lambda
        self.mod = mod
        self.parent = parent
        self.local_funcs: Dict[str, "_FuncInfo"] = {}
        self.builder_values: Dict[str, ast.expr] = {}   # name = some_call(...)
        self.lambdas: List["_FuncInfo"] = []
        self.traced = False

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


class _ModuleIndex:
    __slots__ = ("mod", "funcs", "import_modules", "import_symbols",
                 "numpy_aliases")

    def __init__(self, mod: Module):
        self.mod = mod
        self.funcs: Dict[str, _FuncInfo] = {}        # module-level defs
        self.import_modules: Dict[str, str] = {}     # alias -> dotted
        self.import_symbols: Dict[str, Tuple[str, str]] = {}  # alias -> (mod, name)
        self.numpy_aliases: Set[str] = set()


class _Project:
    """Cross-file index: functions, imports, and every call site with
    its enclosing function scope."""

    def __init__(self, ctx: RepoContext):
        self.ctx = ctx
        self.indexes: Dict[str, _ModuleIndex] = {}   # Module.rel -> index
        self.calls: List[Tuple[ast.Call, Optional[_FuncInfo], Module]] = []
        self.all_funcs: List[_FuncInfo] = []
        for mod in ctx.modules:
            self._index_module(mod)

    # -- construction -----------------------------------------------------

    def _index_module(self, mod: Module) -> None:
        idx = _ModuleIndex(mod)
        self.indexes[mod.rel] = idx
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    asname = alias.asname or alias.name.split(".")[0]
                    idx.import_modules[asname] = (
                        alias.name if alias.asname else
                        alias.name.split(".")[0])
                    if alias.name == "numpy":
                        idx.numpy_aliases.add(asname)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    asname = alias.asname or alias.name
                    full = f"{node.module}.{alias.name}"
                    if node.module == "numpy":
                        idx.numpy_aliases.add(asname)
                    idx.import_modules.setdefault(asname, full)
                    idx.import_symbols[asname] = (node.module, alias.name)
        for stmt in mod.tree.body:
            self._visit(stmt, mod, idx, None)

    def _visit(self, node, mod: Module, idx: _ModuleIndex,
               scope: Optional[_FuncInfo]) -> None:
        """Recursive visitor: collect functions (with their scope
        chain), builder bindings, and every call site."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = _FuncInfo(node, mod, scope)
            self.all_funcs.append(info)
            if scope is None:
                idx.funcs.setdefault(node.name, info)
            else:
                scope.local_funcs[node.name] = info
            for dec in node.decorator_list:
                self._visit(dec, mod, idx, scope)
                if _is_primitive_expr(dec):
                    info.traced = True
            for default in (node.args.defaults + node.args.kw_defaults):
                if default is not None:
                    self._visit(default, mod, idx, scope)
            for stmt in node.body:
                self._visit(stmt, mod, idx, info)
            return
        if isinstance(node, ast.Lambda):
            info = _FuncInfo(node, mod, scope)
            self.all_funcs.append(info)
            if scope is not None:
                scope.lambdas.append(info)
            self._visit(node.body, mod, idx, info)
            return
        if isinstance(node, ast.ClassDef):
            # Methods resolve like module-scope siblings of the class
            # body; the class adds no name scope for our purposes.
            for dec in node.decorator_list:
                self._visit(dec, mod, idx, scope)
            for stmt in node.body:
                self._visit(stmt, mod, idx, scope)
            return
        if isinstance(node, ast.Assign) and scope is not None and \
                isinstance(node.value, ast.Call):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    scope.builder_values[tgt.id] = node.value
        if isinstance(node, ast.Call):
            self.calls.append((node, scope, mod))
        for child in ast.iter_child_nodes(node):
            self._visit(child, mod, idx, scope)

    # -- resolution -------------------------------------------------------

    def resolve_name(self, name: str, scope: Optional[_FuncInfo],
                     mod: Module) -> Optional[_FuncInfo]:
        s = scope
        while s is not None:
            if name in s.local_funcs:
                return s.local_funcs[name]
            s = s.parent
        idx = self.indexes[mod.rel]
        if name in idx.funcs:
            return idx.funcs[name]
        sym = idx.import_symbols.get(name)
        if sym is not None:
            target = self.ctx.by_dotted.get(sym[0])
            if target is not None:
                tindex = self.indexes.get(target.rel)
                if tindex and sym[1] in tindex.funcs:
                    return tindex.funcs[sym[1]]
        return None

    def resolve_func_expr(self, expr, scope, mod) -> Optional[_FuncInfo]:
        """Resolve a callable expression to an analyzed function."""
        if isinstance(expr, ast.Name):
            return self.resolve_name(expr.id, scope, mod)
        if isinstance(expr, ast.Attribute) and isinstance(expr.value,
                                                          ast.Name):
            idx = self.indexes[mod.rel]
            dotted = idx.import_modules.get(expr.value.id)
            if dotted is not None:
                target = self.ctx.by_dotted.get(dotted)
                if target is not None:
                    tindex = self.indexes.get(target.rel)
                    if tindex and expr.attr in tindex.funcs:
                        return tindex.funcs[expr.attr]
        return None

    def builder_binding(self, name: str,
                        scope: Optional[_FuncInfo]) -> Optional[ast.expr]:
        s = scope
        while s is not None:
            if name in s.builder_values:
                return s.builder_values[name]
            s = s.parent
        return None

    # -- traced marking ---------------------------------------------------

    def returned_functions(self, info: _FuncInfo) -> List[_FuncInfo]:
        out: List[_FuncInfo] = []
        node = info.node
        if isinstance(node, ast.Lambda):
            return out
        for stmt in _walk_own_statements(node):
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                val = stmt.value
                if isinstance(val, ast.Name):
                    target = self.resolve_name(val.id, info, info.mod)
                    if target is not None:
                        out.append(target)
                elif isinstance(val, ast.Lambda):
                    for lam in info.lambdas:
                        if lam.node is val:
                            out.append(lam)
        return out

    def mark_callable_arg(self, arg, scope, mod,
                          worklist: List[_FuncInfo]) -> None:
        """An expression passed where a traced callable is expected."""
        if isinstance(arg, ast.Lambda):
            for info in self.all_funcs:
                if info.node is arg:
                    _mark(info, worklist)
            return
        if isinstance(arg, ast.Call):
            # partial(fn, ...) -> fn;  builder(...) -> builder's returns
            if _callee_name(arg.func) == "partial" and arg.args:
                self.mark_callable_arg(arg.args[0], scope, mod, worklist)
                return
            inner = self.resolve_func_expr(arg.func, scope, mod)
            if inner is not None:
                for ret in self.returned_functions(inner):
                    _mark(ret, worklist)
            return
        target = self.resolve_func_expr(arg, scope, mod)
        if target is None and isinstance(arg, ast.Name):
            bound = self.builder_binding(arg.id, scope)
            if bound is not None and isinstance(bound, ast.Call):
                inner = self.resolve_func_expr(bound.func, scope, mod)
                if inner is not None:
                    for ret in self.returned_functions(inner):
                        _mark(ret, worklist)
            return
        if target is not None:
            _mark(target, worklist)


def _mark(info: _FuncInfo, worklist: List[_FuncInfo]) -> None:
    if not info.traced:
        info.traced = True
        worklist.append(info)


def _callee_name(func_expr) -> Optional[str]:
    if isinstance(func_expr, ast.Name):
        return func_expr.id
    if isinstance(func_expr, ast.Attribute):
        return func_expr.attr
    return None


def _is_primitive_expr(expr) -> bool:
    """True for ``jax.jit`` / ``@partial(jax.jit, ...)`` style exprs."""
    if isinstance(expr, ast.Call):
        if _callee_name(expr.func) == "partial" and expr.args:
            return _is_primitive_expr(expr.args[0])
        return _is_primitive_expr(expr.func)
    if isinstance(expr, ast.Attribute):
        return expr.attr in _PRIMITIVE_ATTRS
    if isinstance(expr, ast.Name):
        return expr.id in _PRIMITIVE_NAMES
    return False


def _walk_own_statements(func_node):
    """Statements of a function body, descending into control flow but
    not into nested function/class definitions."""
    todo = list(func_node.body)
    while todo:
        stmt = todo.pop(0)
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            todo.extend(getattr(stmt, field, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            todo.extend(handler.body)


def _static_annotation(ann) -> bool:
    if ann is None:
        return False
    try:
        return ast.unparse(ann) in _STATIC_ANNOTATIONS
    except Exception:
        return False


class _Taint:
    """Per-function taint engine + hazard reporting."""

    def __init__(self, project: _Project, info: _FuncInfo):
        self.project = project
        self.info = info
        self.mod = info.mod
        self.numpy_aliases = project.indexes[info.mod.rel].numpy_aliases
        self.tainted: Set[str] = set()
        self._seed_params()

    def _seed_params(self) -> None:
        node = self.info.node
        args = node.args
        positional = list(args.posonlyargs) + list(args.args)
        n_defaults = len(args.defaults)
        for a in positional:
            if _static_annotation(a.annotation) or a.arg in ("self", "cls"):
                continue
            self.tainted.add(a.arg)
        # kw-only params are static config by repo convention (tile=,
        # stages=, interpret=...); params with literal defaults that are
        # plain constants are treated as static too.
        for a, default in zip(positional[len(positional) - n_defaults:],
                              args.defaults):
            if isinstance(default, ast.Constant):
                self.tainted.discard(a.arg)

    # -- taint computation -----------------------------------------------

    def is_tainted(self, expr) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in self.tainted
        if isinstance(expr, ast.Attribute):
            if expr.attr in _STATIC_ATTRS:
                return False
            return self.is_tainted(expr.value)
        if isinstance(expr, ast.Call):
            name = _callee_name(expr.func)
            if name in ("int", "bool", "float", "len", "isinstance",
                        "range", "type", "str"):
                return False     # host-scalar results (flagged elsewhere)
            root = expr.func
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name) and root.id in _TRACED_ROOTS:
                return name not in _HOST_API
            if isinstance(expr.func, ast.Attribute) and \
                    self.is_tainted(expr.func.value):
                return True      # method on a traced value
            return any(self.is_tainted(a) for a in expr.args) or \
                any(self.is_tainted(kw.value) for kw in expr.keywords)
        if isinstance(expr, ast.Constant):
            return False
        return any(self.is_tainted(child)
                   for child in ast.iter_child_nodes(expr)
                   if isinstance(child, ast.expr))

    def _taint_target(self, tgt) -> bool:
        # Subscript/attribute stores (`buf[i] = x`) do not taint the
        # container name — only whole-name (re)bindings propagate.
        if isinstance(tgt, (ast.Subscript, ast.Attribute)):
            return False
        changed = False
        for node in ast.walk(tgt):
            if isinstance(node, ast.Name) and node.id not in self.tainted:
                self.tainted.add(node.id)
                changed = True
        return changed

    def propagate(self) -> None:
        node = self.info.node
        if isinstance(node, ast.Lambda):
            return
        for _ in range(20):
            changed = False
            for stmt in _walk_own_statements(node):
                if isinstance(stmt, ast.Assign):
                    if self.is_tainted(stmt.value):
                        for tgt in stmt.targets:
                            changed |= self._taint_target(tgt)
                elif isinstance(stmt, ast.AugAssign):
                    if self.is_tainted(stmt.value) and \
                            isinstance(stmt.target, ast.Name):
                        changed |= self._taint_target(stmt.target)
                elif isinstance(stmt, ast.AnnAssign):
                    if stmt.value is not None and \
                            not _static_annotation(stmt.annotation) and \
                            self.is_tainted(stmt.value):
                        changed |= self._taint_target(stmt.target)
                elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                    if self.is_tainted(stmt.iter):
                        changed |= self._taint_target(stmt.target)
            if not changed:
                break

    # -- hazards ----------------------------------------------------------

    def _host_safe_test(self, test) -> bool:
        """Tests that never force a device sync even on traced values."""
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._host_safe_test(test.operand)
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return True
        if isinstance(test, ast.Call) and \
                _callee_name(test.func) == "isinstance":
            return True
        return False

    def hazards(self, rule: Rule) -> List[Finding]:
        node = self.info.node
        out: List[Finding] = []

        def add(anchor, msg):
            f = rule.finding(self.mod, anchor, msg)
            if f is not None:
                out.append(f)

        if isinstance(node, ast.Lambda):
            exprs = [node.body]
        else:
            exprs = []
            for stmt in _walk_own_statements(node):
                if isinstance(stmt, ast.While) and \
                        self.is_tainted(stmt.test) and \
                        not self._host_safe_test(stmt.test):
                    add(stmt, "Python `while` on a traced value inside "
                              "jitted code — restructure with "
                              "jax.lax.while_loop or hoist to the host "
                              "round boundary")
                if isinstance(stmt, ast.If) and \
                        self.is_tainted(stmt.test) and \
                        not self._host_safe_test(stmt.test):
                    add(stmt, "Python `if` on a traced value inside "
                              "jitted code — use jnp.where/lax.cond")
                for child in ast.iter_child_nodes(stmt):
                    if isinstance(child, ast.expr):
                        exprs.append(child)

        seen_calls = set()
        for expr in exprs:
            for call in ast.walk(expr):
                if not isinstance(call, ast.Call) or id(call) in seen_calls:
                    continue
                seen_calls.add(id(call))
                name = _callee_name(call.func)
                if name in ("int", "bool", "float") and call.args and \
                        isinstance(call.func, ast.Name) and \
                        self.is_tainted(call.args[0]):
                    add(call, f"`{name}()` of a traced value forces a "
                              "host sync inside jitted code — keep it a "
                              "jnp scalar or sync at the round boundary")
                elif isinstance(call.func, ast.Attribute) and \
                        call.func.attr in _SYNC_METHODS and \
                        self.is_tainted(call.func.value):
                    add(call, f"`.{call.func.attr}()` on a traced value "
                              "forces a host sync inside jitted code")
                elif isinstance(call.func, ast.Attribute) and \
                        call.func.attr in ("asarray", "array") and \
                        isinstance(call.func.value, ast.Name) and \
                        call.func.value.id in self.numpy_aliases and \
                        any(self.is_tainted(a) for a in call.args):
                    add(call, "`np.asarray`/`np.array` of a device array "
                              "forces a host transfer inside jitted code "
                              "— use jnp equivalents")
                elif isinstance(call.func, ast.Attribute) and \
                        call.func.attr == "device_get" and \
                        any(self.is_tainted(a) for a in call.args):
                    add(call, "`jax.device_get` inside jitted code forces "
                              "a host transfer")
        return out


@register
class TraceSafetyRule(Rule):
    name = "trace-safety"
    description = ("host-sync constructs inside functions reachable from "
                   "jax.jit / shard_map round-loop entry points")
    severity = "error"

    def run(self, ctx: RepoContext) -> List[Finding]:
        project = _Project(ctx)

        # Stage 1: primitive call sites mark their callable arguments.
        worklist: List[_FuncInfo] = [f for f in project.all_funcs
                                     if f.traced]
        for call, scope, mod in project.calls:
            if not _is_primitive_expr(call.func):
                continue
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                project.mark_callable_arg(arg, scope, mod, worklist)

        # Stage 2: propagate through calls from traced bodies.  Lambdas
        # defined in a traced function trace with it, so calls recorded
        # under lambda scopes flow naturally.
        calls_by_scope: Dict[int, List[ast.Call]] = {}
        for call, scope, _mod in project.calls:
            if scope is not None:
                calls_by_scope.setdefault(id(scope), []).append(call)
        processed: Set[int] = set()
        while worklist:
            info = worklist.pop()
            if id(info) in processed:
                continue
            processed.add(id(info))
            for lam in info.lambdas:
                _mark(lam, worklist)
            for call in calls_by_scope.get(id(info), []):
                target = project.resolve_func_expr(
                    call.func, info, info.mod)
                if target is not None:
                    _mark(target, worklist)
                    continue
                if isinstance(call.func, ast.Name):
                    bound = project.builder_binding(call.func.id, info)
                    if isinstance(bound, ast.Call):
                        inner = project.resolve_func_expr(
                            bound.func, info, info.mod)
                        if inner is not None:
                            for ret in project.returned_functions(inner):
                                _mark(ret, worklist)
                elif isinstance(call.func, ast.Call):
                    inner = project.resolve_func_expr(
                        call.func.func, info, info.mod)
                    if inner is not None:
                        for ret in project.returned_functions(inner):
                            _mark(ret, worklist)

        # Stage 3: taint + hazard scan over every traced function.
        findings: List[Finding] = []
        seen = set()
        for info in project.all_funcs:
            if not info.traced:
                continue
            taint = _Taint(project, info)
            taint.propagate()
            for f in taint.hazards(self):
                key = (f.path, f.line, f.message)
                if key not in seen:
                    seen.add(key)
                    findings.append(f)

        # Stage 4: the host half of the BSP contract — the per-round
        # service path gets ONE deliberate device sync (the open-work
        # readback).  Reading lane *placement* state (`active`/`inst`)
        # back via np.asarray anywhere reachable from step_round must be
        # event-driven (guarded by a dirty flag), not per-round.
        for mod in ctx.modules:
            findings.extend(self._round_path_syncs(mod, project))
        return findings

    def _round_path_syncs(self, mod: Module,
                          project: _Project) -> List[Finding]:
        out: List[Finding] = []
        numpy_aliases = project.indexes[mod.rel].numpy_aliases
        if not numpy_aliases:
            return out
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = {n.name: n for n in cls.body
                       if isinstance(n, ast.FunctionDef)}
            if "step_round" not in methods:
                continue
            # Intra-class reachability from step_round via self.m() calls.
            reach: Set[str] = set()
            todo = ["step_round"]
            while todo:
                name = todo.pop()
                if name in reach or name not in methods:
                    continue
                reach.add(name)
                for n in ast.walk(methods[name]):
                    if isinstance(n, ast.Call) and \
                            isinstance(n.func, ast.Attribute) and \
                            isinstance(n.func.value, ast.Name) and \
                            n.func.value.id == "self":
                        todo.append(n.func.attr)
            for name in sorted(reach):
                for call in ast.walk(methods[name]):
                    if not isinstance(call, ast.Call):
                        continue
                    f = call.func
                    if not (isinstance(f, ast.Attribute) and
                            f.attr in ("asarray", "array") and
                            isinstance(f.value, ast.Name) and
                            f.value.id in numpy_aliases):
                        continue
                    if not call.args:
                        continue
                    if self._reads_placement(call.args[0]):
                        fnd = self.finding(
                            mod, call,
                            "per-round bookkeeping reads lane placement "
                            "state (`active`/`inst`) back from device on "
                            "the step_round path — make it event-driven "
                            "(host-side dirty flag / mirror); the BSP "
                            "contract allows one deliberate sync per "
                            "round (the open-work vector)")
                        if fnd:
                            out.append(fnd)
        return out

    @staticmethod
    def _reads_placement(arg) -> bool:
        for n in ast.walk(arg):
            if isinstance(n, ast.Attribute) and \
                    n.attr in ("active", "inst"):
                val = n.value
                text = ""
                while isinstance(val, ast.Attribute):
                    text = val.attr + "." + text
                    val = val.value
                if isinstance(val, ast.Name):
                    text = val.id + "." + text
                if "lanes" in text:
                    return True
        return False
