"""pallas-contract: DESIGN.md §5.2 kernel-contract conformance.

Four statically-checkable clauses for any module that issues a
``pl.pallas_call``:

1. **Pad before divide.**  A grid computed as ``rows // tile`` is only
   exact when the operand was padded to a tile multiple first; the repo
   idiom is ``table = _pad_rows(table, tile)`` before ``shape // tile``.
   A floor-divide by a tile parameter in a pallas-calling function with
   no ``_pad_rows``/``cdiv`` in sight truncates the tail tile silently
   (wrong results on non-multiple shapes — exactly the bug class that
   only fails on TPU).
2. **index_map purity.**  ``BlockSpec`` index maps run at trace time on
   every grid step; they must be pure index arithmetic.  Any function
   call inside an index-map lambda (closures over scalar-prefetch refs
   may subscript, e.g. ``inst_ref[l]``, but never call) is flagged.
3. **VMEM budget.**  Call sites that hard-code ``tile=`` with the
   split-phase layout (``stages=2``) are checked against the 4 MiB
   working-set budget at the documented bound shape (n ≤ 1024 ⇒ w = 32
   words, 128 lanes) — the same formula
   ``(tile·w + lanes·tile·w + 2·lanes·w) · 4 ≤ VMEM_BUDGET_BYTES``
   that ``kernels/autotune.predict_cost`` applies at runtime
   (``predict_cost`` is used directly when jax is importable;
   otherwise the budget constant is AST-extracted from autotune.py so
   the lint job needs no accelerator deps).  ``tile=None`` call sites
   defer to the autotuner and are always fine.
4. **Oracle + parity test.**  Every public kernel entry point in
   ``src/repro/kernels/`` must have a ``<name>_ref`` oracle in
   ``kernels/ref.py`` and be exercised by name somewhere under
   ``tests/`` (the parity suites) — the §5.2 rule that no compiled
   path exists without an interpretable reference.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.core import Finding, Module, RepoContext, Rule, register

#: Kernel entry points whose ``tile=``/``stages=`` kwargs feed the
#: split-phase layout (autotune.choose candidates).
_TILED_ENTRY_POINTS = {
    "count_stats", "stacked_count_stats", "degree_stats", "degree_argmax",
    "domination_stats", "popcount_reduce", "masked_row_reduce",
}

#: Documented bound shape for the static VMEM check (DESIGN §5.2): the
#: benchmark envelope is n ≤ 1024 variables (w = 32 int32 words) on a
#: 128-lane pool.  Larger deployments must autotune (tile=None).
_N_BOUND = 1024
_LANES_BOUND = 128
_DEFAULT_BUDGET = 4 * 1024 * 1024

_PAD_HELPERS = {"_pad_rows", "pad_rows", "cdiv"}

#: Kernel modules exempt from the oracle clause: the oracle registry
#: itself and the dispatch layer.
_ORACLE_EXEMPT = {"ref.py", "ops.py", "autotune.py"}


def _is_pallas_call(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == "pallas_call") or \
        (isinstance(f, ast.Name) and f.id == "pallas_call")


def _callee_name(func_expr) -> Optional[str]:
    if isinstance(func_expr, ast.Name):
        return func_expr.id
    if isinstance(func_expr, ast.Attribute):
        return func_expr.attr
    return None


def _words(n: int) -> int:
    return (n + 31) // 32


def _static_working_set(tile: int, w: int, lanes: int) -> int:
    # Mirrors autotune.predict_cost's stages=2 working-set model: one
    # table tile + per-lane masked tile + two per-lane accumulators.
    return (tile * w + lanes * tile * w + 2 * lanes * w) * 4


def _predict_over_budget(tile: int, budget: int) -> bool:
    """True when ``tile`` at the bound shape exceeds the VMEM budget.
    Prefers the live ``autotune.predict_cost`` (exact model); falls
    back to the mirrored formula when jax is not importable."""
    w = _words(_N_BOUND)
    try:
        from repro.kernels.autotune import predict_cost
    except Exception:
        return _static_working_set(tile, w, _LANES_BOUND) > budget
    cost = predict_cost(_N_BOUND, w, _LANES_BOUND, 1,
                        tile=tile, stages=2, platform="tpu")
    return cost is None


@register
class PallasContractRule(Rule):
    name = "pallas-contract"
    description = ("Pallas kernels obey the §5.2 block/VMEM contract "
                   "and carry ref.py oracles + parity tests")
    severity = "error"

    def run(self, ctx: RepoContext) -> List[Finding]:
        findings: List[Finding] = []
        budget = ctx.literal("src/repro/kernels/autotune.py",
                             "VMEM_BUDGET_BYTES")
        if not isinstance(budget, int):
            budget = _DEFAULT_BUDGET

        for mod in ctx.modules:
            has_pallas = any(_is_pallas_call(n) for n in ast.walk(mod.tree)
                             if isinstance(n, ast.Call))
            self._check_tile_call_sites(mod, budget, findings)
            if not has_pallas:
                continue
            self._check_pad_before_divide(mod, findings)
            self._check_index_map_purity(mod, findings)
            self._check_oracles(ctx, mod, findings)
        return findings

    # -- clause 1: pad before divide -------------------------------------

    def _check_pad_before_divide(self, mod: Module,
                                 findings: List[Finding]) -> None:
        for func in ast.walk(mod.tree):
            if not isinstance(func, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not any(_is_pallas_call(n) for n in ast.walk(func)
                       if isinstance(n, ast.Call)):
                continue
            params = {a.arg for a in (func.args.posonlyargs +
                                      func.args.args +
                                      func.args.kwonlyargs)}
            pads = any(isinstance(n, ast.Call) and
                       _callee_name(n.func) in _PAD_HELPERS
                       for n in ast.walk(func))
            if pads:
                continue
            for n in ast.walk(func):
                if isinstance(n, ast.BinOp) and \
                        isinstance(n.op, ast.FloorDiv) and \
                        isinstance(n.right, ast.Name) and \
                        n.right.id in params and \
                        "tile" in n.right.id:
                    f = self.finding(
                        mod, n,
                        f"grid divides by `{n.right.id}` without padding "
                        "the operand first — call `_pad_rows(x, "
                        f"{n.right.id})` (or use pl.cdiv) so partial "
                        "tiles are not silently dropped (§5.2)")
                    if f:
                        findings.append(f)

    # -- clause 2: index_map purity --------------------------------------

    def _check_index_map_purity(self, mod: Module,
                                findings: List[Finding]) -> None:
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            name = _callee_name(call.func)
            if name not in ("BlockSpec", "PrefetchScalarGridSpec"):
                continue
            lambdas = [a for a in call.args if isinstance(a, ast.Lambda)]
            lambdas += [kw.value for kw in call.keywords
                        if isinstance(kw.value, ast.Lambda)]
            for lam in lambdas:
                for n in ast.walk(lam.body):
                    if isinstance(n, (ast.Call, ast.NamedExpr, ast.Await,
                                      ast.ListComp, ast.SetComp,
                                      ast.DictComp, ast.GeneratorExp)):
                        f = self.finding(
                            mod, lam,
                            "BlockSpec index_map must be pure index "
                            "arithmetic (names, subscripts, +-*//%); "
                            "it re-runs on every grid step at trace "
                            "time, so calls are forbidden (§5.2)")
                        if f:
                            findings.append(f)
                        break

    # -- clause 3: VMEM budget at hard-coded tile sites ------------------

    def _check_tile_call_sites(self, mod: Module, budget: int,
                               findings: List[Finding]) -> None:
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            if _callee_name(call.func) not in _TILED_ENTRY_POINTS:
                continue
            kwargs = {kw.arg: kw.value for kw in call.keywords
                      if kw.arg is not None}
            tile = kwargs.get("tile")
            stages = kwargs.get("stages")
            if not (isinstance(tile, ast.Constant) and
                    isinstance(tile.value, int)):
                continue        # tile=None / dynamic -> autotuner decides
            if not (isinstance(stages, ast.Constant) and
                    stages.value == 2):
                continue        # budget model is for the split layout
            if _predict_over_budget(tile.value, budget):
                f = self.finding(
                    mod, call,
                    f"hard-coded tile={tile.value} with stages=2 "
                    f"exceeds the {budget // (1024 * 1024)} MiB VMEM "
                    f"working-set budget at the bound shape "
                    f"(n={_N_BOUND}, lanes={_LANES_BOUND}) — pass "
                    "tile=None to autotune, or shrink the tile (§5.2)")
                if f:
                    findings.append(f)

    # -- clause 4: oracle + parity test ----------------------------------

    def _check_oracles(self, ctx: RepoContext, mod: Module,
                       findings: List[Finding]) -> None:
        if "src/repro/kernels/" not in f"/{mod.rel}" and \
                not mod.rel.startswith("src/repro/kernels/"):
            return
        base = mod.rel.rsplit("/", 1)[-1]
        if base in _ORACLE_EXEMPT:
            return
        ref_text = ctx.read("src/repro/kernels/ref.py") or ""
        tests_text = self._tests_corpus(ctx)
        for node in mod.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            if node.name.startswith("_"):
                continue
            if f"def {node.name}_ref" not in ref_text:
                f = self.finding(
                    mod, node,
                    f"public kernel `{node.name}` has no "
                    f"`{node.name}_ref` oracle in kernels/ref.py — "
                    "every compiled path needs an interpretable "
                    "reference (§5.2)")
                if f:
                    findings.append(f)
            elif tests_text and node.name not in tests_text:
                f = self.finding(
                    mod, node,
                    f"public kernel `{node.name}` is never exercised "
                    "by name under tests/ — add it to the parity "
                    "suite (§5.2)")
                if f:
                    findings.append(f)

    _tests_cache: Optional[str] = None

    def _tests_corpus(self, ctx: RepoContext) -> str:
        if PallasContractRule._tests_cache is None:
            chunks: List[str] = []
            for base in (ctx.repo_root, ctx.package_root):
                tests = base / "tests"
                if tests.is_dir():
                    for f in sorted(tests.glob("test_*.py")):
                        try:
                            chunks.append(f.read_text(encoding="utf-8"))
                        except OSError:
                            pass
                    break
            PallasContractRule._tests_cache = "\n".join(chunks)
        return PallasContractRule._tests_cache
