"""telemetry-schema: emit()/trace-write call sites checked statically.

PR 7's runtime validation (``solver.emit`` + ``TraceWriter.write`` both
raise on unknown kinds / missing fields) only fires when the offending
code path executes — a typo'd lifecycle kind in a rarely-taken branch
ships silently.  This rule resolves every call site with a *literal*
kind string against the same ground-truth tables the runtime uses:

  * ``EVENT_KINDS``  — AST-extracted from ``src/repro/solver.py``;
  * ``TRACE_KINDS``  — AST-extracted from ``src/repro/obs/trace.py``
    (kind -> required-field frozenset).

Checked shapes (kinds that are variables are skipped — the runtime
validator still covers them):

  * ``emit(cb, "kind", ...)`` and method-style ``self._emit("kind",
    ...)`` / ``obj.emit("kind", ...)``  -> kind ∈ EVENT_KINDS;
  * ``ProgressEvent(kind="kind", ...)`` -> kind ∈ EVENT_KINDS;
  * ``<trace-ish receiver>.write("kind", field=..., ...)`` -> kind ∈
    TRACE_KINDS and required fields ⊆ keyword names (unless ``**kw`` is
    forwarded).  "Trace-ish" = the receiver expression mentions
    ``trace`` (``self.trace``, ``trace``, ``self._trace`` ...), which
    keeps ordinary file ``.write()`` calls out of scope;
  * ``obj.lifecycle("kind", ...)`` -> kind ∈ TRACE_KINDS (the
    collector renames ``round_no``->``round``, so only membership is
    checked here).

The tables are read from the analyzed module set first (so editing
``solver.py`` and linting ``src`` sees the edited table) and fall back
to the checkout this package lives in (so fixture runs resolve too).
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import Finding, Module, RepoContext, Rule, register

_EVENT_TABLE = ("src/repro/solver.py", "EVENT_KINDS")
_TRACE_TABLE = ("src/repro/obs/trace.py", "TRACE_KINDS")


def _literal_str(node) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _expr_mentions_trace(node) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and "trace" in n.id.lower():
            return True
        if isinstance(n, ast.Attribute) and "trace" in n.attr.lower():
            return True
    return False


@register
class TelemetrySchemaRule(Rule):
    name = "telemetry-schema"
    description = ("emit()/trace write() call sites must use known "
                   "EVENT_KINDS/TRACE_KINDS with required fields")
    severity = "error"

    def run(self, ctx: RepoContext) -> List[Finding]:
        event_kinds = ctx.literal(*_EVENT_TABLE)
        trace_kinds = ctx.literal(*_TRACE_TABLE)
        if not isinstance(event_kinds, (set, frozenset)):
            event_kinds = None
        if not isinstance(trace_kinds, dict):
            trace_kinds = None

        findings: List[Finding] = []
        for mod in ctx.modules:
            if mod.rel in (_EVENT_TABLE[0], _TRACE_TABLE[0]):
                continue     # the tables' own modules define the schema
            for call in ast.walk(mod.tree):
                if not isinstance(call, ast.Call):
                    continue
                self._check_call(mod, call, event_kinds, trace_kinds,
                                 findings)
        return findings

    def _check_call(self, mod: Module, call: ast.Call, event_kinds,
                    trace_kinds, findings: List[Finding]) -> None:
        func = call.func

        def add(message):
            f = self.finding(mod, call, message)
            if f:
                findings.append(f)

        # -- emit(...) ----------------------------------------------------
        kind = None
        if isinstance(func, ast.Name) and func.id == "emit":
            if len(call.args) >= 2:
                kind = _literal_str(call.args[1])
        elif isinstance(func, ast.Attribute) and \
                func.attr in ("emit", "_emit"):
            if call.args:
                kind = _literal_str(call.args[0])
        elif (isinstance(func, ast.Name) and func.id == "ProgressEvent"):
            for kw in call.keywords:
                if kw.arg == "kind":
                    kind = _literal_str(kw.value)
            if kind is None and call.args:
                kind = _literal_str(call.args[0])
        if kind is not None and event_kinds is not None:
            if kind not in event_kinds:
                add(f"unknown progress-event kind {kind!r} — not in "
                    f"solver.EVENT_KINDS "
                    f"({', '.join(sorted(event_kinds))})")
            return
        if kind is not None:
            return

        # -- trace.write(...) / lifecycle(...) ----------------------------
        if not isinstance(func, ast.Attribute):
            return
        if func.attr == "write" and _expr_mentions_trace(func.value):
            if not call.args:
                return
            kind = _literal_str(call.args[0])
            if kind is None or trace_kinds is None:
                return
            if kind not in trace_kinds:
                add(f"unknown trace record kind {kind!r} — not in "
                    f"obs.trace.TRACE_KINDS "
                    f"({', '.join(sorted(trace_kinds))})")
                return
            has_star_kwargs = any(kw.arg is None for kw in call.keywords)
            if has_star_kwargs:
                return
            given = {kw.arg for kw in call.keywords}
            required = trace_kinds[kind]
            missing = sorted(set(required) - given)
            if missing:
                add(f"trace record {kind!r} is missing required "
                    f"field(s) {missing} (TRACE_KINDS[{kind!r}] = "
                    f"{{{', '.join(sorted(required))}}})")
        elif func.attr == "lifecycle":
            if not call.args:
                return
            kind = _literal_str(call.args[0])
            if kind is None or trace_kinds is None:
                return
            if kind not in trace_kinds:
                add(f"unknown lifecycle kind {kind!r} — not in "
                    f"obs.trace.TRACE_KINDS")
