"""Lint framework core: findings, rule registry, suppressions, runner.

Design (DESIGN.md §10):

  * A :class:`Rule` is a *project-level* pass — ``run(ctx)`` sees every
    analyzed module at once, because the interesting invariants
    (trace-safety reachability, telemetry schemas) are cross-file.
  * Rules report :class:`Finding` objects (rule id, file, line, message,
    severity).  ``error`` findings fail the build; ``warning`` findings
    are printed but do not affect the exit status.
  * Inline suppressions: ``# repro-lint: disable=<rule> -- <reason>``
    on the offending line (or the line directly above) silences that
    rule for that line.  The reason is mandatory — one without it is
    itself an error (rule ``suppression``), so every deliberate
    violation is documented in place.
  * Idle seed modules (``models/``, ``train/``, ... — see
    :data:`IDLE_SEED_ALLOWLIST`) are excluded from the enforced surface
    until ROADMAP Open item 3 wires them into the engine.

Everything here is stdlib-only; rules must not import jax at module
scope (the CI lint job runs without accelerator deps installed).
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

__all__ = [
    "Finding",
    "IDLE_SEED_ALLOWLIST",
    "LintResult",
    "Module",
    "RepoContext",
    "Rule",
    "all_rules",
    "lint_paths",
    "register",
]

#: Seed modules that exist in the tree but are not wired into the
#: engine yet (ROADMAP Open item 3).  Relative to the lint root's
#: ``src/repro`` package directory (or any analyzed path); matched as
#: path suffixes so the list works both for ``src`` runs and fixtures.
IDLE_SEED_ALLOWLIST: Tuple[str, ...] = (
    "models/",
    "train/",
    "configs/",
    "data/",
    "serve/",
    "distributed/",
    "kernels/flash_attention.py",
    "kernels/ssd_scan.py",
    "launch/train.py",
    "launch/serve.py",
    "launch/dryrun.py",
)

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)"
    r"(?:\s+--\s+(?P<reason>\S.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint diagnostic, anchored to a file:line."""

    rule: str
    path: str          # repo-relative, '/'-separated
    line: int
    message: str
    severity: str = "error"    # "error" | "warning"

    def format(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.severity}: {self.message}")


class Module:
    """A parsed source file: path, text, AST, and suppression table."""

    def __init__(self, path: pathlib.Path, rel: str, text: str,
                 tree: ast.Module):
        self.path = path
        self.rel = rel
        self.text = text
        self.tree = tree
        self.lines = text.splitlines()
        # line -> (set of rule names or {"*"}, reason or None)
        self.suppressions: Dict[int, Tuple[frozenset, Optional[str]]] = {}
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for lineno, line in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = frozenset(r.strip() for r in m.group(1).split(",")
                              if r.strip())
            self.suppressions[lineno] = (rules, m.group("reason"))

    def suppressed(self, rule: str, line: int) -> bool:
        """True when ``rule`` is disabled on ``line`` (same line or the
        line directly above the reported one)."""
        for cand in (line, line - 1):
            entry = self.suppressions.get(cand)
            if entry and (rule in entry[0] or "*" in entry[0]):
                return True
        return False

    def dotted(self, src_root: pathlib.Path) -> Optional[str]:
        """Module's dotted import name relative to ``src_root`` (the
        directory on ``sys.path``), or None if outside it."""
        try:
            rel = self.path.resolve().relative_to(src_root.resolve())
        except ValueError:
            return None
        parts = list(rel.with_suffix("").parts)
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts) if parts else None


class RepoContext:
    """Everything a rule needs: the analyzed modules plus repo anchors.

    ``repo_root`` is the repository checkout (auto-detected from this
    file's location) so rules can consult ground-truth files —
    ``src/repro/solver.py`` for ``EVENT_KINDS``, ``tools/api_surface.txt``
    for the snapshot — even when linting a subset of paths (fixtures).
    """

    def __init__(self, modules: Sequence[Module],
                 repo_root: Optional[pathlib.Path] = None):
        self.modules = list(modules)
        if repo_root is None:
            # src/repro/analysis/core.py -> repo checkout root
            repo_root = pathlib.Path(__file__).resolve().parents[3]
        self.repo_root = repo_root
        self.src_root = repo_root / "src"
        # The checkout this package lives in — fallback for ground-truth
        # files (schema tables, snapshots) when linting a subtree that
        # does not contain them (e.g. the fixture corpus).
        self.package_root = pathlib.Path(__file__).resolve().parents[3]
        self._file_cache: Dict[str, Optional[str]] = {}
        self.by_dotted: Dict[str, Module] = {}
        for mod in self.modules:
            name = mod.dotted(self.src_root)
            if name:
                self.by_dotted[name] = mod

    def read(self, rel: str) -> Optional[str]:
        """Text of a repo-relative file, or None if absent.  Prefers the
        analyzed module set (so fixture runs see fixture content)."""
        if rel not in self._file_cache:
            for mod in self.modules:
                if mod.rel == rel:
                    self._file_cache[rel] = mod.text
                    break
            else:
                text = None
                for base in (self.repo_root, self.package_root):
                    path = base / rel
                    if path.is_file():
                        text = path.read_text(encoding="utf-8")
                        break
                self._file_cache[rel] = text
        return self._file_cache[rel]

    def literal(self, rel: str, name: str) -> Optional[object]:
        """Evaluate the module-level assignment ``name = <literal>`` in a
        repo file via the AST — no import, so no jax dependency.  Calls
        to ``frozenset(...)``/``dict(...)``/``tuple(...)`` over literals
        are unwrapped, and references to *earlier* module-level literal
        names resolve (e.g. ``TRACE_KINDS`` reusing ``_LIFECYCLE``).
        Returns None when absent or non-literal."""
        text = self.read(rel)
        if text is None:
            return None
        try:
            tree = ast.parse(text)
        except SyntaxError:
            return None
        env: Dict[str, object] = {}
        for node in tree.body:
            targets: List[ast.expr] = []
            value: Optional[ast.expr] = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            for tgt in targets:
                if isinstance(tgt, ast.Name):
                    evaluated = _literal_eval(value, env)
                    if evaluated is not None:
                        env[tgt.id] = evaluated
                    if tgt.id == name:
                        return evaluated
        return None


def _literal_eval(node: ast.expr,
                  env: Optional[Dict[str, object]] = None) -> Optional[object]:
    """``ast.literal_eval`` extended to unwrap ``frozenset(...)`` /
    ``set(...)`` / ``dict(...)`` / ``tuple(...)`` / ``list(...)`` calls
    and resolve names bound earlier in ``env``."""
    env = env or {}
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        ctor = node.func.id
        if ctor in ("frozenset", "set", "tuple", "list", "dict"):
            if not node.args and not node.keywords:
                return {"frozenset": frozenset(), "set": set(),
                        "tuple": (), "list": [], "dict": {}}[ctor]
            if len(node.args) == 1 and not node.keywords:
                inner = _literal_eval(node.args[0], env)
                if inner is None:
                    return None
                try:
                    return {"frozenset": frozenset, "set": set,
                            "tuple": tuple, "list": list,
                            "dict": dict}[ctor](inner)
                except TypeError:
                    return None
        return None
    if isinstance(node, ast.Dict):
        out = {}
        for k, v in zip(node.keys, node.values):
            if k is None:
                return None
            key, val = _literal_eval(k, env), _literal_eval(v, env)
            if key is None or val is None:
                return None
            out[key] = val
        return out
    try:
        return ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None


class Rule:
    """Base class for a project-level lint pass.

    Subclasses set ``name``/``description``/``severity`` and implement
    :meth:`run`, yielding findings via :meth:`finding` (which applies
    the inline-suppression table and reports reasonless suppressions).
    """

    name = "abstract"
    description = ""
    severity = "error"

    def run(self, ctx: RepoContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, mod: Module, node, message: str,
                severity: Optional[str] = None) -> Optional[Finding]:
        """Build a Finding for ``node`` (an AST node or an int line
        number) unless an inline suppression covers it."""
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        if mod.suppressed(self.name, line):
            return None
        return Finding(rule=self.name, path=mod.rel, line=line,
                       message=message,
                       severity=severity or self.severity)


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding a Rule to the global registry."""
    if cls.name in _REGISTRY and _REGISTRY[cls.name] is not cls:
        raise ValueError(f"duplicate lint rule name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    """Registered rules by name (import ``repro.analysis`` to populate)."""
    return dict(_REGISTRY)


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    files: int
    skipped: List[str]      # allowlisted files that were not analyzed

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]


def _is_allowlisted(rel: str) -> bool:
    norm = rel.replace("\\", "/")
    for entry in IDLE_SEED_ALLOWLIST:
        if entry.endswith("/"):
            if f"/{entry}" in f"/{norm}":
                return True
        elif norm.endswith(entry):
            return True
    return False


def _collect_files(root: pathlib.Path,
                   paths: Sequence[str]) -> Tuple[List[pathlib.Path],
                                                  List[str]]:
    files: List[pathlib.Path] = []
    skipped: List[str] = []
    for p in paths:
        path = (root / p) if not pathlib.Path(p).is_absolute() \
            else pathlib.Path(p)
        if path.is_file():
            candidates: Iterable[pathlib.Path] = [path]
        else:
            candidates = sorted(path.rglob("*.py"))
        for f in candidates:
            try:
                rel = str(f.resolve().relative_to(root.resolve()))
            except ValueError:
                rel = str(f)
            rel = rel.replace("\\", "/")
            if _is_allowlisted(rel):
                skipped.append(rel)
            else:
                files.append(f)
    return files, skipped


def _suppression_findings(mod: Module) -> List[Finding]:
    out = []
    for lineno, (rules, reason) in sorted(mod.suppressions.items()):
        if reason is None:
            out.append(Finding(
                rule="suppression", path=mod.rel, line=lineno,
                message="suppression is missing its reason — write "
                        "'# repro-lint: disable=<rule> -- why'"))
        unknown = rules - set(_REGISTRY) - {"*"}
        if unknown:
            out.append(Finding(
                rule="suppression", path=mod.rel, line=lineno,
                message=f"suppression names unknown rule(s) "
                        f"{sorted(unknown)}"))
    return out


def lint_paths(paths: Sequence[str],
               root: Optional[pathlib.Path] = None,
               rules: Optional[Sequence[str]] = None) -> LintResult:
    """Run the registered rules over ``paths`` (files or directories,
    resolved against ``root``, default: the repo checkout).  Returns a
    :class:`LintResult`; the caller decides the exit status from
    ``result.errors``."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[3]
    files, skipped = _collect_files(root, paths)

    modules: List[Module] = []
    findings: List[Finding] = []
    for f in files:
        try:
            rel = str(f.resolve().relative_to(root.resolve()))
        except ValueError:
            rel = str(f)
        rel = rel.replace("\\", "/")
        try:
            text = f.read_text(encoding="utf-8")
        except OSError as e:
            findings.append(Finding(rule="parse", path=rel, line=1,
                                    message=f"unreadable: {e}"))
            continue
        try:
            tree = ast.parse(text, filename=rel)
        except SyntaxError as e:
            findings.append(Finding(rule="parse", path=rel,
                                    line=e.lineno or 1,
                                    message=f"syntax error: {e.msg}"))
            continue
        modules.append(Module(f, rel, text, tree))

    ctx = RepoContext(modules, repo_root=root)
    for mod in modules:
        findings.extend(_suppression_findings(mod))

    selected = rules if rules is not None else sorted(_REGISTRY)
    for name in selected:
        cls = _REGISTRY.get(name)
        if cls is None:
            raise KeyError(f"unknown lint rule {name!r} "
                           f"(known: {sorted(_REGISTRY)})")
        findings.extend(cls().run(ctx))

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintResult(findings=findings, files=len(modules),
                      skipped=skipped)
