"""repro-lint: repo-aware static analysis for the framework (DESIGN.md §10).

An AST-based lint pass that enforces, at CI time, the invariants the
runtime can only catch on hardware (or not at all):

  * ``trace-safety``     — no host↔device syncs inside the jitted round
                           loop (``.item()``, ``int(traced)``,
                           ``np.asarray(traced)``, Python ``if``/``while``
                           on traced operands);
  * ``pallas-contract``  — kernels obey the DESIGN §5.2 block/VMEM
                           contract and every public kernel has a
                           ``ref.py`` oracle plus a parity test;
  * ``telemetry-schema`` — ``emit()``/trace ``write()`` call sites are
                           statically valid against ``EVENT_KINDS`` /
                           ``TRACE_KINDS``;
  * ``api-hygiene``      — public exports are snapshotted in
                           ``tools/api_surface.txt`` and deprecation
                           shims carry the exactly-once warning pattern.

Front door: :func:`lint_paths` (used by ``tools/lint.py`` and the test
suite).  The package is stdlib-only — it never imports jax — so the CI
``lint`` job needs no dependency installs.
"""

from repro.analysis.core import (
    Finding,
    LintResult,
    RepoContext,
    Rule,
    all_rules,
    lint_paths,
)

# Importing the rule modules registers their rules with the registry.
from repro.analysis import api_hygiene  # noqa: F401  (registration)
from repro.analysis import pallas_contract  # noqa: F401  (registration)
from repro.analysis import telemetry  # noqa: F401  (registration)
from repro.analysis import trace_safety  # noqa: F401  (registration)

__all__ = [
    "Finding",
    "LintResult",
    "RepoContext",
    "Rule",
    "all_rules",
    "lint_paths",
]
