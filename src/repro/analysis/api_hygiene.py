"""api-hygiene: snapshot-guarded exports + well-formed deprecations.

Two clauses:

1. **Exports are snapshotted.**  For every front-door module listed in
   ``tools/api_surface.py``'s ``MODULES`` tuple, each name in the
   module's ``__all__`` must appear in the checked-in snapshot
   ``tools/api_surface.txt`` (under that module's section).  This is
   the static half of the snapshot guard: ``api_surface.py --check``
   catches *drift* at docs-smoke time but needs a working import of
   jax; this rule catches a forgotten snapshot regen with no deps at
   all, at lint time.  Both ``MODULES`` and ``__all__`` are resolved
   from the AST, never imported.
2. **Deprecation shims use the exactly-once pattern.**  Every
   ``warnings.warn(..., DeprecationWarning, ...)`` must pass
   ``stacklevel=2`` (point at the *caller*, which is what lets
   ``tests/_legacy.one_deprecation`` and the pytest.ini error filters
   pin each shim exactly once) and, when the message is a literal,
   say "deprecated" so the filter regexes can match it.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from repro.analysis.core import Finding, Module, RepoContext, Rule, register

_SNAPSHOT_ENTRY = re.compile(
    r"^  (?:def|const|dataclass|namedtuple|class)\s+([A-Za-z_][A-Za-z_0-9]*)")


def _parse_snapshot(text: str) -> Dict[str, Set[str]]:
    """api_surface.txt -> {module: {exported names}}."""
    sections: Dict[str, Set[str]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if line.startswith("module "):
            current = line[len("module "):].strip()
            sections[current] = set()
        elif current is not None:
            m = _SNAPSHOT_ENTRY.match(line)
            if m:
                sections[current].add(m.group(1))
    return sections


def _module_rel(dotted: str) -> List[str]:
    """Candidate repo-relative paths for a dotted module."""
    base = "src/" + dotted.replace(".", "/")
    return [f"{base}/__init__.py", f"{base}.py"]


@register
class ApiHygieneRule(Rule):
    name = "api-hygiene"
    description = ("public exports snapshotted in tools/api_surface.txt; "
                   "deprecation shims use the exactly-once pattern")
    severity = "error"

    def run(self, ctx: RepoContext) -> List[Finding]:
        findings: List[Finding] = []
        self._check_snapshot(ctx, findings)
        for mod in ctx.modules:
            self._check_deprecations(mod, findings)
        return findings

    # -- clause 1: exports ⊆ snapshot ------------------------------------

    def _check_snapshot(self, ctx: RepoContext,
                        findings: List[Finding]) -> None:
        modules = ctx.literal("tools/api_surface.py", "MODULES")
        snapshot_text = ctx.read("tools/api_surface.txt")
        if not isinstance(modules, tuple) or snapshot_text is None:
            return
        sections = _parse_snapshot(snapshot_text)
        for dotted in modules:
            # Only check modules present in the analyzed set — a
            # fixture/subset run must not re-audit the whole tree.
            mod = ctx.by_dotted.get(dotted)
            if mod is None:
                continue
            exported = self._module_all(mod)
            if exported is None:
                f = self.finding(
                    mod, 1,
                    f"front-door module {dotted} has no literal "
                    "__all__ — the api-surface snapshot needs one")
                if f:
                    findings.append(f)
                continue
            known = sections.get(dotted)
            if known is None:
                f = self.finding(
                    mod, 1,
                    f"module {dotted} is in api_surface.MODULES but has "
                    "no section in tools/api_surface.txt — run "
                    "`python tools/api_surface.py --update`")
                if f:
                    findings.append(f)
                continue
            for name, lineno in sorted(exported.items()):
                if name not in known:
                    f = self.finding(
                        mod, lineno,
                        f"export {dotted}.{name} is missing from "
                        "tools/api_surface.txt — run `python "
                        "tools/api_surface.py --update` and review "
                        "the diff")
                    if f:
                        findings.append(f)

    @staticmethod
    def _module_all(mod: Module) -> Optional[Dict[str, int]]:
        """``__all__`` names -> line number, or None when absent."""
        for node in mod.tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            else:
                continue
            for tgt in targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                    out: Dict[str, int] = {}
                    value = node.value
                    if not isinstance(value, (ast.List, ast.Tuple)):
                        return None
                    for elt in value.elts:
                        if isinstance(elt, ast.Constant) and \
                                isinstance(elt.value, str):
                            out[elt.value] = elt.lineno
                    return out
        return None

    # -- clause 2: deprecation shims -------------------------------------

    def _check_deprecations(self, mod: Module,
                            findings: List[Finding]) -> None:
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            func = call.func
            is_warn = (
                (isinstance(func, ast.Attribute) and func.attr == "warn"
                 and isinstance(func.value, ast.Name)
                 and func.value.id == "warnings")
                or (isinstance(func, ast.Name) and func.id == "warn"))
            if not is_warn:
                continue
            mentions_dep = any(
                isinstance(n, ast.Name) and n.id == "DeprecationWarning"
                for a in (list(call.args) +
                          [kw.value for kw in call.keywords])
                for n in ast.walk(a))
            if not mentions_dep:
                continue
            stacklevel = None
            if len(call.args) >= 3 and isinstance(call.args[2],
                                                  ast.Constant):
                stacklevel = call.args[2].value
            for kw in call.keywords:
                if kw.arg == "stacklevel" and \
                        isinstance(kw.value, ast.Constant):
                    stacklevel = kw.value.value
            if stacklevel != 2:
                f = self.finding(
                    mod, call,
                    "DeprecationWarning must be raised with "
                    "stacklevel=2 so the warning points at the caller "
                    "(the exactly-once shim pattern pinned by "
                    "pytest.ini / tests/_legacy.py)")
                if f:
                    findings.append(f)
            msg = call.args[0] if call.args else None
            if isinstance(msg, ast.Constant) and \
                    isinstance(msg.value, str) and \
                    "deprecat" not in msg.value.lower():
                f = self.finding(
                    mod, call,
                    "deprecation shim message should say 'deprecated' "
                    "so the pytest.ini error filters can pin it")
                if f:
                    findings.append(f)
