"""Pipeline parallelism: GPipe-style microbatch schedule over a mesh axis.

The scale-out path beyond TP=16: layers are split into S stages mapped to
a ``stage`` mesh axis; activations advance stage-to-stage with
``collective_permute`` inside ``shard_map``.  The steady-state loop runs
S + M - 1 ticks for M microbatches (fill + drain), the standard GPipe
schedule; each device computes its stage's layer stack per tick.

This module is exercised at small scale (tests/test_train_substrate.py,
8 host devices) — the production dry-run mesh uses DP x TP, with PP as the
documented growth axis past a pod (DESIGN.md §6).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_forward(stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
                     params_stacked: PyTree, x_mb: jnp.ndarray,
                     mesh: Mesh, stage_axis: str = "stage") -> jnp.ndarray:
    """Run M microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x  applies ONE stage's layer stack.
    params_stacked: leaves with leading dim S (sharded over stage_axis).
    x_mb: [M, mb, ...] microbatched input (replicated across stages).
    Returns [M, mb, ...] outputs (as produced by the last stage).
    """
    s = mesh.shape[stage_axis]
    m = x_mb.shape[0]

    def body(params, xs):
        sid = jax.lax.axis_index(stage_axis)
        p_local = jax.tree_util.tree_map(lambda a: a[0], params)
        n_ticks = s + m - 1

        def tick(carry, t):
            buf, outs = carry          # buf: [mb, ...] current activation
            # stage 0 injects microbatch t (if any); others use permuted.
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = xs[mb_idx]
            cur = jnp.where((sid == 0) & (t < m), inject, buf)
            y = stage_fn(p_local, cur)
            # last stage emits microbatch (t - (s-1)) at ticks >= s-1.
            emit_idx = jnp.clip(t - (s - 1), 0, m - 1)
            do_emit = (sid == s - 1) & (t >= s - 1)
            outs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, emit_idx, 0),
                lambda o: o, outs)
            # hand off to the next stage (ring permute; last->0 unused).
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % s) for i in range(s)])
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(n_ticks))
        # outs only valid on the last stage; broadcast it to all
        # (ppermute is a strict permutation, so gather + select instead).
        outs = jax.lax.all_gather(outs, stage_axis)[s - 1]
        return outs

    from repro.compat import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P(stage_axis),
                                         params_stacked),
                  P()),
        out_specs=P(), check=False)
    return fn(params_stacked, x_mb)
