"""Minimum-cardinality SUBSET SUM — the third, non-graph application.

Demonstrates the framework's problem-obliviousness (§I: "recursive
backtracking is a widely-used technique for solving a very long list of
practical problems").  Given positive ints and a target, find the smallest
subset summing exactly to the target.  Left child takes item ``pos``,
right child skips it; depth == item position, so the tree is binary with
depth exactly n and the indexed encoding applies unchanged.

The fused ``evaluate`` is trivial here (no expensive shared intermediates),
which makes this the minimal example of the protocol.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem, NodeEval
from repro.core.serial import INF, PyNodeEval, PyProblem
from repro.registry import register_problem


class SSInstance(NamedTuple):
    """A subset-sum instance: positive item values + an exact target.

    ``n`` and ``name`` mirror the :class:`~repro.problems.graphs.Graph`
    conventions so registry-driven launchers stay problem-oblivious.
    """

    values: Tuple[int, ...]
    target: int
    name: str = "ss"

    @property
    def n(self) -> int:
        return len(self.values)


def parse_ss_instance(spec: str) -> SSInstance:
    """Parse ``ss:<n>:<seed>``: ``n`` seeded random values in [1, 50) with a
    target drawn as the sum of a random (non-empty) subset, so every
    generated instance is feasible and the optimum is non-trivial.
    """
    kind, *rest = spec.split(":")
    if kind != "ss" or len(rest) != 2:
        raise ValueError(
            f"unknown instance spec {spec!r} (want ss:<n>:<seed>)")
    n, seed = (int(x) for x in rest)
    if n < 1:
        raise ValueError(f"bad subset-sum size in {spec!r}")
    rng = np.random.RandomState(seed)
    values = rng.randint(1, 50, size=n)
    chosen = rng.rand(n) < 0.4
    if not chosen.any():
        chosen[int(rng.randint(n))] = True
    target = int(values[chosen].sum())
    return SSInstance(values=tuple(int(v) for v in values), target=target,
                      name=f"ss_{n}_{seed}")


class SSState(NamedTuple):
    pos: jnp.ndarray      # int32 — next item to decide
    total: jnp.ndarray    # int32 — sum of taken items
    count: jnp.ndarray    # int32 — taken items
    mask: jnp.ndarray     # int32[n] — 1 where taken (solution payload)


@register_problem(
    "ss",
    parse=parse_ss_instance,
    oracle=lambda inst: make_subset_sum_py(inst.values, inst.target),
    # No bitset table to stream — nothing for the kernel layer to fuse, so
    # the family advertises the jnp backend only (DESIGN.md §5.4).  No
    # ``pack``: the stacked service tables are graph-shaped, so subset sum
    # is not servable (submit() raises AdmissionError).
    backends=("jnp",),
    build=lambda inst, backend: make_subset_sum(inst.values, inst.target),
    doc="minimum-cardinality exact subset sum (non-graph family)",
)
def make_subset_sum(values, target: int) -> BinaryProblem:
    vals = jnp.asarray(np.asarray(values, dtype=np.int32))
    n = int(vals.shape[0])
    # Suffix sums let us prune branches that can no longer reach the target.
    suffix = jnp.asarray(np.concatenate(
        [np.cumsum(np.asarray(values, dtype=np.int64)[::-1])[::-1],
         [0]]).astype(np.int32))
    tgt = jnp.int32(target)

    def root() -> SSState:
        return SSState(pos=jnp.int32(0), total=jnp.int32(0),
                       count=jnp.int32(0), mask=jnp.zeros(n, jnp.int32))

    def evaluate(s: SSState, best: jnp.ndarray) -> NodeEval:
        p = jnp.clip(s.pos, 0, n - 1)
        is_sol = (s.pos >= n) & (s.total == tgt)

        pc = jnp.clip(s.pos, 0, n)
        overshoot = s.total > tgt
        unreachable = s.total + suffix[pc] < tgt
        done_wrong = (s.pos >= n) & (s.total != tgt)
        bad = overshoot | unreachable | done_wrong
        lb = jnp.where(bad, INF_VALUE, s.count + (s.total != tgt))

        left = SSState(pos=s.pos + 1, total=s.total + vals[p],
                       count=s.count + 1, mask=s.mask.at[p].set(1))
        right = SSState(pos=s.pos + 1, total=s.total, count=s.count,
                        mask=s.mask)
        return NodeEval(is_solution=is_sol, value=s.count, lower_bound=lb,
                        left=left, right=right, payload=s.mask)

    return BinaryProblem(
        name=f"subset_sum[n={n}]", max_depth=n, root=root,
        evaluate=evaluate,
        payload_zero=lambda: jnp.zeros(n, jnp.int32))


def make_subset_sum_py(values, target: int) -> PyProblem:
    vals = [int(v) for v in values]
    n = len(vals)
    suffix = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        suffix[i] = suffix[i + 1] + vals[i]

    def root():
        return (0, 0, 0)

    def evaluate(s, best):
        pos, total, count = s
        p = min(pos, n - 1)
        is_sol = pos >= n and total == target

        pc = min(pos, n)
        if total > target or total + suffix[pc] < target or \
                (pos >= n and total != target):
            lb = INF
        else:
            lb = count + (1 if total != target else 0)

        left = (pos + 1, total + vals[p], count + 1)
        right = (pos + 1, total, count)
        return PyNodeEval(is_sol, count, lb, left, right)

    return PyProblem(name=f"subset_sum[n={n}]", max_depth=n, root=root,
                     evaluate=evaluate)
