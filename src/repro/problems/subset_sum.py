"""Minimum-cardinality SUBSET SUM — the third, non-graph application.

Demonstrates the framework's problem-obliviousness (§I: "recursive
backtracking is a widely-used technique for solving a very long list of
practical problems").  Given positive ints and a target, find the smallest
subset summing exactly to the target.  Left child takes item ``pos``,
right child skips it; depth == item position, so the tree is binary with
depth exactly n and the indexed encoding applies unchanged.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem
from repro.core.serial import INF, PyProblem


class SSState(NamedTuple):
    pos: jnp.ndarray      # int32 — next item to decide
    total: jnp.ndarray    # int32 — sum of taken items
    count: jnp.ndarray    # int32 — taken items
    mask: jnp.ndarray     # int32[n] — 1 where taken (solution payload)


def make_subset_sum(values, target: int) -> BinaryProblem:
    vals = jnp.asarray(np.asarray(values, dtype=np.int32))
    n = int(vals.shape[0])
    # Suffix sums let us prune branches that can no longer reach the target.
    suffix = jnp.asarray(np.concatenate(
        [np.cumsum(np.asarray(values, dtype=np.int64)[::-1])[::-1],
         [0]]).astype(np.int32))
    tgt = jnp.int32(target)

    def root() -> SSState:
        return SSState(pos=jnp.int32(0), total=jnp.int32(0),
                       count=jnp.int32(0), mask=jnp.zeros(n, jnp.int32))

    def apply(s: SSState, b: jnp.ndarray) -> SSState:
        p = jnp.clip(s.pos, 0, n - 1)
        take = b == 0
        return SSState(
            pos=s.pos + 1,
            total=s.total + jnp.where(take, vals[p], jnp.int32(0)),
            count=s.count + jnp.where(take, jnp.int32(1), jnp.int32(0)),
            mask=s.mask.at[p].set(jnp.where(take, 1, s.mask[p])))

    def leaf_value(s: SSState) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (s.pos >= n) & (s.total == tgt), s.count

    def lower_bound(s: SSState) -> jnp.ndarray:
        p = jnp.clip(s.pos, 0, n)
        overshoot = s.total > tgt
        unreachable = s.total + suffix[p] < tgt
        done_wrong = (s.pos >= n) & (s.total != tgt)
        bad = overshoot | unreachable | done_wrong
        return jnp.where(bad, INF_VALUE, s.count + (s.total != tgt))

    return BinaryProblem(
        name=f"subset_sum[n={n}]", max_depth=n, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound,
        solution_payload=lambda s: s.mask,
        payload_zero=lambda: jnp.zeros(n, jnp.int32))


def make_subset_sum_py(values, target: int) -> PyProblem:
    vals = [int(v) for v in values]
    n = len(vals)
    suffix = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        suffix[i] = suffix[i + 1] + vals[i]

    def root():
        return (0, 0, 0)

    def apply(s, b):
        pos, total, count = s
        p = min(pos, n - 1)
        if b == 0:
            return (pos + 1, total + vals[p], count + 1)
        return (pos + 1, total, count)

    def leaf_value(s):
        pos, total, count = s
        return pos >= n and total == target, count

    def lower_bound(s):
        pos, total, count = s
        p = min(pos, n)
        if total > target or total + suffix[p] < target or \
                (pos >= n and total != target):
            return INF
        return count + (1 if total != target else 0)

    return PyProblem(
        name=f"subset_sum[n={n}]", max_depth=n, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound)
