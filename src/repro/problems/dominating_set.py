"""DOMINATING SET via reduction to MINIMUM SET COVER (paper §V, ref [4]).

Universe = vertices; the set of vertex ``v`` is its closed neighborhood
N[v].  Branch on the candidate covering the most undominated vertices
(ties: smallest id) — left child takes ``v`` into the dominating set, right
child discards ``v`` as a candidate (the paper: "the right branch forces v
to be out of any solution").

Bound: ``|D| + ceil(undominated / best_coverage)`` (admissible — every
further pick dominates at most ``best_coverage`` new vertices).  A node
with undominated vertices but zero possible coverage is infeasible
(INF bound, arity 0).

Fused node evaluation: the coverage vector (masked popcount over closed
neighborhoods) and the undominated count are computed ONCE per node visit
and shared between the solution test, the bound and both children — the
pre-fusion three-callback form recomputed the coverage vector in both
``apply`` and ``lower_bound``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem, NodeEval
from repro.core.serial import INF, PyNodeEval, PyProblem
from repro.problems.graphs import Graph, bit, full_mask


class DSState(NamedTuple):
    dominated: jnp.ndarray   # uint32[w]
    cand: jnp.ndarray        # uint32[w] — vertices still allowed into D
    chosen: jnp.ndarray      # uint32[w] — current D
    size: jnp.ndarray        # int32


def _closed_adj(graph: Graph) -> np.ndarray:
    cadj = graph.adj.copy()
    for v in range(graph.n):
        cadj[v] |= bit(v, graph.words)
    return cadj


def make_dominating_set(graph: Graph) -> BinaryProblem:
    n, w = graph.n, graph.words
    cadj = jnp.asarray(_closed_adj(graph))
    fullm = jnp.asarray(full_mask(n))
    word = jnp.asarray(np.arange(n, dtype=np.int32) // 32)
    shift = jnp.asarray((np.arange(n, dtype=np.int32) % 32).astype(np.uint32))
    one = jnp.uint32(1)

    def vbit(v):
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32), jnp.uint32(0))

    def root() -> DSState:
        return DSState(dominated=jnp.zeros(w, jnp.uint32), cand=fullm,
                       chosen=jnp.zeros(w, jnp.uint32), size=jnp.int32(0))

    def evaluate(state: DSState, best: jnp.ndarray) -> NodeEval:
        # The ONE coverage pass: |N[v] \ dominated| for every candidate v.
        undom_rows = jnp.bitwise_and(
            cadj, jnp.bitwise_not(state.dominated)[None, :])
        cov = jax.lax.population_count(undom_rows).sum(axis=1).astype(
            jnp.int32)
        cand_f = ((state.cand[word] >> shift) & one) == one
        cov = jnp.where(cand_f, cov, jnp.int32(-1))

        # Undominated count (one popcount of the complement).
        rem = jnp.bitwise_and(fullm, jnp.bitwise_not(state.dominated))
        u = jax.lax.population_count(rem).sum().astype(jnp.int32)
        is_sol = u == 0

        # Bound from the shared coverage vector.
        best_cov = jnp.max(cov)
        infeasible = (u > 0) & (best_cov <= 0)
        need = (u + jnp.maximum(best_cov, 1) - 1) // jnp.maximum(best_cov, 1)
        lb = jnp.where(infeasible, INF_VALUE, state.size + need)

        # Children from the shared branch vertex.
        v = jnp.argmax(cov).astype(jnp.int32)
        bv = vbit(v)
        new_cand = jnp.bitwise_and(state.cand, jnp.bitwise_not(bv))
        left = DSState(dominated=jnp.bitwise_or(state.dominated, cadj[v]),
                       cand=new_cand,
                       chosen=jnp.bitwise_or(state.chosen, bv),
                       size=state.size + 1)
        right = DSState(dominated=state.dominated, cand=new_cand,
                        chosen=state.chosen, size=state.size)
        return NodeEval(is_solution=is_sol, value=state.size, lower_bound=lb,
                        left=left, right=right, payload=state.chosen)

    return BinaryProblem(
        name=f"ds[{graph.name}]", max_depth=n, root=root, evaluate=evaluate,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32))


def make_dominating_set_py(graph: Graph) -> PyProblem:
    n, w = graph.n, graph.words
    cadj = _closed_adj(graph)
    fullm = full_mask(n)
    word = np.arange(n, dtype=np.int32) // 32
    shift = (np.arange(n, dtype=np.int32) % 32).astype(np.uint32)

    def vbit(v):
        out = np.zeros(w, np.uint32)
        out[v // 32] = np.uint32(1) << np.uint32(v % 32)
        return out

    def root():
        return (np.zeros(w, np.uint32), fullm.copy(),
                np.zeros(w, np.uint32), 0)

    def evaluate(state, best):
        dominated, cand, chosen, size = state
        cov = np.bitwise_count(cadj & ~dominated[None, :]).sum(
            axis=1).astype(np.int64)
        cand_f = ((cand[word] >> shift) & np.uint32(1)) == 1
        cov = np.where(cand_f, cov, -1)

        u = int(np.bitwise_count(fullm & ~dominated).sum())
        is_sol = u == 0

        best_cov = int(np.max(cov))
        if u > 0 and best_cov <= 0:
            lb = INF
        else:
            bc = max(best_cov, 1)
            lb = size + (u + bc - 1) // bc

        v = int(np.argmax(cov))
        bv = vbit(v)
        new_cand = cand & ~bv
        left = (dominated | cadj[v], new_cand, chosen | bv, size + 1)
        right = (dominated, new_cand, chosen, size)
        return PyNodeEval(is_sol, size, lb, left, right)

    return PyProblem(name=f"ds[{graph.name}]", max_depth=n, root=root,
                     evaluate=evaluate)
