"""DOMINATING SET via reduction to MINIMUM SET COVER (paper §V, ref [4]).

Universe = vertices; the set of vertex ``v`` is its closed neighborhood
N[v].  Branch on the candidate covering the most undominated vertices
(ties: smallest id) — left child takes ``v`` into the dominating set, right
child discards ``v`` as a candidate (the paper: "the right branch forces v
to be out of any solution").

Bound: ``|D| + ceil(undominated / best_coverage)`` (admissible — every
further pick dominates at most ``best_coverage`` new vertices).  A node
with undominated vertices but zero possible coverage is infeasible
(INF bound, arity 0).

Fused node evaluation (DESIGN.md §1): the coverage vector (masked popcount
over closed neighborhoods), the branch vertex and the undominated count
are computed ONCE per node visit and shared between the solution test, the
bound and both children, through a pluggable ``stats_fn``:

  backend="jnp"     — inline jnp (materializes the [n, w] masked matrix);
  backend="pallas"  — ``repro.kernels.bitset_ops.domination_stats``, the
                      universal masked-popcount kernel bound with
                      mask = the undominated set and valid = the candidate
                      set (DESIGN.md §5.2/§5.4; interpret-mode off-TPU).

Both backends are bitwise-identical — same coverage counts, same
smallest-id tie-break, same bound — so the search tree is invariant under
the backend (asserted node-for-node vs the serial oracle by
``tests/test_node_eval.py``).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem, NodeEval
from repro.core.serial import INF, PyNodeEval, PyProblem
from repro.problems.graphs import Graph, bit, full_mask, parse_graph_instance
from repro.registry import register_problem


class DSState(NamedTuple):
    dominated: jnp.ndarray   # uint32[w]
    cand: jnp.ndarray        # uint32[w] — vertices still allowed into D
    chosen: jnp.ndarray      # uint32[w] — current D
    size: jnp.ndarray        # int32


def _closed_adj(graph: Graph) -> np.ndarray:
    cadj = graph.adj.copy()
    for v in range(graph.n):
        cadj[v] |= bit(v, graph.words)
    return cadj


#: stats_fn contract: (dominated uint32[w], cand uint32[w]) ->
#: (best_coverage, branch_vertex, undominated) int32 scalars, where
#: coverage[v] = |N[v] \ dominated| over candidates (-1 for
#: non-candidates), best_coverage is the max (-1 when no candidate),
#: branch_vertex follows the smallest-id tie-break (0 when no candidate)
#: and undominated counts the not-yet-dominated vertices.  This is THE
#: once-per-node computation (DESIGN.md §5.4).
DomStatsFn = Callable[[jnp.ndarray, jnp.ndarray],
                      Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]


def make_domination_stats_fn(graph: Graph, backend: str = "jnp", *,
                             tile: Optional[int] = None,
                             interpret: Optional[bool] = None) -> DomStatsFn:
    """Build the per-node domination-statistics function for ``backend``.

    ``tile=None`` defers the kernel block shape to the per-shape autotuner
    (DESIGN.md §5.6)."""
    n, w = graph.n, graph.words
    cadj = jnp.asarray(_closed_adj(graph))
    fullm = jnp.asarray(full_mask(n))

    if backend == "pallas":
        from repro.kernels import ops

        def stats(dominated: jnp.ndarray, cand: jnp.ndarray):
            out = ops.domination_stats(cadj, dominated[None, :],
                                       cand[None, :], fullm, tile=tile,
                                       use_pallas=True, interpret=interpret)[0]
            # Kernel reports vertex -1 when no candidate exists; the jnp
            # argmax reports 0.  Normalize so both backends yield identical
            # (and discarded) children on dead states.
            return out[0], jnp.maximum(out[1], 0), out[2]

        return stats

    if backend != "jnp":
        raise ValueError(f"unknown dominating-set backend {backend!r}")

    word = jnp.asarray(np.arange(n, dtype=np.int32) // 32)
    shift = jnp.asarray((np.arange(n, dtype=np.int32) % 32).astype(np.uint32))
    one = jnp.uint32(1)

    def stats(dominated: jnp.ndarray, cand: jnp.ndarray):
        undom_rows = jnp.bitwise_and(cadj, jnp.bitwise_not(dominated)[None, :])
        cov = jax.lax.population_count(undom_rows).sum(axis=1).astype(
            jnp.int32)
        cand_f = ((cand[word] >> shift) & one) == one
        cov = jnp.where(cand_f, cov, jnp.int32(-1))
        rem = jnp.bitwise_and(fullm, jnp.bitwise_not(dominated))
        u = jax.lax.population_count(rem).sum().astype(jnp.int32)
        return jnp.max(cov), jnp.argmax(cov).astype(jnp.int32), u

    return stats


def _pack_ds(graph: Graph, n: int):
    """Service packing: pad into a stacked FAMILY_DS slot (closed adjacency;
    lazy import keeps problems <-> service acyclic)."""
    from repro.service.batch_problem import FAMILY_DS, pack_instance
    return pack_instance(graph, FAMILY_DS, n)


@register_problem(
    "ds",
    parse=parse_graph_instance,
    oracle=lambda graph: make_dominating_set_py(graph),
    backends=("jnp", "pallas"),
    pack=_pack_ds,
    family_id=1,                       # batch_problem.FAMILY_DS
    doc="minimum dominating set via set-cover branching (paper §V)",
)
def make_dominating_set(graph: Graph, backend: str = "jnp", *,
                        tile: Optional[int] = None,
                        interpret: Optional[bool] = None,
                        stats_fn: Optional[DomStatsFn] = None
                        ) -> BinaryProblem:
    """jnp BinaryProblem for the engine (vmap-safe, shape-static).

    ``backend`` routes the per-node coverage pass (see module docstring);
    ``stats_fn`` overrides it entirely (tests inject counting wrappers).
    Under ``backend="pallas"`` (without a ``stats_fn`` override) the
    problem also carries ``evaluate_batch``: all W lanes' coverage passes
    fuse into ONE ``domination_stats`` kernel launch per engine step
    (DESIGN.md §5.5).
    """
    n, w = graph.n, graph.words
    cadj = jnp.asarray(_closed_adj(graph))
    fullm = jnp.asarray(full_mask(n))
    one = jnp.uint32(1)
    batched = backend == "pallas" and stats_fn is None
    if stats_fn is None:
        stats_fn = make_domination_stats_fn(graph, backend, tile=tile,
                                            interpret=interpret)

    def vbit(v):
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32), jnp.uint32(0))

    def root() -> DSState:
        return DSState(dominated=jnp.zeros(w, jnp.uint32), cand=fullm,
                       chosen=jnp.zeros(w, jnp.uint32), size=jnp.int32(0))

    def _finish(state: DSState, best: jnp.ndarray, best_cov, v,
                u) -> NodeEval:
        is_sol = u == 0

        # Bound from the shared coverage maximum.
        infeasible = (u > 0) & (best_cov <= 0)
        need = (u + jnp.maximum(best_cov, 1) - 1) // jnp.maximum(best_cov, 1)
        lb = jnp.where(infeasible, INF_VALUE, state.size + need)

        # Children from the shared branch vertex.
        bv = vbit(v)
        new_cand = jnp.bitwise_and(state.cand, jnp.bitwise_not(bv))
        left = DSState(dominated=jnp.bitwise_or(state.dominated, cadj[v]),
                       cand=new_cand,
                       chosen=jnp.bitwise_or(state.chosen, bv),
                       size=state.size + 1)
        right = DSState(dominated=state.dominated, cand=new_cand,
                        chosen=state.chosen, size=state.size)
        return NodeEval(is_solution=is_sol, value=state.size, lower_bound=lb,
                        left=left, right=right, payload=state.chosen)

    def evaluate(state: DSState, best: jnp.ndarray) -> NodeEval:
        # THE one coverage pass (DESIGN.md §5.4): best |N[v] \ dominated|
        # over candidates, its vertex, and the undominated count.
        best_cov, v, u = stats_fn(state.dominated, state.cand)
        return _finish(state, best, best_cov, v, u)

    evaluate_batch = None
    if batched:
        from repro.kernels import ops

        def evaluate_batch(states: DSState, best: jnp.ndarray) -> NodeEval:
            # ONE kernel launch covers every lane's coverage pass: the
            # whole uint32[L, w] mask block is batched into each grid step
            # instead of one pallas_call per lane (DESIGN.md §5.5).
            out = ops.domination_stats(cadj, states.dominated, states.cand,
                                       fullm, tile=tile, use_pallas=True,
                                       interpret=interpret)
            return jax.vmap(_finish)(states, best, out[:, 0],
                                     jnp.maximum(out[:, 1], 0), out[:, 2])

    return BinaryProblem(
        name=f"ds[{graph.name}]", max_depth=n, root=root, evaluate=evaluate,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32),
        evaluate_batch=evaluate_batch)


def make_dominating_set_py(graph: Graph) -> PyProblem:
    n, w = graph.n, graph.words
    cadj = _closed_adj(graph)
    fullm = full_mask(n)
    word = np.arange(n, dtype=np.int32) // 32
    shift = (np.arange(n, dtype=np.int32) % 32).astype(np.uint32)

    def vbit(v):
        out = np.zeros(w, np.uint32)
        out[v // 32] = np.uint32(1) << np.uint32(v % 32)
        return out

    def root():
        return (np.zeros(w, np.uint32), fullm.copy(),
                np.zeros(w, np.uint32), 0)

    def evaluate(state, best):
        dominated, cand, chosen, size = state
        cov = np.bitwise_count(cadj & ~dominated[None, :]).sum(
            axis=1).astype(np.int64)
        cand_f = ((cand[word] >> shift) & np.uint32(1)) == 1
        cov = np.where(cand_f, cov, -1)

        u = int(np.bitwise_count(fullm & ~dominated).sum())
        is_sol = u == 0

        best_cov = int(np.max(cov))
        if u > 0 and best_cov <= 0:
            lb = INF
        else:
            bc = max(best_cov, 1)
            lb = size + (u + bc - 1) // bc

        v = int(np.argmax(cov))
        bv = vbit(v)
        new_cand = cand & ~bv
        left = (dominated | cadj[v], new_cand, chosen | bv, size + 1)
        right = (dominated, new_cand, chosen, size)
        return PyNodeEval(is_sol, size, lb, left, right)

    return PyProblem(name=f"ds[{graph.name}]", max_depth=n, root=root,
                     evaluate=evaluate)
