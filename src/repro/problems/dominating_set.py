"""DOMINATING SET via reduction to MINIMUM SET COVER (paper §V, ref [4]).

Universe = vertices; the set of vertex ``v`` is its closed neighborhood
N[v].  Branch on the candidate covering the most undominated vertices
(ties: smallest id) — left child takes ``v`` into the dominating set, right
child discards ``v`` as a candidate (the paper: "the right branch forces v
to be out of any solution").

Bound: ``|D| + ceil(undominated / best_coverage)`` (admissible — every
further pick dominates at most ``best_coverage`` new vertices).  A node
with undominated vertices but zero possible coverage is infeasible
(INF bound, arity 0).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem
from repro.core.serial import INF, PyProblem
from repro.problems.graphs import Graph, bit, full_mask


class DSState(NamedTuple):
    dominated: jnp.ndarray   # uint32[w]
    cand: jnp.ndarray        # uint32[w] — vertices still allowed into D
    chosen: jnp.ndarray      # uint32[w] — current D
    size: jnp.ndarray        # int32


def _closed_adj(graph: Graph) -> np.ndarray:
    cadj = graph.adj.copy()
    for v in range(graph.n):
        cadj[v] |= bit(v, graph.words)
    return cadj


def make_dominating_set(graph: Graph) -> BinaryProblem:
    n, w = graph.n, graph.words
    cadj = jnp.asarray(_closed_adj(graph))
    fullm = jnp.asarray(full_mask(n))
    word = jnp.asarray(np.arange(n, dtype=np.int32) // 32)
    shift = jnp.asarray((np.arange(n, dtype=np.int32) % 32).astype(np.uint32))
    one = jnp.uint32(1)

    def cand_flags(cand):
        return ((cand[word] >> shift) & one) == one

    def coverage(state: DSState) -> jnp.ndarray:      # int32[n], -1 for non-cand
        undom = jnp.bitwise_and(cadj, jnp.bitwise_not(state.dominated)[None, :])
        cov = jax.lax.population_count(undom).sum(axis=1).astype(jnp.int32)
        return jnp.where(cand_flags(state.cand), cov, jnp.int32(-1))

    def vbit(v):
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32), jnp.uint32(0))

    def root() -> DSState:
        return DSState(dominated=jnp.zeros(w, jnp.uint32), cand=fullm,
                       chosen=jnp.zeros(w, jnp.uint32), size=jnp.int32(0))

    def apply(state: DSState, b: jnp.ndarray) -> DSState:
        cov = coverage(state)
        v = jnp.argmax(cov).astype(jnp.int32)
        bv = vbit(v)
        take = b == 0
        dominated = jnp.where(take, jnp.bitwise_or(state.dominated, cadj[v]),
                              state.dominated)
        return DSState(
            dominated=dominated,
            cand=jnp.bitwise_and(state.cand, jnp.bitwise_not(bv)),
            chosen=jnp.where(take, jnp.bitwise_or(state.chosen, bv),
                             state.chosen),
            size=state.size + jnp.where(take, jnp.int32(1), jnp.int32(0)))

    def undom_count(state):
        rem = jnp.bitwise_and(fullm, jnp.bitwise_not(state.dominated))
        return jax.lax.population_count(rem).sum().astype(jnp.int32)

    def leaf_value(state: DSState) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return undom_count(state) == 0, state.size

    def lower_bound(state: DSState) -> jnp.ndarray:
        u = undom_count(state)
        best_cov = jnp.max(coverage(state))
        infeasible = (u > 0) & (best_cov <= 0)
        need = (u + jnp.maximum(best_cov, 1) - 1) // jnp.maximum(best_cov, 1)
        return jnp.where(infeasible, INF_VALUE, state.size + need)

    return BinaryProblem(
        name=f"ds[{graph.name}]", max_depth=n, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound,
        solution_payload=lambda s: s.chosen,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32))


def make_dominating_set_py(graph: Graph) -> PyProblem:
    n, w = graph.n, graph.words
    cadj = _closed_adj(graph)
    fullm = full_mask(n)
    word = np.arange(n, dtype=np.int32) // 32
    shift = (np.arange(n, dtype=np.int32) % 32).astype(np.uint32)

    def cand_flags(cand):
        return ((cand[word] >> shift) & np.uint32(1)) == 1

    def coverage(state):
        dominated, cand = state[0], state[1]
        cov = np.bitwise_count(cadj & ~dominated[None, :]).sum(axis=1).astype(np.int64)
        return np.where(cand_flags(cand), cov, -1)

    def vbit(v):
        out = np.zeros(w, np.uint32)
        out[v // 32] = np.uint32(1) << np.uint32(v % 32)
        return out

    def root():
        return (np.zeros(w, np.uint32), fullm.copy(),
                np.zeros(w, np.uint32), 0)

    def apply(state, b):
        dominated, cand, chosen, size = state
        v = int(np.argmax(coverage(state)))
        bv = vbit(v)
        if b == 0:
            return (dominated | cadj[v], cand & ~bv, chosen | bv, size + 1)
        return (dominated, cand & ~bv, chosen, size)

    def undom_count(state):
        return int(np.bitwise_count(fullm & ~state[0]).sum())

    def leaf_value(state):
        return undom_count(state) == 0, state[3]

    def lower_bound(state):
        u = undom_count(state)
        best_cov = int(np.max(coverage(state)))
        if u > 0 and best_cov <= 0:
            return INF
        bc = max(best_cov, 1)
        return state[3] + (u + bc - 1) // bc

    return PyProblem(
        name=f"ds[{graph.name}]", max_depth=n, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound)
