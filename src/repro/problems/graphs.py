"""Packed-bitset graphs (the paper's hybrid-representation substrate, §V).

The paper's solvers use a "hybrid graph data-structure" combining
adjacency-matrix and adjacency-list advantages with cheap backtracking
undo.  The XLA-native equivalent is a *packed bitset adjacency matrix*:
``uint32[n, w]`` with ``w = ceil(n/32)`` words per row.  Search-node state
is then one or two ``uint32[w]`` masks — O(n/32) words — and every graph
operation (degree, neighborhood union, vertex deletion) is a handful of
bitwise ops + population counts, which vectorize over lanes and map to the
VPU on TPU.  ``repro.kernels.bitset_degree`` provides the Pallas version of
the hot fused degree+argmax; the jnp forms here are its oracle.

Generators are deterministic (seeded) — the framework requires
reproducible search trees.
"""

from __future__ import annotations

import dataclasses

import numpy as np

WORD = 32


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph with packed adjacency rows.

    Attributes:
      n: number of vertices (ids 0..n-1).
      adj: uint32[n, w] packed adjacency matrix (symmetric, no self loops).
      name: label used in logs/benchmarks.
    """

    n: int
    adj: np.ndarray
    name: str = "graph"

    @property
    def words(self) -> int:
        return self.adj.shape[1]

    @property
    def m(self) -> int:
        return int(np.bitwise_count(self.adj).sum()) // 2

    def degrees(self) -> np.ndarray:
        return np.bitwise_count(self.adj).sum(axis=1).astype(np.int32)


def num_words(n: int) -> int:
    return (n + WORD - 1) // WORD


def full_mask(n: int) -> np.ndarray:
    """uint32[w] with bits 0..n-1 set (the all-alive mask)."""
    w = num_words(n)
    mask = np.zeros(w, np.uint32)
    for i in range(n):
        mask[i // WORD] |= np.uint32(1) << np.uint32(i % WORD)
    return mask


def bit(v: int, w: int) -> np.ndarray:
    """uint32[w] with only bit v set."""
    out = np.zeros(w, np.uint32)
    out[v // WORD] = np.uint32(1) << np.uint32(v % WORD)
    return out


def pack_adjacency(dense: np.ndarray, name: str = "graph") -> Graph:
    """Pack a dense bool/int adjacency matrix into a Graph."""
    dense = np.asarray(dense)
    n = dense.shape[0]
    dense = (dense != 0)
    dense = dense | dense.T
    np.fill_diagonal(dense, False)
    w = num_words(n)
    adj = np.zeros((n, w), np.uint32)
    for i in range(n):
        idxs = np.nonzero(dense[i])[0]
        for j in idxs:
            adj[i, j // WORD] |= np.uint32(1) << np.uint32(j % WORD)
    return Graph(n=n, adj=adj, name=name)


def gnp_graph(n: int, p: float, seed: int, name: str = "") -> Graph:
    """Erdős–Rényi G(n, p) — the p_hat-style random benchmark family."""
    rng = np.random.RandomState(seed)
    upper = rng.rand(n, n) < p
    dense = np.triu(upper, k=1)
    return pack_adjacency(dense, name or f"gnp_{n}_{p}_{seed}")


def circulant_graph(n: int, offsets, name: str = "") -> Graph:
    """Circulant graph: v ~ v±o (mod n) for each offset o.

    With two offsets this is 4-regular — the stand-in for the paper's
    60-cell (300 vertices, 600 edges, 4-regular; its regularity defeats
    pruning, which is what made it hard).
    """
    dense = np.zeros((n, n), bool)
    for v in range(n):
        for o in offsets:
            dense[v][(v + o) % n] = True
            dense[v][(v - o) % n] = True
    return pack_adjacency(dense, name or f"circulant_{n}_{tuple(offsets)}")


def cell60_graph(n: int = 300) -> Graph:
    """4-regular 300-vertex circulant — the 60-cell analogue (§VI).

    The true 60-cell is a specific 4-regular polytopal graph; what makes it
    a hard VC instance is 4-regularity + high girth defeating degree-based
    pruning.  A circulant with coprime offsets reproduces those structural
    properties deterministically without shipping polytope data.
    """
    return circulant_graph(n, (1, 7), name="60cell-analogue")


def parse_graph_instance(spec: str) -> Graph:
    """Parse the graph-problem instance-spec grammar shared by every graph
    family's registry entry (moved out of ``launch/solve.py``):

      ``gnp:<n>:<p*100>:<seed>`` — Erdős–Rényi G(n, p);
      ``reg:<n>:<k>:<seed>``     — random k-regular-ish graph;
      ``cell60``                 — the 4-regular 60-cell analogue.
    """
    if spec == "cell60":
        return cell60_graph()
    kind, *rest = spec.split(":")
    try:
        if kind == "gnp":
            n, p100, seed = (int(x) for x in rest)
            return gnp_graph(n, p100 / 100.0, seed=seed)
        if kind == "reg":
            n, k, seed = (int(x) for x in rest)
            return random_regularish_graph(n, k, seed=seed)
    except (TypeError, ValueError) as e:
        raise ValueError(f"bad {kind} instance spec {spec!r}: {e}") from None
    raise ValueError(
        f"unknown instance spec {spec!r} (want gnp:<n>:<p*100>:<seed>, "
        f"reg:<n>:<k>:<seed> or cell60)")


def random_regularish_graph(n: int, k: int, seed: int, name: str = "") -> Graph:
    """k-regular-ish graph via random perfect matchings (union of k)."""
    rng = np.random.RandomState(seed)
    dense = np.zeros((n, n), bool)
    for _ in range(k):
        perm = rng.permutation(n)
        for i in range(0, n - 1, 2):
            a, b = perm[i], perm[i + 1]
            dense[a, b] = dense[b, a] = True
    return pack_adjacency(dense, name or f"reg_{n}_{k}_{seed}")
