"""Problem instances for the parallel recursive backtracking framework.

Each problem is exposed in two exactly-equivalent forms:

* ``make_<problem>``      — :class:`repro.core.api.BinaryProblem` (jnp,
  shape-static, vmap-safe) consumed by the vectorized engine;
* ``make_<problem>_py``   — :class:`repro.core.serial.PyProblem` (numpy
  scalar) consumed by the serial oracle and the protocol simulator.

Equivalence (identical search trees node-for-node) is what the paper's
determinism requirement demands and is asserted by tests.
"""

from repro.problems.graphs import (  # noqa: F401
    Graph, gnp_graph, circulant_graph, cell60_graph, pack_adjacency,
    random_regularish_graph,
)
from repro.problems.vertex_cover import (  # noqa: F401
    make_degree_stats_fn, make_vertex_cover, make_vertex_cover_callbacks,
    make_vertex_cover_py,
)
from repro.problems.dominating_set import (  # noqa: F401
    make_domination_stats_fn, make_dominating_set, make_dominating_set_py,
)
from repro.problems.subset_sum import make_subset_sum, make_subset_sum_py  # noqa: F401

#: CLI-facing graph-problem factories (``launch/solve.py``).  Each factory
#: advertises the kernel backends it accepts via a ``backends`` attribute
#: (DESIGN.md §5.4) — the launchers validate --backend against it instead
#: of hard-coding per-problem knowledge.
PROBLEM_FACTORIES = {
    "vc": make_vertex_cover,
    "ds": make_dominating_set,
}


def problem_backends(name: str) -> tuple:
    """Kernel backends supported by registered problem ``name``."""
    return tuple(getattr(PROBLEM_FACTORIES[name], "backends", ("jnp",)))
