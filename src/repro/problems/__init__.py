"""Problem instances for the parallel recursive backtracking framework.

Each problem is exposed in two exactly-equivalent forms:

* ``make_<problem>``      — :class:`repro.core.api.BinaryProblem` (jnp,
  shape-static, vmap-safe) consumed by the vectorized engine;
* ``make_<problem>_py``   — :class:`repro.core.serial.PyProblem` (numpy
  scalar) consumed by the serial oracle and the protocol simulator.

Equivalence (identical search trees node-for-node) is what the paper's
determinism requirement demands and is asserted by tests.

Every family self-registers with :mod:`repro.registry` via ONE
``@register_problem`` call in its own module — factory, serial oracle,
instance parser, kernel-backend capabilities and (for graph families)
service packing.  Launchers, the service driver and the
:class:`repro.solver.Solver` facade resolve problems exclusively through
that registry (DESIGN.md §6); the ``PROBLEM_FACTORIES`` /
``problem_backends`` names below are deprecated registry views kept for
pre-registry callers.
"""

from repro import registry as _registry
from repro.problems.graphs import (  # noqa: F401
    Graph, gnp_graph, circulant_graph, cell60_graph, pack_adjacency,
    parse_graph_instance, random_regularish_graph,
)
from repro.problems.vertex_cover import (  # noqa: F401
    make_degree_stats_fn, make_vertex_cover, make_vertex_cover_callbacks,
    make_vertex_cover_py,
)
from repro.problems.dominating_set import (  # noqa: F401
    make_domination_stats_fn, make_dominating_set, make_dominating_set_py,
)
from repro.problems.subset_sum import (  # noqa: F401
    SSInstance, make_subset_sum, make_subset_sum_py, parse_ss_instance,
)

#: DEPRECATED registry view — use ``repro.registry.get(name).factory``.
#: Populated from the registry so the two can never diverge.
PROBLEM_FACTORIES = {name: _registry.get(name).factory
                     for name in _registry.names()}


def problem_backends(name: str) -> tuple:
    """DEPRECATED — use ``repro.registry.problem_backends(name)``."""
    return _registry.problem_backends(name)
