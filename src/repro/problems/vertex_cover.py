"""VERTEX COVER by max-degree branching (paper §V).

Branching rule (the paper's): deterministically select an alive vertex ``v``
of maximum degree (ties: smallest id).  Left child adds ``v`` to the cover;
right child adds *all* alive neighbors N(v) to the cover.  Either ``v`` or
all of N(v) is in any cover, so the rule is complete; each child removes at
least one vertex so the tree depth is at most n.

Bound: ``|cover| + ceil(m_alive / Δ_alive)`` — each additional cover vertex
removes at most Δ edges, an admissible lower bound (branch-and-reduce
pruning, §I).  The incumbent broadcast makes this bound global, which is
the mechanism behind the paper's super-linear speedups on the 60-cell.

State is two packed bitsets + a counter; see ``repro.problems.graphs``.

Fused node evaluation (DESIGN.md §1/§3).  Every per-node quantity here —
the solution test (residual graph edgeless), the bound (Δ and 2·m of the
residual graph) and the branch vertex (argmax degree) — is a function of
ONE masked-popcount degree pass over the adjacency bitsets.  The fused
``evaluate`` performs that pass exactly once per node visit, through a
pluggable ``stats_fn``:

  backend="jnp"     — inline jnp (materializes the [n, w] masked matrix);
  backend="pallas"  — ``repro.kernels.bitset_degree.degree_stats``, the
                      universal masked-popcount kernel of
                      ``repro.kernels.bitset_ops`` bound with mask = valid
                      = the alive set (DESIGN.md §5.2/§5.4;
                      interpret-mode off-TPU); vmap over lanes lifts into
                      an extra grid dimension.

Both backends are bitwise-identical (same degrees, same smallest-id
tie-break, same bound), so the search tree is invariant under the backend —
asserted against the serial oracle node-for-node by tests.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple  # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import BinaryProblem, NodeEval
from repro.core.serial import PyNodeEval, PyProblem
from repro.problems.graphs import Graph, full_mask, parse_graph_instance
from repro.registry import register_problem


class VCState(NamedTuple):
    alive: jnp.ndarray    # uint32[w] — vertices still in the residual graph
    cover: jnp.ndarray    # uint32[w] — vertices chosen into the cover
    size: jnp.ndarray     # int32     — |cover|


def _vertex_bits(n: int):
    word = np.arange(n, dtype=np.int32) // 32
    shift = (np.arange(n, dtype=np.int32) % 32).astype(np.uint32)
    return word, shift


#: stats_fn contract: alive uint32[w] -> (max_degree, branch_vertex,
#: degree_sum) int32 scalars, where degrees are over the residual graph,
#: max_degree is -1 when no vertex is alive, branch_vertex follows the
#: smallest-id tie-break (0 when nothing is alive) and degree_sum is
#: 2 * m_alive.  This is THE once-per-node computation.
StatsFn = Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]


def make_degree_stats_fn(graph: Graph, backend: str = "jnp", *,
                         tile: Optional[int] = None,
                         interpret: Optional[bool] = None) -> StatsFn:
    """Build the per-node degree-statistics function for ``backend``.

    ``tile=None`` defers the kernel block shape to the per-shape autotuner
    (DESIGN.md §5.6)."""
    n, w = graph.n, graph.words
    adj = jnp.asarray(graph.adj)                      # uint32[n, w]

    if backend == "pallas":
        from repro.kernels import ops

        def stats(alive: jnp.ndarray):
            out = ops.degree_stats(adj, alive[None, :], tile=tile,
                                   use_pallas=True, interpret=interpret)[0]
            # Kernel reports vertex -1 when nothing is alive; the jnp argmax
            # reports 0.  Normalize so both backends yield identical (and
            # discarded) children on dead states.
            return out[0], jnp.maximum(out[1], 0), out[2]

        return stats

    if backend != "jnp":
        raise ValueError(f"unknown vertex-cover backend {backend!r}")

    word_np, shift_np = _vertex_bits(n)
    word, shift = jnp.asarray(word_np), jnp.asarray(shift_np)
    one = jnp.uint32(1)

    def stats(alive: jnp.ndarray):
        rows = jnp.bitwise_and(adj, alive[None, :])
        degs = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
        alive_f = ((alive[word] >> shift) & one) == one
        degs = jnp.where(alive_f, degs, jnp.int32(-1))
        return (jnp.max(degs), jnp.argmax(degs).astype(jnp.int32),
                jnp.sum(jnp.maximum(degs, 0)))

    return stats


def _pack_vc(graph: Graph, n: int):
    """Service packing: pad into a stacked FAMILY_VC slot (lazy import keeps
    problems <-> service acyclic)."""
    from repro.service.batch_problem import FAMILY_VC, pack_instance
    return pack_instance(graph, FAMILY_VC, n)


@register_problem(
    "vc",
    parse=parse_graph_instance,
    oracle=lambda graph: make_vertex_cover_py(graph),
    backends=("jnp", "pallas"),
    pack=_pack_vc,
    family_id=0,                       # batch_problem.FAMILY_VC
    doc="minimum vertex cover by max-degree branching (paper §V)",
)
def make_vertex_cover(graph: Graph, backend: str = "jnp", *,
                      tile: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      stats_fn: Optional[StatsFn] = None) -> BinaryProblem:
    """jnp BinaryProblem for the engine (vmap-safe, shape-static).

    ``backend`` routes the per-node degree pass (see module docstring);
    ``stats_fn`` overrides it entirely (tests inject counting wrappers).
    Under ``backend="pallas"`` (without a ``stats_fn`` override) the
    problem also carries ``evaluate_batch``: all W lanes' degree passes
    fuse into ONE ``degree_stats`` kernel launch per engine step
    (DESIGN.md §5.5).
    """
    n, w = graph.n, graph.words
    adj = jnp.asarray(graph.adj)
    one = jnp.uint32(1)
    fullm = jnp.asarray(full_mask(n))
    batched = backend == "pallas" and stats_fn is None
    if stats_fn is None:
        stats_fn = make_degree_stats_fn(graph, backend, tile=tile,
                                        interpret=interpret)

    def vbit(v):                                      # uint32[w], bit v
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32),
                         jnp.uint32(0))

    def root() -> VCState:
        return VCState(alive=fullm, cover=jnp.zeros(w, jnp.uint32),
                       size=jnp.int32(0))

    def _finish(state: VCState, best: jnp.ndarray, dmax, v,
                m2) -> NodeEval:
        # Solution test: the residual graph has no edges left.
        edgeless = dmax <= 0

        # Bound: |cover| + ceil(m_alive / Δ_alive).
        d_eff = jnp.maximum(dmax, 1)
        need = (m2 + 2 * d_eff - 1) // (2 * d_eff)    # ceil(m / Δ)
        lb = state.size + need

        # Children from the shared branch vertex.
        bv = vbit(v)
        nb = jnp.bitwise_and(adj[v], state.alive)     # alive neighborhood
        nb_count = jax.lax.population_count(nb).sum().astype(jnp.int32)
        left = VCState(
            alive=jnp.bitwise_and(state.alive, jnp.bitwise_not(bv)),
            cover=jnp.bitwise_or(state.cover, bv),
            size=state.size + 1)
        right = VCState(
            alive=jnp.bitwise_and(state.alive,
                                  jnp.bitwise_not(jnp.bitwise_or(nb, bv))),
            cover=jnp.bitwise_or(state.cover, nb),
            size=state.size + nb_count)
        return NodeEval(is_solution=edgeless, value=state.size,
                        lower_bound=lb, left=left, right=right,
                        payload=state.cover)

    def evaluate(state: VCState, best: jnp.ndarray) -> NodeEval:
        dmax, v, m2 = stats_fn(state.alive)           # the ONE degree pass
        return _finish(state, best, dmax, v, m2)

    evaluate_batch = None
    if batched:
        from repro.kernels import ops

        def evaluate_batch(states: VCState, best: jnp.ndarray) -> NodeEval:
            # ONE kernel launch covers every lane's degree pass: the whole
            # uint32[L, w] alive block is batched into each grid step
            # instead of one pallas_call per lane (DESIGN.md §5.5).
            out = ops.degree_stats(adj, states.alive, tile=tile,
                                   use_pallas=True, interpret=interpret)
            return jax.vmap(_finish)(states, best, out[:, 0],
                                     jnp.maximum(out[:, 1], 0), out[:, 2])

    return BinaryProblem(
        name=f"vc[{graph.name}]",
        max_depth=n,
        root=root,
        evaluate=evaluate,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32),
        evaluate_batch=evaluate_batch,
    )


def make_vertex_cover_callbacks(graph: Graph, *,
                                degrees_counter: Optional[dict] = None
                                ) -> BinaryProblem:
    """The PRE-fusion three-callback form, kept as the legacy/adapter
    baseline: ``leaf_value``, ``lower_bound`` and ``apply`` each recompute
    the full degree vector, so one node visit pays ~4 degree passes.
    ``degrees_counter["n"]`` (if given) counts those passes — benchmarks
    and the fusion tests measure the win against this.
    """
    n, w = graph.n, graph.words
    adj = jnp.asarray(graph.adj)
    word_np, shift_np = _vertex_bits(n)
    word, shift = jnp.asarray(word_np), jnp.asarray(shift_np)
    one = jnp.uint32(1)
    fullm = jnp.asarray(full_mask(n))

    def degrees(alive):                               # int32[n], -1 for dead
        if degrees_counter is not None:
            degrees_counter["n"] = degrees_counter.get("n", 0) + 1
        rows = jnp.bitwise_and(adj, alive[None, :])
        degs = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
        alive_f = ((alive[word] >> shift) & one) == one
        return jnp.where(alive_f, degs, jnp.int32(-1))

    def vbit(v):
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32),
                         jnp.uint32(0))

    def root() -> VCState:
        return VCState(alive=fullm, cover=jnp.zeros(w, jnp.uint32),
                       size=jnp.int32(0))

    def apply(state: VCState, bit: jnp.ndarray) -> VCState:
        v = jnp.argmax(degrees(state.alive)).astype(jnp.int32)
        bv = vbit(v)
        nb = jnp.bitwise_and(adj[v], state.alive)
        nb_count = jax.lax.population_count(nb).sum().astype(jnp.int32)
        take_v = bit == 0
        dead = jnp.where(take_v, bv, jnp.bitwise_or(nb, bv))
        added = jnp.where(take_v, bv, nb)
        return VCState(
            alive=jnp.bitwise_and(state.alive, jnp.bitwise_not(dead)),
            cover=jnp.bitwise_or(state.cover, added),
            size=state.size + jnp.where(take_v, jnp.int32(1), nb_count))

    def leaf_value(state: VCState) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.max(degrees(state.alive)) <= 0, state.size

    def lower_bound(state: VCState) -> jnp.ndarray:
        degs = degrees(state.alive)
        dmax = jnp.maximum(jnp.max(degs), 1)
        m2 = jnp.sum(jnp.maximum(degs, 0))            # 2 * m_alive
        need = (m2 + 2 * dmax - 1) // (2 * dmax)      # ceil(m / Δ)
        return state.size + need

    return BinaryProblem.from_callbacks(
        name=f"vc[{graph.name}]",
        max_depth=n,
        root=root,
        apply=apply,
        leaf_value=leaf_value,
        lower_bound=lower_bound,
        solution_payload=lambda s: s.cover,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32),
    )


def make_vertex_cover_py(graph: Graph) -> PyProblem:
    """numpy scalar mirror — must branch identically to the jnp form.

    Fused like the jnp form: one degree pass per ``evaluate``.
    """
    n, w = graph.n, graph.words
    adj = graph.adj
    word_np, shift_np = _vertex_bits(n)
    fullm = full_mask(n)

    def degrees(alive):
        degs = np.bitwise_count(adj & alive[None, :]).sum(axis=1).astype(np.int64)
        alive_f = ((alive[word_np] >> shift_np) & np.uint32(1)) == 1
        return np.where(alive_f, degs, -1)

    def vbit(v):
        out = np.zeros(w, np.uint32)
        out[v // 32] = np.uint32(1) << np.uint32(v % 32)
        return out

    def root():
        return (fullm.copy(), np.zeros(w, np.uint32), 0)

    def evaluate(state, best):
        alive, cover, size = state
        degs = degrees(alive)                         # the ONE degree pass
        dmax = int(np.max(degs))
        edgeless = dmax <= 0

        d_eff = max(dmax, 1)
        m2 = int(np.maximum(degs, 0).sum())
        lb = size + (m2 + 2 * d_eff - 1) // (2 * d_eff)

        v = int(np.argmax(degs))
        bv = vbit(v)
        nb = adj[v] & alive
        left = (alive & ~bv, cover | bv, size + 1)
        right = (alive & ~(nb | bv), cover | nb,
                 size + int(np.bitwise_count(nb).sum()))
        return PyNodeEval(edgeless, size, lb, left, right)

    return PyProblem(name=f"vc[{graph.name}]", max_depth=n, root=root,
                     evaluate=evaluate)
