"""VERTEX COVER by max-degree branching (paper §V).

Branching rule (the paper's): deterministically select an alive vertex ``v``
of maximum degree (ties: smallest id).  Left child adds ``v`` to the cover;
right child adds *all* alive neighbors N(v) to the cover.  Either ``v`` or
all of N(v) is in any cover, so the rule is complete; each child removes at
least one vertex so the tree depth is at most n.

Bound: ``|cover| + ceil(m_alive / Δ_alive)`` — each additional cover vertex
removes at most Δ edges, an admissible lower bound (branch-and-reduce
pruning, §I).  The incumbent broadcast makes this bound global, which is
the mechanism behind the paper's super-linear speedups on the 60-cell.

State is two packed bitsets + a counter; see ``repro.problems.graphs``.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import INF_VALUE, BinaryProblem
from repro.core.serial import INF, PyProblem
from repro.problems.graphs import Graph, full_mask


class VCState(NamedTuple):
    alive: jnp.ndarray    # uint32[w] — vertices still in the residual graph
    cover: jnp.ndarray    # uint32[w] — vertices chosen into the cover
    size: jnp.ndarray     # int32     — |cover|


def _vertex_bits(n: int):
    word = np.arange(n, dtype=np.int32) // 32
    shift = (np.arange(n, dtype=np.int32) % 32).astype(np.uint32)
    return word, shift


def make_vertex_cover(graph: Graph) -> BinaryProblem:
    """jnp BinaryProblem for the engine (vmap-safe, shape-static)."""
    n, w = graph.n, graph.words
    adj = jnp.asarray(graph.adj)                      # uint32[n, w]
    word_np, shift_np = _vertex_bits(n)
    word, shift = jnp.asarray(word_np), jnp.asarray(shift_np)
    one = jnp.uint32(1)
    fullm = jnp.asarray(full_mask(n))

    def alive_flags(alive):                           # bool[n]
        return ((alive[word] >> shift) & one) == one

    def degrees(alive):                               # int32[n], 0 for dead
        rows = jnp.bitwise_and(adj, alive[None, :])
        degs = jax.lax.population_count(rows).sum(axis=1).astype(jnp.int32)
        return jnp.where(alive_flags(alive), degs, jnp.int32(-1))

    def pick(alive) -> jnp.ndarray:
        """Max-degree alive vertex, smallest id on ties (argmax = first)."""
        return jnp.argmax(degrees(alive)).astype(jnp.int32)

    def vbit(v):                                      # uint32[w], bit v
        return jnp.where(jnp.arange(w) == (v // 32),
                         one << (v.astype(jnp.uint32) % 32),
                         jnp.uint32(0))

    def root() -> VCState:
        return VCState(alive=fullm, cover=jnp.zeros(w, jnp.uint32),
                       size=jnp.int32(0))

    def apply(state: VCState, bit: jnp.ndarray) -> VCState:
        v = pick(state.alive)
        bv = vbit(v)
        nb = jnp.bitwise_and(adj[v], state.alive)     # alive neighborhood
        nb_count = jax.lax.population_count(nb).sum().astype(jnp.int32)
        take_v = bit == 0
        dead = jnp.where(take_v, bv, jnp.bitwise_or(nb, bv))
        added = jnp.where(take_v, bv, nb)
        return VCState(
            alive=jnp.bitwise_and(state.alive, jnp.bitwise_not(dead)),
            cover=jnp.bitwise_or(state.cover, added),
            size=state.size + jnp.where(take_v, jnp.int32(1), nb_count))

    def leaf_value(state: VCState) -> Tuple[jnp.ndarray, jnp.ndarray]:
        degs = degrees(state.alive)
        edgeless = jnp.max(degs) <= 0
        return edgeless, state.size

    def lower_bound(state: VCState) -> jnp.ndarray:
        degs = degrees(state.alive)
        dmax = jnp.maximum(jnp.max(degs), 1)
        m2 = jnp.sum(jnp.maximum(degs, 0))            # 2 * m_alive
        need = (m2 + 2 * dmax - 1) // (2 * dmax)      # ceil(m / Δ)
        return state.size + need

    return BinaryProblem(
        name=f"vc[{graph.name}]",
        max_depth=n,
        root=root,
        apply=apply,
        leaf_value=leaf_value,
        lower_bound=lower_bound,
        solution_payload=lambda s: s.cover,
        payload_zero=lambda: jnp.zeros(w, jnp.uint32),
    )


def make_vertex_cover_py(graph: Graph) -> PyProblem:
    """numpy scalar mirror — must branch identically to the jnp form."""
    n, w = graph.n, graph.words
    adj = graph.adj
    word_np, shift_np = _vertex_bits(n)
    fullm = full_mask(n)

    def alive_flags(alive):
        return ((alive[word_np] >> shift_np) & np.uint32(1)) == 1

    def degrees(alive):
        degs = np.bitwise_count(adj & alive[None, :]).sum(axis=1).astype(np.int64)
        return np.where(alive_flags(alive), degs, -1)

    def vbit(v):
        out = np.zeros(w, np.uint32)
        out[v // 32] = np.uint32(1) << np.uint32(v % 32)
        return out

    def root():
        return (fullm.copy(), np.zeros(w, np.uint32), 0)

    def apply(state, bit):
        alive, cover, size = state
        v = int(np.argmax(degrees(alive)))
        bv = vbit(v)
        nb = adj[v] & alive
        if bit == 0:
            return (alive & ~bv, cover | bv, size + 1)
        return (alive & ~(nb | bv), cover | nb,
                size + int(np.bitwise_count(nb).sum()))

    def leaf_value(state):
        alive, _, size = state
        return bool(np.max(degrees(alive)) <= 0), size

    def lower_bound(state):
        alive, _, size = state
        degs = degrees(alive)
        dmax = max(int(np.max(degs)), 1)
        m2 = int(np.maximum(degs, 0).sum())
        return size + (m2 + 2 * dmax - 1) // (2 * dmax)

    return PyProblem(
        name=f"vc[{graph.name}]", max_depth=n, root=root, apply=apply,
        leaf_value=leaf_value, lower_bound=lower_bound)
