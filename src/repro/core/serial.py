"""Faithful scalar reference of the paper's algorithms (Figs. 1, 3-5, 7).

Three artifacts live here, all pure Python (no JAX), used as oracles:

1. ``serial_rb`` — SERIAL-RB (Fig. 1) as an iterative stepper; returns the
   optimum, the visit log and node count.  This is the ground truth every
   parallel configuration must match.

2. ``ParallelRBSimulator`` — a discrete-time simulator of PARALLEL-RB
   (Fig. 7) with the paper's *actual* protocol: GETPARENT initial virtual
   topology (Fig. 5), round-robin GETNEXTPARENT re-probing, task requests
   answered with GETHEAVIESTTASKINDEX / FIXINDEX (Fig. 4), incumbent
   broadcast on improvement, and ``passes > 2`` three-state termination.
   One simulator *tick* advances every active core by one node visit — the
   machine-independent unit the paper's butterfly-effect analysis counts —
   so the makespan in ticks is the simulated parallel running time and
   per-core T_S / T_R match the paper's Tables I/II semantics.

3. ``PyProblem`` — the problem protocol for the scalar world (plain Python
   callables), mirroring the fused ``evaluate`` protocol of
   :class:`repro.core.api.BinaryProblem`.  ``repro.problems`` exposes each
   problem in both forms and tests assert the jnp engine agrees with this
   simulator node-for-node.

The simulator is the **paper-faithful baseline** recorded in EXPERIMENTS.md;
the BSP/JAX engine in ``repro.core.engine``/``distributed`` is the TPU-native
adaptation measured against it.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

from repro.core.indexing import fix_index, get_heaviest_task_index

INF = 2 ** 30


class PyNodeEval(NamedTuple):
    """Scalar twin of :class:`repro.core.api.NodeEval` (no payload — the
    oracle only tracks objective values, not solution artifacts)."""

    is_solution: bool
    value: int
    lower_bound: int
    left: Any
    right: Any


@dataclasses.dataclass(frozen=True)
class PyProblem:
    """Scalar (pure-Python) version of :class:`repro.core.api.BinaryProblem`.

    Semantics match the jnp form exactly: binary tree, minimization,
    deterministic branching, one fused ``evaluate(state, best) ->
    PyNodeEval`` per node visit.  ``evaluate`` must be side-effect free
    (children are new states) and its children must not depend on ``best``;
    the stepper keeps the explicit stack, which is the scalar analogue of
    the paper's undo-based backtracking (§III-D).
    """

    name: str
    max_depth: int
    root: Callable[[], Any]
    evaluate: Callable[[Any, int], PyNodeEval]

    @classmethod
    def from_callbacks(cls, *, name: str, max_depth: int,
                       root: Callable[[], Any],
                       apply: Callable[[Any, int], Any],
                       leaf_value: Callable[[Any], Tuple[bool, int]],
                       lower_bound: Callable[[Any], int]) -> "PyProblem":
        """Adapt a legacy three-callback scalar problem (no fusion: each
        node visit pays ``leaf_value + lower_bound + 2×apply``)."""

        def evaluate(state: Any, best: int) -> PyNodeEval:
            is_sol, val = leaf_value(state)
            return PyNodeEval(is_sol, val, lower_bound(state),
                              apply(state, 0), apply(state, 1))

        return cls(name=name, max_depth=max_depth, root=root,
                   evaluate=evaluate)

    def apply(self, state: Any, bit: int) -> Any:
        """Derived child generation (CONVERTINDEX replay uses this)."""
        ev = self.evaluate(state, INF)
        return ev.left if bit == 0 else ev.right


class _DFS:
    """Iterative one-node-per-step DFS with the paper's current_idx encoding.

    ``idx[j]`` is the branch (0/1) taken from depth j to j+1 on the live
    path, ``-1`` = that right sibling was delegated (skip on backtrack),
    ``-2`` = unvisited.  ``base`` is the depth of the subtree this core owns
    (its *main task*): backtracking above ``base`` means the core is done.
    """

    UNVISITED, DELEGATED = -2, -1

    def __init__(self, problem: PyProblem):
        self.p = problem
        self.idx: List[int] = [self.UNVISITED] * (problem.max_depth + 1)
        self.stack: List[Any] = [None] * (problem.max_depth + 2)
        self.depth = 0
        self.base = 0
        self.active = False
        self.nodes = 0

    def start_root(self) -> None:
        self.stack[0] = self.p.root()
        self.depth, self.base, self.active = 0, 0, True
        self.idx = [self.UNVISITED] * (self.p.max_depth + 1)

    def start_task(self, bits: List[int]) -> None:
        """CONVERTINDEX: replay a (FIXINDEX-ed) task index from the root."""
        self.idx = [self.UNVISITED] * (self.p.max_depth + 1)
        state = self.p.root()
        self.stack[0] = state
        for j, b in enumerate(bits):
            self.idx[j] = b
            state = self.p.apply(state, b)
            self.stack[j + 1] = state
        self.depth = self.base = len(bits)
        self.active = True

    def step(self, best: int) -> Tuple[bool, int]:
        """Visit one node. Returns (improved, value-if-improved-else-INF)."""
        if not self.active:
            return False, INF
        d = self.depth
        state = self.stack[d]
        c = self.idx[d]
        improved, val = False, INF

        if c == self.UNVISITED:                      # first arrival: visit node
            self.nodes += 1
            ev = self.p.evaluate(state, best)        # ONE fused node visit
            if ev.is_solution and ev.value < best:   # IsSolution (Fig. 3 l.5-6)
                improved, val, best = True, ev.value, ev.value
            pruned = ev.lower_bound >= best
            if ev.is_solution or pruned:             # leaf: backtrack (l.7-8)
                self._backtrack()
            else:                                    # descend left (l.13-16)
                self._descend(0, ev.left)
        elif c == 0:                                 # left done: go right
            ev = self.p.evaluate(state, best)
            self._descend(1, ev.right)
        else:                                        # c in {1, -1}: exhausted
            self._backtrack()
        return improved, val

    def _descend(self, bit: int, child: Any) -> None:
        d = self.depth
        self.idx[d] = bit
        self.stack[d + 1] = child
        if d + 1 <= self.p.max_depth:
            self.idx[d + 1] = self.UNVISITED
        self.depth = d + 1

    def _backtrack(self) -> None:
        self.depth -= 1
        if self.depth < self.base:
            self.active = False
            self.depth = self.base

    # -- the paper's Fig. 4 operations on the live path -------------------

    def get_heaviest(self) -> Optional[List[int]]:
        """GETHEAVIESTTASKINDEX over the live prefix [base, depth)."""
        live = self.idx[: self.depth]
        for i in range(self.base, self.depth):
            if live[i] == 0:
                self.idx[i] = self.DELEGATED
                return list(self.idx[: i + 1])
        return None


def serial_rb(problem: PyProblem, max_steps: int = 10 ** 8,
              record_visits: bool = False
              ) -> Tuple[int, int, List[Tuple[int, ...]]]:
    """SERIAL-RB (Fig. 1): returns (best value, nodes visited, visit log).

    The visit log (optional) records the bit-path of every *visited* node —
    the oracle for the "no node explored twice / none lost" property tests.
    """
    dfs = _DFS(problem)
    dfs.start_root()
    best = INF
    visits: List[Tuple[int, ...]] = []
    steps = 0
    while dfs.active and steps < max_steps:
        if record_visits and dfs.idx[dfs.depth] == _DFS.UNVISITED:
            visits.append(tuple(dfs.idx[: dfs.depth]))
        improved, val = dfs.step(best)
        if improved:
            best = val
        steps += 1
    return best, dfs.nodes, visits


@dataclasses.dataclass
class CoreStats:
    t_s: int = 0           # tasks received (main tasks), paper's T_S
    t_r: int = 0           # task requests issued, paper's T_R
    nodes: int = 0


class ParallelRBSimulator:
    """Discrete-time simulation of PARALLEL-RB (Fig. 7) on ``c`` cores.

    Message model: requests and responses are mailbox entries delivered
    instantly but *consumed at the receiver's next tick* — one-tick latency,
    which preserves the paper's asynchrony (a donor answers requests between
    node visits, Fig. 3 lines 9-11) without modelling a network.

    States: 'active' (has a main task), 'idle' (requesting), 'inactive'
    (passes > 2, Fig. 7 line 5).  Termination when all cores are inactive.
    """

    def __init__(self, problem: PyProblem, c: int,
                 instant_bound_share: bool = True):
        self.p = problem
        self.c = c
        self.cores = [_DFS(problem) for _ in range(c)]
        self.stats = [CoreStats() for _ in range(c)]
        self.state = ["idle"] * c
        self.parent = [get_parent(r, c) for r in range(c)]
        self.passes = [0] * c
        self.init = [True] * c
        self.requests: List[deque] = [deque() for _ in range(c)]   # requester ranks
        self.responses: List[deque] = [deque() for _ in range(c)]  # Optional[bits]
        self.outstanding = [False] * c
        self.best = INF
        self.instant_bound_share = instant_bound_share
        self.pending_best: Dict[int, int] = {}   # core -> best known (delayed mode)
        self.local_best = [INF] * c
        self.ticks = 0
        self.cores[0].start_root()
        self.state[0] = "active"
        self.stats[0].t_s = 1

    # ------------------------------------------------------------------

    def _answer_requests(self, r: int) -> None:
        """Fig. 3 lines 9-11: donor services queued requests between visits."""
        while self.requests[r]:
            requester = self.requests[r].popleft()
            task = self.cores[r].get_heaviest() if self.state[r] == "active" else None
            if task is not None:
                task = fix_index(task)
            self.responses[requester].append(task)

    def _core_best(self, r: int) -> int:
        return self.best if self.instant_bound_share else self.local_best[r]

    def _broadcast_best(self, v: int) -> None:
        """Notification message (§IV-B).  Instant mode models a free
        broadcast; delayed mode delivers at each core's next tick (one-hop
        latency), which only affects pruning efficiency, never correctness.
        """
        self.best = min(self.best, v)
        if self.instant_bound_share:
            for i in range(self.c):
                self.local_best[i] = min(self.local_best[i], v)
        else:
            for i in range(self.c):
                self.pending_best[i] = min(self.pending_best.get(i, INF), v)

    def tick(self) -> None:
        self.ticks += 1
        if not self.instant_bound_share and self.pending_best:
            for i, v in list(self.pending_best.items()):
                self.local_best[i] = min(self.local_best[i], v)
            self.pending_best.clear()
        for r in range(self.c):
            # Even inactive cores answer queued requests (with null) so no
            # requester blocks forever — the paper's status broadcast makes
            # this case rare; the mailbox makes it safe.
            self._answer_requests(r)
            if self.state[r] == "inactive":
                continue
            core = self.cores[r]
            if self.state[r] == "active":
                improved, val = core.step(self._core_best(r))
                self.stats[r].nodes = core.nodes
                if improved:
                    self._broadcast_best(val)   # notification message (§IV-B)
                if not core.active:
                    self.state[r] = "idle"
            if self.state[r] == "idle":
                self._idle_step(r)

    def _advance_parent(self, r: int) -> None:
        """Fig. 7 lines 12-14 / 18: move to the next parent in the topology."""
        if self.init[r]:
            self.init[r] = False
            self.parent[r] = (r + 1) % self.c
        else:
            self.parent[r], self.passes[r] = get_next_parent(
                self.parent[r], r, self.c, self.passes[r])
        if self.passes[r] > 2:                       # termination (l.5)
            self.state[r] = "inactive"

    def _idle_step(self, r: int) -> None:
        if self.responses[r]:                        # consume a reply
            self.outstanding[r] = False
            task = self.responses[r].popleft()
            if task is not None:
                self.cores[r].start_task(task)
                self.state[r] = "active"
                self.stats[r].t_s += 1
                self.passes[r] = 0
                if self.init[r]:                     # first reply: l.14
                    self.init[r] = False
                    self.parent[r] = (r + 1) % self.c
                return
            self._advance_parent(r)                  # null reply: probe on
            return
        if self.outstanding[r]:
            return                                   # wait for the reply
        target = self.parent[r]
        if target == r or self.state[target] == "inactive":
            self._advance_parent(r)                  # skip dead/self parents
            return
        self.requests[target].append(r)
        self.stats[r].t_r += 1
        self.outstanding[r] = True

    def run(self, max_ticks: int = 10 ** 7) -> "SimResult":
        while not all(s == "inactive" for s in self.state):
            if self.ticks >= max_ticks:
                raise RuntimeError("simulator did not terminate")
            self.tick()
        return SimResult(
            best=self.best,
            makespan=self.ticks,
            total_nodes=sum(st.nodes for st in self.stats),
            t_s=[st.t_s for st in self.stats],
            t_r=[st.t_r for st in self.stats],
        )


@dataclasses.dataclass
class SimResult:
    best: int
    makespan: int
    total_nodes: int
    t_s: List[int]
    t_r: List[int]

    @property
    def avg_t_s(self) -> float:
        return sum(self.t_s) / len(self.t_s)

    @property
    def avg_t_r(self) -> float:
        return sum(self.t_r) / len(self.t_r)


# ---------------------------------------------------------------------------
# Virtual topology (paper Fig. 5) — verbatim transcriptions.
# ---------------------------------------------------------------------------


def get_parent(r: int, c: int) -> int:
    """GETPARENT (Fig. 5, top).  C_0's parent is itself by convention."""
    parent = 0
    for i in range(c):
        if 2 ** i > r:
            break
        parent = r - 2 ** i
    return parent


def get_next_parent(parent: int, r: int, c: int, passes: int) -> Tuple[int, int]:
    """GETNEXTPARENT (Fig. 5, bottom).  Returns (new parent, new passes).

    ``passes`` increments each time the probe cycles past the core's own
    rank — i.e. once per full unsuccessful sweep of all participants.
    """
    parent = (parent + 1) % c
    if parent == r:
        parent = (parent + 1) % c
        passes += 1
    return parent, passes
