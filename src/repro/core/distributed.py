"""Distributed solve: shard_map steal rounds across the device mesh.

The paper's decentralized MPI protocol (virtual parent topology, non-blocking
task requests, incumbent broadcast, 3-state termination) maps to
bulk-synchronous rounds on a TPU mesh (DESIGN.md §2):

  round := expand(R engine steps)            # pure lane-local compute
           → intra-device steal              # lanes balance within a chip
           → cross-device steal              # collectives over the mesh
           → incumbent all-reduce(min)       # paper's notification broadcast
           → termination all-reduce          # paper's 3-state protocol

Cross-device steal (deterministic, loss-free):

  1. every device advertises (idle_count, donatable_count) — all_gather;
  2. a greedy prefix quota assigns each device a donation count such that
     Σ donate_i ≤ Σ idle_i (no extracted task can go unclaimed — extraction
     marks the donor slot DELEGATED, so an unclaimed task would be a lost
     subtree; the quota rule makes claiming a bijection);
  3. devices extract their quota (heaviest first) and all_gather the index
     vectors — O(d) int8 each, the paper's compact task encoding is what
     makes this affordable at 512+ devices;
  4. device r's idle lanes claim the tasks whose global rank matches their
     global thief rank (pure arithmetic, no extra messages);
  5. psum-min of the incumbent; the round loop ends when the global number
     of active lanes and donatable tasks are both zero.

The host driver (``repro.solver.Solver.solve``) runs these jitted rounds in
a Python loop so that checkpointing (paper §VII: persist ``current_idx``),
elastic re-sharding and fault injection happen at round boundaries — the
production posture for restartable long jobs.  The kwarg-style ``solve``
kept here is a deprecated shim over that facade (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import shard_map

from repro.core.api import UNVISITED, BinaryProblem
from repro.core import steal
from repro.core.engine import Lanes, init_lanes, make_expand


class SolveStats(NamedTuple):
    best: int
    rounds: int
    nodes: int
    t_s: int           # total tasks received (paper's T_S numerator)
    t_r: int           # total task requests (paper's T_R numerator)
    donated: int
    lanes: int
    t_c: int = 0       # tasks received cross-device (subset of t_s)


def _axis_rank(axis_names: Sequence[str]) -> jnp.ndarray:
    """Linearized device rank over (possibly multiple) mesh axes."""
    rank = jnp.int32(0)
    for name in axis_names:
        rank = rank * compat.axis_size(name) + jax.lax.axis_index(name)
    return rank


def cross_device_steal(problem: BinaryProblem, lanes: Lanes,
                       axis_names: Sequence[str], max_ship: int) -> Lanes:
    """One cross-device steal phase (steps 1-4 above), instance-scoped.

    ``max_ship`` bounds tasks shipped per device per round (static shape of
    the all_gather payload).  With K > 1 instances the entire protocol runs
    PER INSTANCE: demand/supply summaries, greedy prefix quotas and the
    rank-arithmetic claim are all keyed by ``inst``, so a thief only ever
    claims a task of its own instance (the tenant-isolation invariant).
    K = 1 reduces to the original single-pool protocol.
    """
    w, il = lanes.idx.shape
    k = lanes.best.shape[0]
    ax = tuple(axis_names)
    me = _axis_rank(ax)
    lane_ids = jnp.arange(w, dtype=jnp.int32)
    safe_inst = jnp.clip(lanes.inst, 0, k - 1)

    thieves = steal.thief_mask(lanes)
    slots = steal.donor_slots(lanes)
    donors = steal.donor_mask(lanes, slots)
    demand_local = jnp.zeros((k,), jnp.int32).at[safe_inst].add(
        thieves.astype(jnp.int32))
    donatable = jnp.zeros((k,), jnp.int32).at[safe_inst].add(
        donors.astype(jnp.int32))

    # (1) advertise; all_gather along the flattened mesh axes.
    summary = jnp.stack([demand_local, donatable], axis=1)      # [K, 2]
    all_sum = jax.lax.all_gather(summary, ax, tiled=False).reshape(-1, k, 2)
    demands, supplies = all_sum[:, :, 0], all_sum[:, :, 1]      # [D, K]
    total_demand = jnp.sum(demands, axis=0)                     # [K]

    # (2) greedy prefix quota per instance: devices donate in rank order
    # until that instance's demand is met.
    presum = jnp.cumsum(supplies, axis=0) - supplies            # [D, K]
    quota = jnp.clip(total_demand[None, :]
                     - jnp.minimum(presum, total_demand[None, :]),
                     0, supplies)                               # [D, K]
    # Cap each device's TOTAL at max_ship (static payload) with an
    # instance-major prefix over the demand-limited quotas — capping the
    # quotas (not the donatable counts) so a zero-demand instance's idle
    # supply can never crowd higher-id tenants out of the budget.  Every
    # device computes the same capped matrix, keeping the rank arithmetic
    # below globally consistent.
    qpre = jnp.cumsum(quota, axis=1) - quota                    # [D, K]
    quota = jnp.clip(max_ship - jnp.minimum(qpre, max_ship), 0, quota)
    my_quota = quota[me]                                        # [K]

    lanes, bits, tdepth, tinst, trank, valid = steal.extract_tasks(
        lanes, my_quota, max_tasks=max_ship)

    # (3) ship the index vectors (tiny: max_ship × (IDX_LEN+4) int32).
    # Each row carries its GLOBAL within-instance rank so claiming needs no
    # further coordination.
    task_offset = jnp.cumsum(quota, axis=0) - quota             # [D, K]
    grank_task = task_offset[me, tinst] + trank
    payload = jnp.concatenate(
        [bits.astype(jnp.int32), tdepth[:, None], tinst[:, None],
         grank_task[:, None], valid[:, None].astype(jnp.int32)],
        axis=1)                                                 # [S, IL+4]
    world = jax.lax.all_gather(payload, ax, tiled=False).reshape(
        -1, il + 4)                                             # [D*S, IL+4]
    w_bits, w_depth = world[:, :il], world[:, il]
    w_inst, w_grank = world[:, il + 1], world[:, il + 2]
    w_valid = world[:, il + 3] > 0

    # (4) claim by per-instance global rank arithmetic: the thief with
    # within-instance global rank g claims the instance's g-th global task.
    thief_offset = (jnp.cumsum(demands, axis=0) - demands)[me]  # [K]
    my_trank = steal._rank_within_instance(thieves, lane_ids, lanes.inst)
    my_grank = thief_offset[safe_inst] + my_trank
    src, claim = steal.claim_tasks(thieves, safe_inst, my_grank,
                                   w_inst, w_grank, w_valid)

    rbits = jnp.where(claim[:, None], w_bits[src].astype(jnp.int8),
                      UNVISITED)
    rdepth = jnp.where(claim, w_depth[src], 0)
    rinst = jnp.where(claim, w_inst[src], 0)

    lanes = lanes._replace(t_r=lanes.t_r + thieves.astype(jnp.int32))
    return steal.install_tasks(problem, lanes, rbits, rdepth, rinst, claim,
                               cross=True)


def make_round(problem: BinaryProblem, steps_per_round: int,
               axis_names: Sequence[str] = (), max_ship: int = 16,
               fused_steps: int = 1,
               ) -> Callable[[Lanes], Tuple[Lanes, jnp.ndarray]]:
    """Build the per-device round body (expand → steal → share → count).

    With empty ``axis_names`` this is the single-device round used by unit
    tests; otherwise it must run inside shard_map over those axes.
    ``fused_steps`` groups S engine steps per expand-loop iteration
    (tree-identical for any S — see ``make_expand``).
    """
    expand = make_expand(problem, steps_per_round, fused_steps)

    def round_fn(lanes: Lanes) -> Tuple[Lanes, jnp.ndarray]:
        lanes = expand(lanes)
        lanes = steal.balance_device(problem, lanes)
        if axis_names:
            lanes = cross_device_steal(problem, lanes, axis_names, max_ship)
            # Paper's notification broadcast: share the incumbent table.
            best = jax.lax.pmin(lanes.best, tuple(axis_names))
            lanes = lanes._replace(best=best)
        # Termination metric PER INSTANCE: active lanes + donatable slots.
        # The service driver retires instance i when open_work[i] == 0; the
        # single-instance solve sums the vector.
        k = lanes.best.shape[0]
        safe_inst = jnp.clip(lanes.inst, 0, k - 1)
        slots = steal.donor_slots(lanes)
        contrib = (lanes.active.astype(jnp.int32)
                   + (lanes.active
                      & (slots < lanes.idx.shape[1])).astype(jnp.int32))
        open_work = jnp.zeros((k,), jnp.int32).at[safe_inst].add(contrib)
        if axis_names:
            open_work = jax.lax.psum(open_work, tuple(axis_names))
        return lanes, open_work

    return round_fn


def lane_partition_specs(problem: BinaryProblem,
                         axis_names: Sequence[str]) -> Lanes:
    """PartitionSpec pytree for ``Lanes`` under a mesh: lane arrays shard
    their leading W-dim over all mesh axes; the per-instance incumbent
    table (``best``, ``best_payload``) and the step clock are replicated.
    Shared by the solve path, the sharded service driver and the mesh
    tests."""
    axes = tuple(axis_names)

    def spec_for(field):
        return P() if field in ("best", "steps", "best_payload") else P(axes)

    proto = _lanes_proto(problem)
    return Lanes(**{f: jax.tree_util.tree_map(
        lambda _: spec_for(f), getattr(proto, f)) for f in Lanes._fields})


def make_distributed_round(problem: BinaryProblem, mesh: Mesh,
                           steps_per_round: int, max_ship: int = 16,
                           fused_steps: int = 1):
    """shard_map the round over every axis of ``mesh`` (flat worker pool)."""
    axes = tuple(mesh.axis_names)
    round_fn = make_round(problem, steps_per_round, axes, max_ship,
                          fused_steps)
    in_specs = lane_partition_specs(problem, axes)
    fn = shard_map(round_fn, mesh=mesh, in_specs=(in_specs,),
                   out_specs=(in_specs, P()), check=False)
    return jax.jit(fn)


def _lanes_proto(problem: BinaryProblem) -> Lanes:
    """Structure-only prototype used to build PartitionSpec pytrees."""
    return init_lanes(problem, 1, seed_root=False)


def solve(problem: BinaryProblem,
          num_lanes: int,
          steps_per_round: int = 256,
          max_rounds: int = 100000,
          mesh: Optional[Mesh] = None,
          max_ship: int = 16,
          bootstrap_rounds: int = 0,
          bootstrap_steps: int = 8,
          checkpoint_every: int = 0,
          checkpoint_path: Optional[str] = None,
          resume_from: Optional[str] = None,
          on_round: Optional[Callable[[int, Lanes, int], None]] = None,
          ) -> Tuple[Any, SolveStats, Lanes]:
    """DEPRECATED kwarg entry point — use :class:`repro.solver.Solver`.

    Thin shim over ``Solver(SolverConfig(...)).solve(problem)`` (DESIGN.md
    §6); the round loop is the facade's, so results are bitwise-identical
    to the new API.  ``num_lanes`` is the per-device lane count
    (``SolverConfig.lanes``); ``on_round`` maps onto the typed
    :class:`repro.solver.ProgressEvent` stream ("round" events).
    """
    import warnings

    from repro.solver import ProgressEvent, Solver, SolverConfig

    warnings.warn(
        "repro.core.distributed.solve(...) is deprecated; use "
        "repro.solver.Solver(SolverConfig(...)).solve(problem)",
        DeprecationWarning, stacklevel=2)
    if checkpoint_every and not checkpoint_path:
        checkpoint_every = 0        # legacy behavior: silently no-op
    config = SolverConfig(
        lanes=num_lanes, steps_per_round=steps_per_round,
        max_rounds=max_rounds, mesh=mesh, max_ship=max_ship,
        bootstrap_rounds=bootstrap_rounds, bootstrap_steps=bootstrap_steps,
        checkpoint_every=checkpoint_every, checkpoint_path=checkpoint_path,
        resume_from=resume_from)
    on_event = None
    if on_round is not None:
        def on_event(ev: ProgressEvent) -> None:
            if ev.kind == "round":
                on_round(ev.round, ev.lanes, ev.open_work)
    result = Solver(config, on_event=on_event).solve(problem)
    return result.payload, result.stats, result.lanes


def _gather_lanes(lanes: Lanes) -> Lanes:
    """Pull lane state to host (fully addressable) for pool/ckpt surgery."""
    return jax.tree_util.tree_map(
        lambda l: jnp.asarray(np.asarray(jax.device_get(l))), lanes)


def _shard_lanes(lanes: Lanes, mesh: Mesh) -> Lanes:
    """Place lane arrays sharded over all mesh axes (leading dim)."""
    axes = tuple(mesh.axis_names)

    def put(field, leaf):
        if field in ("best", "steps") or leaf.ndim == 0:
            spec = P()
        elif field == "best_payload":
            spec = P()
        else:
            spec = P(axes)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return Lanes(**{
        f: jax.tree_util.tree_map(lambda l: put(f, l), getattr(lanes, f))
        for f in Lanes._fields})
