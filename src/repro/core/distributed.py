"""Distributed solve: shard_map steal rounds across the device mesh.

The paper's decentralized MPI protocol (virtual parent topology, non-blocking
task requests, incumbent broadcast, 3-state termination) maps to
bulk-synchronous rounds on a TPU mesh (DESIGN.md §2):

  round := expand(R engine steps)            # pure lane-local compute
           → intra-device steal              # lanes balance within a chip
           → cross-device steal              # collectives over the mesh
           → incumbent all-reduce(min)       # paper's notification broadcast
           → termination all-reduce          # paper's 3-state protocol

Cross-device steal (deterministic, loss-free):

  1. every device advertises (idle_count, donatable_count) — all_gather;
  2. a greedy prefix quota assigns each device a donation count such that
     Σ donate_i ≤ Σ idle_i (no extracted task can go unclaimed — extraction
     marks the donor slot DELEGATED, so an unclaimed task would be a lost
     subtree; the quota rule makes claiming a bijection);
  3. devices extract their quota (heaviest first) and all_gather the index
     vectors — O(d) int8 each, the paper's compact task encoding is what
     makes this affordable at 512+ devices;
  4. device r's idle lanes claim the tasks whose global rank matches their
     global thief rank (pure arithmetic, no extra messages);
  5. psum-min of the incumbent; the round loop ends when the global number
     of active lanes and donatable tasks are both zero.

The host driver (`solve`) runs jitted rounds in a Python loop so that
checkpointing (paper §VII: persist ``current_idx``), elastic re-sharding and
fault injection happen at round boundaries — the production posture for
restartable long jobs.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import shard_map

from repro.core.api import UNVISITED, INF_VALUE, BinaryProblem
from repro.core import steal
from repro.core.engine import Lanes, init_lanes, make_expand


class SolveStats(NamedTuple):
    best: int
    rounds: int
    nodes: int
    t_s: int           # total tasks received (paper's T_S numerator)
    t_r: int           # total task requests (paper's T_R numerator)
    donated: int
    lanes: int


def _axis_rank(axis_names: Sequence[str]) -> jnp.ndarray:
    """Linearized device rank over (possibly multiple) mesh axes."""
    rank = jnp.int32(0)
    for name in axis_names:
        rank = rank * compat.axis_size(name) + jax.lax.axis_index(name)
    return rank


def cross_device_steal(problem: BinaryProblem, lanes: Lanes,
                       axis_names: Sequence[str], max_ship: int) -> Lanes:
    """One cross-device steal phase (steps 1-4 above).

    ``max_ship`` bounds tasks shipped per device per round (static shape of
    the all_gather payload).
    """
    w, il = lanes.idx.shape
    ax = tuple(axis_names)
    me = _axis_rank(ax)

    idle = (~lanes.active).astype(jnp.int32)
    demand_local = jnp.sum(idle)
    slots = steal.donor_slots(lanes)
    supply_local = jnp.sum((lanes.active & (slots < il)).astype(jnp.int32))
    supply_local = jnp.minimum(supply_local, max_ship)

    # (1) advertise; all_gather along the flattened mesh axes.
    summary = jnp.stack([demand_local, supply_local])
    all_sum = jax.lax.all_gather(summary, ax, tiled=False)  # [D, 2]
    all_sum = all_sum.reshape(-1, 2)
    demands, supplies = all_sum[:, 0], all_sum[:, 1]
    total_demand = jnp.sum(demands)

    # (2) greedy prefix quota: devices donate in rank order until demand met.
    presum = jnp.cumsum(supplies) - supplies
    quota = jnp.clip(total_demand - jnp.minimum(presum, total_demand),
                     0, supplies)
    my_quota = quota[me]

    # Don't ship to ourselves what we can solve locally: local thieves are
    # served by the intra-device round that precedes this phase, so demand
    # here is already net of local matches.
    lanes, bits, tdepth, valid = steal.extract_tasks(
        lanes, my_quota, max_tasks=max_ship)

    # (3) ship the index vectors (tiny: max_ship × IDX_LEN int8).
    payload = jnp.concatenate(
        [bits.astype(jnp.int32), tdepth[:, None], valid[:, None].astype(jnp.int32)],
        axis=1)                                            # [S, IL+2]
    world = jax.lax.all_gather(payload, ax, tiled=False).reshape(
        -1, max_ship, il + 2)                               # [D, S, IL+2]

    # (4) claim by global rank arithmetic.  ``install_tasks`` hands row k to
    # the k-th idle lane (its thief-rank contract), so rows here MUST be
    # indexed by local thief rank, not by lane id — per-lane rows silently
    # drop tasks whenever the idle lanes are not a prefix of the lane ids
    # (the dropped task is already DELEGATED at its donor: a lost subtree).
    task_counts = quota                                     # tasks from dev j
    task_offset = jnp.cumsum(task_counts) - task_counts
    thief_offset = (jnp.cumsum(demands) - demands)[me]
    n_tasks_total = jnp.sum(task_counts)

    # Flatten world tasks in (device, slot) order; the g-th valid global task
    # lives at flat position: device j with task_offset[j] <= g <
    # task_offset[j]+quota[j], slot g - task_offset[j].
    rank = jnp.arange(w, dtype=jnp.int32)                   # local thief rank
    grank = thief_offset + rank                             # global thief rank
    claim = (rank < demand_local) & (grank < n_tasks_total)
    g = jnp.clip(grank, 0, jnp.maximum(n_tasks_total - 1, 0))
    src_dev = jnp.sum((task_offset[None, :] <= g[:, None]).astype(jnp.int32),
                      axis=1) - 1
    src_dev = jnp.clip(src_dev, 0, world.shape[0] - 1)
    src_slot = jnp.clip(g - task_offset[src_dev], 0, max_ship - 1)

    recv = world[src_dev, src_slot]                         # [W, IL+2]
    rbits = jnp.where(claim[:, None], recv[:, :il].astype(jnp.int8),
                      UNVISITED)
    rdepth = jnp.where(claim, recv[:, il], 0)
    rvalid = claim & (recv[:, il + 1] > 0)

    lanes = lanes._replace(t_r=lanes.t_r + (~lanes.active).astype(jnp.int32))
    return steal.install_tasks(problem, lanes, rbits, rdepth, rvalid)


def make_round(problem: BinaryProblem, steps_per_round: int,
               axis_names: Sequence[str] = (), max_ship: int = 16,
               ) -> Callable[[Lanes], Tuple[Lanes, jnp.ndarray]]:
    """Build the per-device round body (expand → steal → share → count).

    With empty ``axis_names`` this is the single-device round used by unit
    tests; otherwise it must run inside shard_map over those axes.
    """
    expand = make_expand(problem, steps_per_round)

    def round_fn(lanes: Lanes) -> Tuple[Lanes, jnp.ndarray]:
        lanes = expand(lanes)
        lanes = steal.balance_device(problem, lanes)
        if axis_names:
            lanes = cross_device_steal(problem, lanes, axis_names, max_ship)
            # Paper's notification broadcast: share the incumbent value.
            best = jax.lax.pmin(lanes.best, tuple(axis_names))
            lanes = lanes._replace(best=best)
        # Termination metric: active lanes + donatable slots, globally.
        slots = steal.donor_slots(lanes)
        open_work = (jnp.sum(lanes.active.astype(jnp.int32))
                     + jnp.sum((slots < lanes.idx.shape[1]).astype(jnp.int32)))
        if axis_names:
            open_work = jax.lax.psum(open_work, tuple(axis_names))
        return lanes, open_work

    return round_fn


def make_distributed_round(problem: BinaryProblem, mesh: Mesh,
                           steps_per_round: int, max_ship: int = 16):
    """shard_map the round over every axis of ``mesh`` (flat worker pool)."""
    axes = tuple(mesh.axis_names)
    round_fn = make_round(problem, steps_per_round, axes, max_ship)

    # Lane arrays shard their leading W-dim over all mesh axes; scalars
    # (best, steps) and the incumbent payload are replicated per device.
    def in_spec_for(field, leaf):
        if field in ("best", "steps"):
            return P()
        if field == "best_payload":
            return P()
        return P(axes)

    in_specs = Lanes(**{f: jax.tree_util.tree_map(
        lambda _: in_spec_for(f, _), getattr(_lanes_proto(problem), f))
        for f in Lanes._fields})

    fn = shard_map(round_fn, mesh=mesh, in_specs=(in_specs,),
                   out_specs=(in_specs, P()), check=False)
    return jax.jit(fn)


def _lanes_proto(problem: BinaryProblem) -> Lanes:
    """Structure-only prototype used to build PartitionSpec pytrees."""
    return init_lanes(problem, 1, seed_root=False)


def solve(problem: BinaryProblem,
          num_lanes: int,
          steps_per_round: int = 256,
          max_rounds: int = 100000,
          mesh: Optional[Mesh] = None,
          max_ship: int = 16,
          bootstrap_rounds: int = 0,
          bootstrap_steps: int = 8,
          checkpoint_every: int = 0,
          checkpoint_path: Optional[str] = None,
          resume_from: Optional[str] = None,
          on_round: Optional[Callable[[int, Lanes, int], None]] = None,
          ) -> Tuple[Any, SolveStats, Lanes]:
    """Host driver: run rounds until global termination.

    ``num_lanes`` is the per-device lane count.  With ``mesh=None`` the solve
    is single-device (unit tests, benchmarks); with a mesh every device runs
    ``num_lanes`` lanes and rounds are the shard_map'd collective version.

    Bootstrap: a few short rounds (small R) ramp work distribution up the
    same way the paper's GETPARENT topology floods initial tasks — without
    it, every lane but lane 0 idles for a full round.

    ``resume_from`` restores a checkpoint written by any earlier run at ANY
    lane/device count (elastic restart, paper §VII): surplus tasks beyond
    the new lane count wait in a host-side pool and are installed into idle
    lanes at round boundaries.
    """
    from repro.core import checkpoint as ckpt

    if mesh is None:
        round_fn = jax.jit(make_round(problem, steps_per_round))
        boot_fn = (jax.jit(make_round(problem, bootstrap_steps))
                   if bootstrap_rounds else None)
        total_lanes = num_lanes
    else:
        n_dev = int(np.prod(mesh.devices.shape))
        round_fn = make_distributed_round(problem, mesh, steps_per_round,
                                          max_ship)
        boot_fn = (make_distributed_round(problem, mesh, bootstrap_steps,
                                          max_ship)
                   if bootstrap_rounds else None)
        total_lanes = num_lanes * n_dev

    pool: list = []
    if resume_from is not None:
        lanes, pool = ckpt.restore(resume_from, problem, total_lanes)
        bootstrap_rounds = max(bootstrap_rounds, 1)  # respread stolen work
    else:
        lanes = init_lanes(problem, total_lanes)
    if mesh is not None:
        lanes = _shard_lanes(lanes, mesh)

    def feed_pool(lanes):
        nonlocal pool
        if pool:
            lanes = _gather_lanes(lanes)
            lanes, pool = ckpt.install_pending(problem, lanes, pool)
            if mesh is not None:
                lanes = _shard_lanes(lanes, mesh)
        return lanes

    rounds, done = 0, False
    for _ in range(bootstrap_rounds):
        lanes = feed_pool(lanes)
        lanes, open_work = boot_fn(lanes) if boot_fn else round_fn(lanes)
        rounds += 1
        if int(open_work) == 0 and not pool:
            done = True
            break
    while not done and rounds < max_rounds:
        lanes = feed_pool(lanes)
        lanes, open_work = round_fn(lanes)
        rounds += 1
        if on_round is not None:
            on_round(rounds, lanes, int(open_work))
        if checkpoint_every and checkpoint_path and rounds % checkpoint_every == 0:
            ckpt.save(checkpoint_path, _gather_lanes(lanes))
        if int(open_work) == 0 and not pool:
            done = True

    stats = SolveStats(
        best=int(jnp.min(lanes.best)),
        rounds=rounds,
        nodes=int(jnp.sum(lanes.nodes)),
        t_s=int(jnp.sum(lanes.t_s)),
        t_r=int(jnp.sum(lanes.t_r)),
        donated=int(jnp.sum(lanes.donated)),
        lanes=int(lanes.active.shape[0]),
    )
    best_payload = jax.tree_util.tree_map(np.asarray, lanes.best_payload)
    return best_payload, stats, lanes


def _gather_lanes(lanes: Lanes) -> Lanes:
    """Pull lane state to host (fully addressable) for pool/ckpt surgery."""
    return jax.tree_util.tree_map(
        lambda l: jnp.asarray(np.asarray(jax.device_get(l))), lanes)


def _shard_lanes(lanes: Lanes, mesh: Mesh) -> Lanes:
    """Place lane arrays sharded over all mesh axes (leading dim)."""
    axes = tuple(mesh.axis_names)

    def put(field, leaf):
        if field in ("best", "steps") or leaf.ndim == 0:
            spec = P()
        elif field == "best_payload":
            spec = P()
        else:
            spec = P(axes)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return Lanes(**{
        f: jax.tree_util.tree_map(lambda l: put(f, l), getattr(lanes, f))
        for f in Lanes._fields})
