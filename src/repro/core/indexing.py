"""Indexed search trees (paper §IV-A and §IV-C).

This module contains the paper's index machinery in two forms:

1. *Faithful scalar reference* (`get_heaviest_task_index`, `fix_index`) —
   direct transcriptions of Fig. 4, operating on Python lists.  These are the
   oracles for property tests and the protocol simulator in
   ``repro.core.serial``.

2. *Vectorized jnp versions* (`heaviest_open_slot`, `extract_task`,
   `fix_task_bits`) operating on fixed-width ``int8[D_MAX]`` arrays with the
   sentinels from :mod:`repro.core.api`.  These are what the engine and the
   steal round use, vmapped over lanes.

Binary-tree indices are bit paths: ``idx[j]`` is the branch taken from depth
``j`` to ``j+1``.  ``idx[j] == 0`` means the left child is in progress, so the
*right* sibling at depth ``j+1`` is still unexplored — the shallowest such
slot is the heaviest task (weight ``1/(d+1)``).  Marking a slot ``-1``
(DELEGATED) records that this right sibling was shipped to another worker and
must be skipped when backtracking (Fig. 3, lines 2-3).

§IV-C (arbitrary branching factor) is implemented by
`ArbitraryIndex`: a 2 x D_MAX array whose first row is the child-position path
(idx1) and whose second row counts unexplored right siblings (idx2).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.api import DELEGATED, LEFT, RIGHT, UNVISITED

# ---------------------------------------------------------------------------
# 1. Faithful scalar reference (paper Fig. 4) — Python ints, used by tests
#    and the serial protocol simulator.
# ---------------------------------------------------------------------------


def get_heaviest_task_index(current_idx: List[int]) -> Optional[List[int]]:
    """Paper Fig. 4 (top): extract the heaviest unexplored task.

    Scans top-down for the first slot equal to 0 (left child in progress ⇒
    right sibling pending), marks it -1 in-place, and returns the prefix
    ``current_idx[0..i]`` (inclusive), exactly as the paper does.  Returns
    None when no task is available.
    """
    for i in range(len(current_idx)):
        if current_idx[i] == 0:
            current_idx[i] = -1
            return list(current_idx[: i + 1])
    return None


def fix_index(temp_idx: List[int]) -> List[int]:
    """Paper Fig. 4 (bottom): convert an extracted prefix into a task index.

    Interior negative entries (slots that were delegated *earlier* along the
    donor's path) are reset to 0 — the donor's path went left there — and the
    last entry becomes 1: the stolen task is the right sibling.
    """
    out = list(temp_idx)
    for i in range(len(out) - 1):
        if out[i] < 0:
            out[i] = 0
    out[-1] = 1
    return out


def index_to_position(bits: List[int]) -> Tuple[int, int]:
    """(depth, position) of the node addressed by a bit-path (paper §II)."""
    d = len(bits)
    p = 0
    for b in bits:
        p = (p << 1) | int(b)
    return d, p


# ---------------------------------------------------------------------------
# 2. Vectorized jnp versions used by the engine (fixed width D_MAX).
# ---------------------------------------------------------------------------


def heaviest_open_slot(idx: jnp.ndarray, base_depth: jnp.ndarray,
                       depth: jnp.ndarray) -> jnp.ndarray:
    """Depth of the shallowest open (stealable) slot, or D_MAX if none.

    A slot j is open iff base_depth <= j < depth and idx[j] == LEFT: the lane
    went left at depth j and the right sibling is unexplored.  Slots below
    ``base_depth`` belong to the subtree's owner further up the (virtual)
    delegation chain and are never stealable — the vectorized analogue of the
    paper's "each core only donates from its own main task".
    """
    d_max = idx.shape[-1]
    j = jnp.arange(d_max, dtype=jnp.int32)
    open_mask = (idx == LEFT) & (j >= base_depth) & (j < depth)
    return jnp.min(jnp.where(open_mask, j, jnp.int32(d_max)))


def extract_task(idx: jnp.ndarray, slot: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized GETHEAVIESTTASKINDEX + FIXINDEX in one shot.

    Marks ``idx[slot] = DELEGATED`` in the donor's array and returns
    ``(donor_idx, task_bits)`` where ``task_bits`` is the *fixed* index of the
    stolen node: bits[j<slot] are the donor's path with delegation marks
    flattened to LEFT (FIXINDEX), ``bits[slot] = RIGHT``, and padding is
    UNVISITED.  The stolen node lives at depth ``slot + 1``.
    """
    d_max = idx.shape[-1]
    j = jnp.arange(d_max, dtype=jnp.int32)
    donor_idx = jnp.where(j == slot, DELEGATED, idx)
    prefix = jnp.where(idx < 0, LEFT, idx)           # FIXINDEX interior rule
    bits = jnp.where(j < slot, prefix, UNVISITED)
    bits = jnp.where(j == slot, RIGHT, bits)
    return donor_idx, bits.astype(jnp.int8)


def task_weight(slot: jnp.ndarray) -> jnp.ndarray:
    """Paper §II: w(N_{d,p}) = 1/(d+1); the stolen node is at depth slot+1."""
    return 1.0 / (slot.astype(jnp.float32) + 2.0)


# ---------------------------------------------------------------------------
# 3. Arbitrary branching factor (paper §IV-C) — reference implementation.
# ---------------------------------------------------------------------------


class ArbitraryIndex:
    """Two-row index for trees with arbitrary branching factor (§IV-C).

    Row 0 (idx1): child position taken at each depth (the root-to-node path).
    Row 1 (idx2): number of unexplored *right* siblings at each depth.

    The heaviest task is found at the first depth x whose idx2 entry is
    non-zero; stealing sends the last ``s`` siblings (the paper requires the
    stolen set S to be a suffix of the children ordering) and decrements idx2
    by |S|.  With branching factor 2 this degenerates exactly to the binary
    scheme above, which the property tests assert.
    """

    def __init__(self, max_depth: int):
        self.max_depth = max_depth
        self.idx1 = np.full(max_depth, -2, dtype=np.int32)
        self.idx2 = np.full(max_depth, -2, dtype=np.int32)
        self.depth = 0

    def push_child(self, k: int, num_children: int) -> None:
        """Descend to the k-th child (0-based) of a node with num_children."""
        self.idx1[self.depth] = k
        self.idx2[self.depth] = num_children - (k + 1)
        self.depth += 1

    def pop(self) -> None:
        self.depth -= 1
        self.idx1[self.depth] = -2
        self.idx2[self.depth] = -2

    def advance_sibling(self) -> bool:
        """Move to the next unexplored right sibling at the current depth.

        Returns False when none remain (all explored or delegated).
        """
        d = self.depth - 1
        if d < 0 or self.idx2[d] <= 0:
            return False
        self.idx1[d] += 1
        self.idx2[d] -= 1
        return True

    def heaviest_depth(self) -> Optional[int]:
        for x in range(self.depth):
            if self.idx2[x] > 0:
                return x
        return None

    def steal(self, take: int = 1) -> Optional[Tuple[np.ndarray, int, int]]:
        """Extract up to ``take`` trailing siblings of the heaviest depth.

        Returns (path idx1[0..x], first stolen child position, count) and
        decrements idx2[x] — the paper's "choose S as a suffix" rule.
        """
        x = self.heaviest_depth()
        if x is None:
            return None
        s = min(take, int(self.idx2[x]))
        first = self.idx1[x] + (self.idx2[x] - s) + 1
        self.idx2[x] -= s
        return self.idx1[: x + 1].copy(), int(first), s
