"""The paper's contribution: indexed search trees + parallel backtracking.

Public API:
  BinaryProblem          — fused-evaluate problem protocol (jnp, engine form)
  NodeEval               — the fused per-node evaluation record
  PyProblem / PyNodeEval — problem protocol (scalar oracle form)
  solve                  — distributed solver driver (single- or multi-device)
  serial_rb              — SERIAL-RB oracle
  ParallelRBSimulator    — faithful PARALLEL-RB protocol simulator

Legacy three-callback problems adapt via ``BinaryProblem.from_callbacks`` /
``PyProblem.from_callbacks`` (DESIGN.md §1).
"""

from repro.core.api import (  # noqa: F401
    DELEGATED, LEFT, RIGHT, UNVISITED, INF_VALUE, BinaryProblem, NodeEval,
    tree_select,
)
from repro.core.serial import (  # noqa: F401
    INF, ParallelRBSimulator, PyNodeEval, PyProblem, SimResult,
    get_next_parent, get_parent, serial_rb,
)
from repro.core.distributed import SolveStats, solve  # noqa: F401
from repro.core.engine import Lanes, init_lanes  # noqa: F401
