"""User-facing problem protocol for the parallel recursive backtracking framework.

The paper (Abu-Khzam et al., 2013) requires only that (a) the number of
children of a search-node can be computed on the fly and (b) child generation
is deterministic with a well-defined order, so that re-running the serial
algorithm always yields the identical search tree.  We inherit both
requirements and strengthen them for XLA: every callback must be jnp-traceable
with static shapes.

A problem is expressed against a *binary* search tree (the paper's primary
setting; ``repro.core.indexing`` also implements the arbitrary-branching
encoding of §IV-C).  Each node either branches into exactly two children
(``left = bit 0``, ``right = bit 1``) or is a terminal (leaf / pruned).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

PyTree = Any

#: Sentinel values used in ``current_idx`` arrays (paper, Fig. 2-4).
UNVISITED = jnp.int8(-2)   # slot beyond the live path / child not yet taken
DELEGATED = jnp.int8(-1)   # right sibling at this depth was shipped elsewhere
LEFT = jnp.int8(0)
RIGHT = jnp.int8(1)

#: "Infinite" objective for minimization problems (int32-safe).
INF_VALUE = jnp.int32(2**30)


@dataclasses.dataclass(frozen=True)
class BinaryProblem:
    """A minimization problem explored by binary recursive backtracking.

    All callables receive/return jnp values and must be shape-static,
    deterministic and vmap-safe.  ``state`` is an arbitrary pytree whose
    leaves have fixed shapes.

    Attributes:
      name: identifier used in logs/benchmarks.
      max_depth: static bound D_MAX on the tree depth (root is depth 0; any
        node satisfies depth <= max_depth).
      root: () -> state — the root search-node.
      apply: (state, bit:int32) -> state — descend to the left (0) or right
        (1) child.  Must be total: called under ``lax.cond``-free vectorized
        code, it may be invoked on terminal states whose result is discarded.
      leaf_value: (state) -> (is_solution_leaf: bool, value: int32) — whether
        this node is a *solution* leaf and its objective value.  Non-solution
        terminals (infeasible nodes) must return (False, anything).
      lower_bound: (state) -> int32 — admissible lower bound on the best
        objective in the subtree rooted here.  The engine prunes when
        ``lower_bound(state) >= best_so_far`` (we search for strictly better
        solutions, mirroring IsSolution in the paper).  Terminal/infeasible
        nodes should return INF_VALUE so that arity becomes 0.
      solution_payload: (state) -> pytree — the actual solution (e.g. the
        cover bitset) recorded when a new incumbent is found.
      payload_zero: () -> pytree — zero-initialized payload of the same
        structure/shape (used to allocate incumbent buffers).
    """

    name: str
    max_depth: int
    root: Callable[[], PyTree]
    apply: Callable[[PyTree, jnp.ndarray], PyTree]
    leaf_value: Callable[[PyTree], tuple]
    lower_bound: Callable[[PyTree], jnp.ndarray]
    solution_payload: Callable[[PyTree], PyTree]
    payload_zero: Callable[[], PyTree]

    def arity(self, state: PyTree, best: jnp.ndarray) -> jnp.ndarray:
        """Number of children: 0 when leaf or pruned by bound, else 2.

        This composition is what the paper calls HasNextChild + the
        branch-and-reduce pruning rule: a child is generated only while the
        node can still beat the incumbent.
        """
        is_leaf, _ = self.leaf_value(state)
        pruned = self.lower_bound(state) >= best
        return jnp.where(is_leaf | pruned, jnp.int32(0), jnp.int32(2))
