"""User-facing problem protocol for the parallel recursive backtracking framework.

The paper (Abu-Khzam et al., 2013) requires only that (a) the number of
children of a search-node can be computed on the fly and (b) child generation
is deterministic with a well-defined order, so that re-running the serial
algorithm always yields the identical search tree.  We inherit both
requirements and strengthen them for XLA: every callback must be jnp-traceable
with static shapes.

A problem is expressed against a *binary* search tree (the paper's primary
setting; ``repro.core.indexing`` also implements the arbitrary-branching
encoding of §IV-C).  Each node either branches into exactly two children
(``left = bit 0``, ``right = bit 1``) or is a terminal (leaf / pruned).

Fused protocol (DESIGN.md §1).  A problem provides ONE callback::

    evaluate(state, best) -> NodeEval(is_solution, value, lower_bound,
                                      left, right, payload)

The engine visits exactly one search-node per lane per step, and that visit
is exactly one ``evaluate`` call — the paper's unit of work (§III-D).  All
per-node intermediates (degree vectors, alive masks, branch-vertex picks)
are computed once inside ``evaluate`` and shared between the solution test,
the bound, and both children.  The previous three-callback protocol
(``apply`` / ``leaf_value`` / ``lower_bound``) paid for those intermediates
up to four times per visit; :meth:`BinaryProblem.from_callbacks` adapts such
legacy problems unchanged.

Determinism contract: ``left``, ``right`` and ``payload`` must NOT depend on
``best`` — the incumbent may legally influence only pruning (via
``lower_bound``), never the tree shape, or replayed tasks would diverge from
their donors.  Unused ``NodeEval`` fields are dead-code-eliminated by XLA,
so e.g. CONVERTINDEX replay (which only consumes one child) does not pay for
the bound computation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any

#: Sentinel values used in ``current_idx`` arrays (paper, Fig. 2-4).
UNVISITED = jnp.int8(-2)   # slot beyond the live path / child not yet taken
DELEGATED = jnp.int8(-1)   # right sibling at this depth was shipped elsewhere
LEFT = jnp.int8(0)
RIGHT = jnp.int8(1)

#: "Infinite" objective for minimization problems (int32-safe).
INF_VALUE = jnp.int32(2**30)


class NodeEval(NamedTuple):
    """Everything the engine needs from one search-node, in one pass.

    Attributes:
      is_solution: bool — this node is a *solution* leaf.  Non-solution
        terminals (infeasible nodes) return False and rely on
        ``lower_bound >= best`` (use INF_VALUE) to become terminal.
      value: int32 — objective value if ``is_solution`` (arbitrary otherwise).
      lower_bound: int32 — admissible lower bound on the best objective in
        the subtree rooted here.  The engine prunes when ``lower_bound >=
        best_so_far`` (strictly-better search, mirroring the paper's
        IsSolution).
      left: state pytree — the bit-0 child.  Must be total: it is computed
        under branchless vectorized code even at terminal nodes, where it is
        discarded.
      right: state pytree — the bit-1 child (same totality requirement).
      payload: pytree — the actual solution (e.g. the cover bitset) recorded
        when this node improves the incumbent.
    """

    is_solution: jnp.ndarray
    value: jnp.ndarray
    lower_bound: jnp.ndarray
    left: PyTree
    right: PyTree
    payload: PyTree


def tree_select(pred: jnp.ndarray, a: PyTree, b: PyTree) -> PyTree:
    """Branchless pytree blend: ``a`` where ``pred`` else ``b``."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


@dataclasses.dataclass(frozen=True)
class BinaryProblem:
    """A minimization problem explored by binary recursive backtracking.

    All callables receive/return jnp values and must be shape-static,
    deterministic and vmap-safe.  ``state`` is an arbitrary pytree whose
    leaves have fixed shapes.

    Attributes:
      name: identifier used in logs/benchmarks.
      max_depth: static bound D_MAX on the tree depth (root is depth 0; any
        node satisfies depth <= max_depth).
      root: () -> state — the root search-node.
      evaluate: (state, best:int32) -> NodeEval — the fused per-node
        callback (see module docstring for the contract).
      payload_zero: () -> pytree — zero-initialized payload of the same
        structure/shape as ``NodeEval.payload`` (used to allocate incumbent
        buffers).
      num_instances: K — how many independent problem *instances* this
        problem multiplexes (the solver-service path).  Ordinary problems
        leave it at 1; a stacked problem (``repro.service.batch_problem``)
        sets K > 1, keeps a per-lane instance id inside its state, and the
        engine maintains a per-instance incumbent table of length K.
      instance_root: optional (inst:int32) -> state — per-instance root for
        K > 1 problems (CONVERTINDEX replay of a stolen task must start
        from the root of the task's OWN instance).  ``None`` means
        ``root()`` is instance-independent.
      evaluate_batch: optional (states, best) -> NodeEval over a LEADING
        lane axis — the fused-round fast path.  When set, the engine's
        vectorized step calls it ONCE per step with all W lanes' states
        (leaves [W, ...], best int32[W]) instead of ``vmap(evaluate)``,
        letting a kernel backend batch every lane into one launch
        (DESIGN.md §5.5).  MUST be bitwise-identical to
        ``vmap(evaluate)`` — the search tree may not depend on which
        path ran.  ``None`` falls back to ``vmap(evaluate)``.
    """

    name: str
    max_depth: int
    root: Callable[[], PyTree]
    evaluate: Callable[[PyTree, jnp.ndarray], NodeEval]
    payload_zero: Callable[[], PyTree]
    num_instances: int = 1
    instance_root: Optional[Callable[[jnp.ndarray], PyTree]] = None
    evaluate_batch: Optional[Callable[[PyTree, jnp.ndarray], NodeEval]] = None

    @classmethod
    def from_callbacks(cls, *, name: str, max_depth: int,
                       root: Callable[[], PyTree],
                       apply: Callable[[PyTree, jnp.ndarray], PyTree],
                       leaf_value: Callable[[PyTree], tuple],
                       lower_bound: Callable[[PyTree], jnp.ndarray],
                       solution_payload: Callable[[PyTree], PyTree],
                       payload_zero: Callable[[], PyTree]) -> "BinaryProblem":
        """Adapt a legacy three-callback problem to the fused protocol.

        The adapter simply calls every legacy callback inside one
        ``evaluate`` — correct but without intermediate sharing, so each
        node visit still pays ``leaf_value + lower_bound + 2×apply``.
        Problems on hot paths should implement ``evaluate`` natively.
        """

        def evaluate(state: PyTree, best: jnp.ndarray) -> NodeEval:
            is_sol, val = leaf_value(state)
            return NodeEval(
                is_solution=is_sol,
                value=val,
                lower_bound=lower_bound(state),
                left=apply(state, jnp.int32(0)),
                right=apply(state, jnp.int32(1)),
                payload=solution_payload(state),
            )

        return cls(name=name, max_depth=max_depth, root=root,
                   evaluate=evaluate, payload_zero=payload_zero)

    def apply(self, state: PyTree, bit: jnp.ndarray) -> PyTree:
        """Descend to the left (0) or right (1) child.

        Derived from ``evaluate``; the unused NodeEval fields are dead code
        under jit, so CONVERTINDEX replay costs one shared-intermediate pass
        per edge.
        """
        ev = self.evaluate(state, INF_VALUE)
        return tree_select(bit == 0, ev.left, ev.right)

    def arity(self, state: PyTree, best: jnp.ndarray) -> jnp.ndarray:
        """Number of children: 0 when leaf or pruned by bound, else 2.

        This composition is what the paper calls HasNextChild + the
        branch-and-reduce pruning rule: a child is generated only while the
        node can still beat the incumbent.
        """
        ev = self.evaluate(state, best)
        pruned = ev.lower_bound >= best
        return jnp.where(ev.is_solution | pruned, jnp.int32(0), jnp.int32(2))


def root_of(problem: BinaryProblem, inst: jnp.ndarray) -> PyTree:
    """Root of instance ``inst`` — `root()` for single-instance problems."""
    if problem.instance_root is not None:
        return problem.instance_root(inst)
    return problem.root()
