"""Vectorized recursive-backtracking engine (the paper's SERIAL-RB, SIMD-ified).

A *lane* is the TPU analogue of the paper's "core": an independent depth-first
searcher whose entire control state is the paper's ``current_idx`` array plus
a stack of search-node states along the live root-to-node path.  ``W`` lanes
advance in lockstep under ``vmap``; one *engine step* visits exactly one
search-node per active lane (one fused ``Problem.evaluate`` call — the unit
the paper's butterfly-effect analysis in §III-D counts).

Control encoding per lane (paper Fig. 2/3 semantics):

  idx[j] ∈ {UNVISITED, DELEGATED, LEFT, RIGHT} — the branch taken from depth
  ``j`` to ``j+1`` along the live path; LEFT means the right sibling at depth
  ``j+1`` is still pending, DELEGATED means it was stolen (skip on backtrack,
  Fig. 3 lines 2-3).

  depth       — current node's depth; its state is ``stack[depth]``.
  base        — the lane owns the subtree rooted at depth ``base`` (its "main
                task"); backtracking past it makes the lane idle.  Slots below
                ``base`` are the fixed path of the stolen task and are never
                donated (they belong to the chain of previous owners).

The incumbent (``best``) is shared across lanes every step — the vectorized
version of the paper's solution-broadcast notification messages.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import (DELEGATED, LEFT, RIGHT, UNVISITED, INF_VALUE,
                            BinaryProblem, root_of, tree_select)

PyTree = Any

#: ``Lanes.inst`` value for a lane not (yet) bound to any instance.  Such a
#: lane never steals and never donates; the service driver retargets it.
NO_INSTANCE = -1


class Lanes(NamedTuple):
    """State of W lanes on one device.  All leading dims are W unless noted.

    ``K = problem.num_instances`` instances are multiplexed over the lane
    pool: each lane serves exactly one instance (``inst``), the incumbent is
    a per-instance table, and stealing never crosses instances.  Ordinary
    single-instance problems have K = 1 and ``inst`` identically 0, which
    reduces every mechanism below to the paper's original semantics.
    """

    idx: jnp.ndarray          # int8  [W, IDX_LEN]
    depth: jnp.ndarray        # int32 [W]
    base: jnp.ndarray         # int32 [W]
    inst: jnp.ndarray         # int32 [W]   — instance the lane serves (or
                              #               NO_INSTANCE for unbound lanes)
    active: jnp.ndarray       # bool  [W]
    stack: PyTree             # leaves [W, STACK_LEN, ...]
    best: jnp.ndarray         # int32 [K]      — per-instance incumbent value
    best_payload: PyTree      # leaves [K, ...] — per-instance incumbent solution
    nodes: jnp.ndarray        # int32 [W]    — search-nodes visited
    t_s: jnp.ndarray          # int32 [W]    — tasks received (paper's T_S)
    t_r: jnp.ndarray          # int32 [W]    — task requests made (paper's T_R)
    donated: jnp.ndarray      # int32 [W]    — tasks donated
    t_c: jnp.ndarray          # int32 [W]    — tasks received CROSS-device
                              #               (a subset of t_s; telemetry
                              #               splits steal traffic by scope)
    steps: jnp.ndarray        # int32 []     — engine steps executed


def idx_len(problem: BinaryProblem) -> int:
    return problem.max_depth + 1


def stack_len(problem: BinaryProblem) -> int:
    return problem.max_depth + 2


def init_lanes(problem: BinaryProblem, num_lanes: int,
               seed_root: bool = True, bind_instance: bool = True) -> Lanes:
    """Allocate W idle lanes; optionally hand lane 0 the root task N_{0,0}.

    The paper's initialization assigns the root to C_0 and lets every other
    core request its first task through the virtual topology; here all other
    lanes start idle and are fed by the first steal rounds (bootstrap).

    ``bind_instance=False`` starts every lane UNBOUND (``inst ==
    NO_INSTANCE``): the multi-tenant service pool, where lanes only acquire
    an instance at admission/steal time and unbound lanes neither steal nor
    donate.
    """
    w, il, sl = num_lanes, idx_len(problem), stack_len(problem)
    k = problem.num_instances
    root = root_of(problem, jnp.int32(0))

    def alloc(leaf):
        buf = jnp.zeros((w, sl) + leaf.shape, leaf.dtype)
        if seed_root:
            buf = buf.at[0, 0].set(leaf)
        return buf

    stack = jax.tree_util.tree_map(alloc, root)
    active = jnp.zeros((w,), jnp.bool_)
    if seed_root:
        active = active.at[0].set(True)
    return Lanes(
        idx=jnp.full((w, il), UNVISITED, jnp.int8),
        depth=jnp.zeros((w,), jnp.int32),
        base=jnp.zeros((w,), jnp.int32),
        inst=(jnp.zeros((w,), jnp.int32) if bind_instance
              else jnp.full((w,), NO_INSTANCE, jnp.int32)),
        active=active,
        stack=stack,
        best=jnp.full((k,), INF_VALUE, jnp.int32),
        best_payload=jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (k,) + l.shape),
            problem.payload_zero()),
        nodes=jnp.zeros((w,), jnp.int32),
        t_s=jnp.zeros((w,), jnp.int32).at[0].set(1 if seed_root else 0),
        t_r=jnp.zeros((w,), jnp.int32),
        donated=jnp.zeros((w,), jnp.int32),
        t_c=jnp.zeros((w,), jnp.int32),
        steps=jnp.int32(0),
    )


def _select_node(idx, depth, stack):
    """Gather ONE lane's current node state off its stack (vmapped by
    ``make_step`` — the select half of the select/evaluate/advance split
    that lets ``evaluate_batch`` see all lanes in one call)."""
    il = idx.shape[0]
    d = jnp.clip(depth, 0, il - 1)
    state = jax.tree_util.tree_map(
        lambda s: jax.lax.dynamic_index_in_dim(s, d, keepdims=False), stack)
    return state, d


def _advance_lane(idx, depth, base, active, stack, best, ev, d):
    """Apply ONE lane's NodeEval: descend/backtrack and report
    (improved, value, payload) for incumbent election across lanes.

    Branchless: every path is computed and blended with ``where`` so the
    function vmaps over lanes with no divergence.  ``ev`` is the node's
    evaluation — produced per-lane by ``vmap(evaluate)`` or for all lanes
    at once by ``evaluate_batch`` (DESIGN.md §1/§5.5); either way exactly
    one evaluation backs one node visit.
    """
    il = idx.shape[0]
    c = idx[d]
    first = c == UNVISITED
    is_sol, val, lb = ev.is_solution, ev.value, ev.lower_bound

    improved = active & first & is_sol & (val < best)
    best_eff = jnp.where(improved, val, best)
    terminal = is_sol | (lb >= best_eff)

    # Which child to generate: left on first arrival, right after returning
    # from a completed left subtree.
    take_right = (~first) & (c == LEFT)
    descend = active & ((first & ~terminal) | take_right)
    child = tree_select(first, ev.left, ev.right)

    wpos = jnp.clip(d + 1, 0, il)  # stack has one extra slot
    new_stack = jax.tree_util.tree_map(
        lambda s, ch: jax.lax.dynamic_update_index_in_dim(
            s,
            jnp.where(descend, ch,
                      jax.lax.dynamic_index_in_dim(s, wpos, keepdims=False)),
            wpos, axis=0),
        stack, child)

    # current_idx maintenance (paper Fig. 3, line 4).
    slot_now = jnp.where(descend & first, LEFT,
                         jnp.where(descend & take_right, RIGHT, c))
    new_idx = idx.at[d].set(jnp.where(active, slot_now, c))
    # Fresh child slot starts UNVISITED.
    child_slot = jnp.where(descend, UNVISITED, new_idx[jnp.clip(d + 1, 0, il - 1)])
    new_idx = new_idx.at[jnp.clip(d + 1, 0, il - 1)].set(child_slot)

    new_depth = jnp.where(active, jnp.where(descend, depth + 1, depth - 1), depth)
    new_active = active & (new_depth >= base)
    new_depth = jnp.maximum(new_depth, 0)

    visited = active & first
    return (new_idx, new_depth, new_active, new_stack, visited,
            improved, jnp.where(improved, val, INF_VALUE), ev.payload)


def make_step(problem: BinaryProblem):
    """Build the vectorized one-step transition Lanes -> Lanes.

    The step is select → evaluate → advance: node states are gathered per
    lane, evaluated — through ``problem.evaluate_batch`` as ONE batched
    call when the problem provides it, else ``vmap(evaluate)`` — and the
    results applied per lane.  Both evaluation paths are bitwise-identical
    by the ``evaluate_batch`` contract, so the search tree is invariant.
    """

    select_v = jax.vmap(_select_node)
    advance_v = jax.vmap(_advance_lane)
    if problem.evaluate_batch is not None:
        eval_all = problem.evaluate_batch
    else:
        eval_all = jax.vmap(problem.evaluate)

    def step(lanes: Lanes) -> Lanes:
        w = lanes.active.shape[0]
        k = lanes.best.shape[0]
        safe_inst = jnp.clip(lanes.inst, 0, k - 1)
        # Each lane prunes against ITS instance's incumbent.
        best_per_lane = lanes.best[safe_inst]
        states, d = select_v(lanes.idx, lanes.depth, lanes.stack)
        evs = eval_all(states, best_per_lane)
        (idx, depth, active, stack, visited, improved, vals,
         payloads) = advance_v(lanes.idx, lanes.depth, lanes.base,
                               lanes.active, lanes.stack, best_per_lane,
                               evs, d)
        # Incumbent election per instance (the paper's broadcast, free
        # here): segment-min of the improved values over ``inst``, then the
        # lowest-id winning lane supplies the payload for its instance.
        seg = jnp.full((k,), INF_VALUE, jnp.int32).at[safe_inst].min(vals)
        any_improved = seg < lanes.best
        new_best = jnp.minimum(lanes.best, seg)
        lane_ids = jnp.arange(w, dtype=jnp.int32)
        winner = jnp.full((k,), w, jnp.int32).at[safe_inst].min(
            jnp.where(improved & (vals == seg[safe_inst]), lane_ids, w))
        safe_winner = jnp.clip(winner, 0, w - 1)

        def elect(p, old):
            upd = any_improved.reshape((k,) + (1,) * (old.ndim - 1))
            return jnp.where(upd, p[safe_winner], old)

        new_payload = jax.tree_util.tree_map(elect, payloads,
                                             lanes.best_payload)
        return lanes._replace(
            idx=idx, depth=depth, active=active, stack=stack,
            best=new_best, best_payload=new_payload,
            nodes=lanes.nodes + visited.astype(jnp.int32),
            steps=lanes.steps + 1)

    return step


def make_expand(problem: BinaryProblem, num_steps: int,
                fused_steps: int = 1):
    """Run up to ``num_steps`` engine steps, early-exiting when all idle.

    This is the compute phase between steal rounds; ``num_steps`` is the
    round granularity R (the BSP analogue of the paper's disruption-time
    knob, hillclimbed in EXPERIMENTS.md §Perf).

    ``fused_steps`` = S > 1 fuses S step applications into each while-loop
    iteration (an unrolled ``fori_loop`` group), amortizing the loop's
    carry bookkeeping and dispatch across S node visits per launch.  Each
    fused sub-step is guarded by the exact original loop condition
    (``any(active) & step_index < num_steps``), so the sequence of actual
    ``step`` applications — and therefore the search tree, node counts and
    step counter — is IDENTICAL for every S.
    """
    step = make_step(problem)

    if fused_steps <= 1:
        def expand(lanes: Lanes) -> Lanes:
            def cond(carry):
                i, lanes = carry
                return (i < num_steps) & jnp.any(lanes.active)

            def body(carry):
                i, lanes = carry
                return i + 1, step(lanes)

            _, lanes = jax.lax.while_loop(cond, body, (jnp.int32(0), lanes))
            return lanes

        return expand

    s = int(fused_steps)

    def expand(lanes: Lanes) -> Lanes:
        def cond(carry):
            i, ln = carry
            return (i < num_steps) & jnp.any(ln.active)

        def body(carry):
            i, ln = carry

            def one(j, ln):
                run = jnp.any(ln.active) & (i + j < num_steps)
                return jax.lax.cond(run, step, lambda l: l, ln)

            return i + s, jax.lax.fori_loop(0, s, one, ln)

        _, lanes = jax.lax.while_loop(cond, body, (jnp.int32(0), lanes))
        return lanes

    return expand


def replay_path(problem: BinaryProblem, bits: jnp.ndarray,
                path_depth: jnp.ndarray, stack: PyTree,
                inst: jnp.ndarray = jnp.int32(0)) -> PyTree:
    """CONVERTINDEX: rebuild the state stack for a task index (paper §IV-A).

    Starting from the root of instance ``inst`` (plain ``root()`` for
    single-instance problems), re-applies the branch decisions ``bits[0..
    path_depth-1]`` (delegation marks already flattened to LEFT by
    FIXINDEX).  Fills ``stack[j]`` for j = 0..path_depth and returns the new
    stack.  The cost is O(D_MAX) child derivations (``Problem.apply``, i.e.
    ``evaluate`` with the non-child outputs dead-code-eliminated) — the
    paper's serial-overhead term, incurred once per received task.
    """
    il = bits.shape[0]
    root = root_of(problem, inst)
    stack = jax.tree_util.tree_map(
        lambda s, r: jax.lax.dynamic_update_index_in_dim(s, r, 0, axis=0),
        stack, root)

    def body(j, carry):
        state, stack = carry
        bit = jnp.clip(bits[j].astype(jnp.int32), 0, 1)
        nxt = problem.apply(state, bit)
        take = j < path_depth
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(take, b, a), state, nxt)
        stack = jax.tree_util.tree_map(
            lambda s, st: jax.lax.dynamic_update_index_in_dim(
                s, jnp.where(take, st,
                             jax.lax.dynamic_index_in_dim(s, jnp.clip(j + 1, 0, s.shape[0] - 1), keepdims=False)),
                jnp.clip(j + 1, 0, s.shape[0] - 1), axis=0),
            stack, state)
        return state, stack

    _, stack = jax.lax.fori_loop(0, il, body, (root, stack))
    return stack
