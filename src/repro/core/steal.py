"""Heaviest-task work stealing between lanes on one device (paper §IV-A/B).

Every steal round, idle lanes (*thieves*) are matched with active lanes that
have an open right-branch (*donors*).  Donor priority is the paper's implicit
weight: the lane whose shallowest open slot is closest to the root donates
first (w = 1/(d+1)).  Extraction is GETHEAVIESTTASKINDEX (mark DELEGATED,
ship the prefix) and installation is FIXINDEX + CONVERTINDEX (replay).

The donor→thief pairing is a deterministic ranked matching — the
bulk-synchronous closed form of the paper's virtual-topology heuristic
("request from the core expected to hold the heaviest task"): sorting donors
by weight and pairing them with thieves in rank order is exactly what the
GETPARENT tree converges to, computed in one argsort instead of message
probing.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.api import RIGHT, UNVISITED, BinaryProblem
from repro.core.engine import Lanes, replay_path
from repro.core.indexing import extract_task, heaviest_open_slot


def donor_slots(lanes: Lanes) -> jnp.ndarray:
    """Per-lane shallowest open slot (IDX_LEN = no donatable work)."""
    return jax.vmap(heaviest_open_slot)(lanes.idx, lanes.base, lanes.depth)


def extract_tasks(lanes: Lanes, num: jnp.ndarray, max_tasks: int
                  ) -> Tuple[Lanes, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Extract up to ``num`` (<= max_tasks) heaviest tasks from this device.

    Returns (lanes', bits[max_tasks, IDX_LEN], task_depth[max_tasks],
    valid[max_tasks]).  Tasks are extracted from distinct lanes in weight
    order (shallowest open slot first, lane id tiebreak).  Donor lanes get
    their slot marked DELEGATED and ``donated`` incremented.
    """
    w, il = lanes.idx.shape
    slots = donor_slots(lanes)
    can = lanes.active & (slots < il)
    # Rank donors: primary = slot depth (weight), secondary = lane id.
    key = jnp.where(can, slots * w + jnp.arange(w, dtype=jnp.int32),
                    jnp.int32(il * w + w))
    order = jnp.argsort(key)                       # donor lanes, best first
    rank = jnp.argsort(order)                      # lane -> its donor rank
    is_donor = can & (rank < num)

    new_idx_all, bits_all = jax.vmap(extract_task)(lanes.idx, slots)
    new_idx = jnp.where(is_donor[:, None], new_idx_all, lanes.idx)
    lanes = lanes._replace(
        idx=new_idx, donated=lanes.donated + is_donor.astype(jnp.int32))

    # Gather the first ``max_tasks`` donors' payloads in rank order.
    sel = order[:max_tasks]
    bits = bits_all[sel]
    tdepth = slots[sel] + 1
    valid = is_donor[sel]
    bits = jnp.where(valid[:, None], bits, UNVISITED)
    return lanes, bits.astype(jnp.int8), tdepth, valid


def install_tasks(problem: BinaryProblem, lanes: Lanes, bits: jnp.ndarray,
                  tdepth: jnp.ndarray, valid: jnp.ndarray) -> Lanes:
    """Give tasks to idle lanes (FIXINDEX was applied at extraction).

    The k-th valid task goes to the k-th idle lane.  Receiving lanes replay
    the index through ``Problem.apply`` (CONVERTINDEX) to rebuild their state
    stack, then resume as owners of the stolen subtree (base = task depth).
    """
    w, il = lanes.idx.shape
    n_tasks = bits.shape[0]
    thief = ~lanes.active
    tkey = jnp.where(thief, jnp.arange(w, dtype=jnp.int32), jnp.int32(w))
    torder = jnp.argsort(tkey)
    trank = jnp.argsort(torder)                    # lane -> thief rank
    gets = thief & (trank < n_tasks)
    src = jnp.clip(trank, 0, n_tasks - 1)
    my_bits = bits[src]
    my_depth = tdepth[src]
    my_valid = valid[src] & gets

    # CONVERTINDEX replay for receiving lanes (vectorized, masked).
    replay = jax.vmap(functools.partial(replay_path, problem))
    new_stack = replay(my_bits, my_depth, lanes.stack)
    stack = jax.tree_util.tree_map(
        lambda new, old: jnp.where(
            my_valid.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
        new_stack, lanes.stack)

    idx = jnp.where(my_valid[:, None], my_bits, lanes.idx)
    return lanes._replace(
        idx=idx,
        depth=jnp.where(my_valid, my_depth, lanes.depth),
        base=jnp.where(my_valid, my_depth, lanes.base),
        active=lanes.active | my_valid,
        stack=stack,
        t_s=lanes.t_s + my_valid.astype(jnp.int32),
    )


def balance_device(problem: BinaryProblem, lanes: Lanes) -> Lanes:
    """One intra-device steal round: match idle lanes with heaviest donors."""
    w = lanes.idx.shape[0]
    idle = ~lanes.active
    demand = jnp.sum(idle.astype(jnp.int32))
    # Every idle lane "requests" this round (paper's T_R accounting).
    lanes = lanes._replace(t_r=lanes.t_r + idle.astype(jnp.int32))
    lanes, bits, tdepth, valid = extract_tasks(lanes, demand, max_tasks=w)
    return install_tasks(problem, lanes, bits, tdepth, valid)
