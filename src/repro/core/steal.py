"""Heaviest-task work stealing between lanes on one device (paper §IV-A/B).

Every steal round, idle lanes (*thieves*) are matched with active lanes that
have an open right-branch (*donors*).  Donor priority is the paper's implicit
weight: the lane whose shallowest open slot is closest to the root donates
first (w = 1/(d+1)).  Extraction is GETHEAVIESTTASKINDEX (mark DELEGATED,
ship the prefix) and installation is FIXINDEX + CONVERTINDEX (replay).

The donor→thief pairing is a deterministic ranked matching — the
bulk-synchronous closed form of the paper's virtual-topology heuristic
("request from the core expected to hold the heaviest task"): sorting donors
by weight and pairing them with thieves in rank order is exactly what the
GETPARENT tree converges to, computed in one argsort instead of message
probing.

Instance scoping (the solver-service invariant).  With K > 1 instances
multiplexed over the lane pool, the matching is keyed by ``(inst, slot,
lane)``: a thief is paired only with a donor of the SAME instance, so one
tenant's starvation never leaks work (or search-tree nodes) from another.
Lanes with ``inst == NO_INSTANCE`` neither steal nor donate.  With K = 1
every lane has inst 0 and the matching degenerates to the original global
ranked matching.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.api import UNVISITED, BinaryProblem
from repro.core.engine import Lanes, replay_path
from repro.core.indexing import extract_task, heaviest_open_slot


def donor_slots(lanes: Lanes) -> jnp.ndarray:
    """Per-lane shallowest open slot (IDX_LEN = no donatable work)."""
    return jax.vmap(heaviest_open_slot)(lanes.idx, lanes.base, lanes.depth)


def donor_mask(lanes: Lanes, slots: jnp.ndarray) -> jnp.ndarray:
    """Lanes that could donate: active, bound to an instance, open slot."""
    il = lanes.idx.shape[1]
    return lanes.active & (lanes.inst >= 0) & (slots < il)


def thief_mask(lanes: Lanes) -> jnp.ndarray:
    """Lanes that may receive work: idle but bound to an instance."""
    return (~lanes.active) & (lanes.inst >= 0)


def _rank_within_instance(member: jnp.ndarray, key: jnp.ndarray,
                          inst: jnp.ndarray) -> jnp.ndarray:
    """Rank of each member lane among same-instance members, by ``key``.

    O(W^2) boolean reduction — W is a per-device lane count (tens to a few
    hundred), so the [W, W] mask is tiny next to the lane stacks.
    """
    same = inst[:, None] == inst[None, :]
    better = member[None, :] & same & (key[None, :] < key[:, None])
    return jnp.sum(better.astype(jnp.int32), axis=1)


def match_thieves_to_donors(lanes: Lanes, slots: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Instance-scoped ranked matching.

    Returns (src, matched, is_donor): per-lane donor lane id each thief
    draws from (arbitrary where not matched), the per-lane "this thief got
    a task" mask, and the per-lane "this donor was drained" mask.  The
    matching pairs the r-th thief of instance i (lane-id order) with the
    r-th donor of instance i (heaviest-first: slot depth, lane-id
    tiebreak) — for K = 1 this is exactly the original global matching.
    """
    w = lanes.idx.shape[0]
    lane_ids = jnp.arange(w, dtype=jnp.int32)
    donors = donor_mask(lanes, slots)
    thieves = thief_mask(lanes)
    dkey = slots * w + lane_ids                    # weight-major, lane tiebreak
    drank = _rank_within_instance(donors, dkey, lanes.inst)
    trank = _rank_within_instance(thieves, lane_ids, lanes.inst)
    same = lanes.inst[:, None] == lanes.inst[None, :]
    pair = (thieves[:, None] & donors[None, :] & same
            & (trank[:, None] == drank[None, :]))
    src = jnp.argmax(pair, axis=1).astype(jnp.int32)
    matched = jnp.any(pair, axis=1)
    is_donor = jnp.any(pair, axis=0)
    return src, matched, is_donor


def extract_tasks(lanes: Lanes, quota: jnp.ndarray, max_tasks: int
                  ) -> Tuple[Lanes, jnp.ndarray, jnp.ndarray, jnp.ndarray,
                             jnp.ndarray, jnp.ndarray]:
    """Extract the per-instance ``quota[i]`` heaviest tasks of each instance.

    ``quota`` is int32[K].  Returns (lanes', bits[max_tasks, IDX_LEN],
    task_depth[max_tasks], task_inst[max_tasks], task_rank[max_tasks],
    valid[max_tasks]).  Tasks are extracted from distinct lanes in
    (instance, weight) order; ``task_rank`` is the task's rank WITHIN its
    instance on this device (the cross-device claim key).  Donor lanes get
    their slot marked DELEGATED and ``donated`` incremented.
    """
    w, il = lanes.idx.shape
    k = quota.shape[0]
    lane_ids = jnp.arange(w, dtype=jnp.int32)
    slots = donor_slots(lanes)
    can = donor_mask(lanes, slots)
    dkey = slots * w + lane_ids
    drank = _rank_within_instance(can, dkey, lanes.inst)
    safe_inst = jnp.clip(lanes.inst, 0, k - 1)
    is_donor = can & (drank < quota[safe_inst])

    new_idx_all, bits_all = jax.vmap(extract_task)(lanes.idx, slots)
    new_idx = jnp.where(is_donor[:, None], new_idx_all, lanes.idx)
    lanes = lanes._replace(
        idx=new_idx, donated=lanes.donated + is_donor.astype(jnp.int32))

    # Ship rows in (instance, weight) order: instance-major key sort.
    key = jnp.where(is_donor, safe_inst * (il * w) + dkey,
                    jnp.int32(k * il * w + w))
    order = jnp.argsort(key)
    sel = order[:max_tasks]
    valid = is_donor[sel]
    bits = jnp.where(valid[:, None], bits_all[sel], UNVISITED)
    tdepth = jnp.where(valid, slots[sel] + 1, 0)
    tinst = jnp.where(valid, safe_inst[sel], 0)
    trank = jnp.where(valid, drank[sel], 0)
    return lanes, bits.astype(jnp.int8), tdepth, tinst, trank, valid


def claim_tasks(thieves: jnp.ndarray, inst: jnp.ndarray,
                my_grank: jnp.ndarray, w_inst: jnp.ndarray,
                w_grank: jnp.ndarray, w_valid: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-instance rank-arithmetic claim (cross-device step 4).

    ``thieves``/``inst``/``my_grank`` describe the local lanes (bool[W],
    int32[W], int32[W]); ``w_inst``/``w_grank``/``w_valid`` describe the
    gathered world task rows ([D*S]).  Returns ``(src, claim)``: the world
    row each lane claims (arbitrary where unclaimed) and the claim mask.

    Invariant (the PR-1 bug class, property-tested in
    ``tests/test_steal_quota.py``): when ``(inst, grank)`` is unique among
    valid rows and among thieves — which the quota construction guarantees
    — claims form a bijection between matching rows and thieves, and a
    thief only ever claims a row of its own instance.
    """
    pair = (thieves[:, None] & w_valid[None, :]
            & (w_inst[None, :] == inst[:, None])
            & (w_grank[None, :] == my_grank[:, None]))       # [W, D*S]
    src = jnp.argmax(pair, axis=1)
    claim = jnp.any(pair, axis=1)
    return src, claim


def install_tasks(problem: BinaryProblem, lanes: Lanes, bits: jnp.ndarray,
                  tdepth: jnp.ndarray, tinst: jnp.ndarray,
                  valid: jnp.ndarray, cross: bool = False) -> Lanes:
    """Install per-LANE task rows (FIXINDEX was applied at extraction).

    Row ``i`` goes to lane ``i`` — callers route tasks to specific thief
    lanes (``valid`` gates installation; it must only be set on idle
    lanes).  Receiving lanes replay the index through ``Problem.apply``
    (CONVERTINDEX) from the root of the task's instance to rebuild their
    state stack, then resume as owners of the stolen subtree (base = task
    depth).  ``cross`` (a static flag, True from ``cross_device_steal``)
    additionally bumps the receiver's ``t_c`` counter so telemetry can
    split steal traffic into intra- vs cross-device scope.
    """
    my_valid = valid & ~lanes.active

    # CONVERTINDEX replay for receiving lanes (vectorized, masked).
    replay = jax.vmap(functools.partial(replay_path, problem))
    new_stack = replay(bits, tdepth, lanes.stack, tinst)
    stack = jax.tree_util.tree_map(
        lambda new, old: jnp.where(
            my_valid.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
        new_stack, lanes.stack)

    idx = jnp.where(my_valid[:, None], bits, lanes.idx)
    recv = my_valid.astype(jnp.int32)
    return lanes._replace(
        idx=idx,
        depth=jnp.where(my_valid, tdepth, lanes.depth),
        base=jnp.where(my_valid, tdepth, lanes.base),
        inst=jnp.where(my_valid, tinst, lanes.inst),
        active=lanes.active | my_valid,
        stack=stack,
        t_s=lanes.t_s + recv,
        t_c=lanes.t_c + recv if cross else lanes.t_c,
    )


def balance_device(problem: BinaryProblem, lanes: Lanes) -> Lanes:
    """One intra-device steal round: same-instance thief/donor matching."""
    slots = donor_slots(lanes)
    thieves = thief_mask(lanes)
    # Every bound idle lane "requests" this round (paper's T_R accounting).
    lanes = lanes._replace(t_r=lanes.t_r + thieves.astype(jnp.int32))
    src, matched, is_donor = match_thieves_to_donors(lanes, slots)

    new_idx_all, bits_all = jax.vmap(extract_task)(lanes.idx, slots)
    lanes = lanes._replace(
        idx=jnp.where(is_donor[:, None], new_idx_all, lanes.idx),
        donated=lanes.donated + is_donor.astype(jnp.int32))

    bits = jnp.where(matched[:, None], bits_all[src], UNVISITED).astype(
        jnp.int8)
    tdepth = jnp.where(matched, slots[src] + 1, 0)
    tinst = jnp.where(matched, lanes.inst[src], 0)
    return install_tasks(problem, lanes, bits, tdepth, tinst, matched)
