"""Checkpoint / restart for the solver (paper §VII, made first-class).

The paper observes that under indexed search trees, checkpointing is
"reasonably straightforward ... by forcing every core to write its
current_idx to some file".  We implement exactly that, plus the elastic
half the paper only gestures at (join-leave):

* ``save`` — persist every lane's ``(idx, depth, base, inst, active)`` plus
  the per-instance incumbent table to a single ``.npz``.  The *entire*
  solver state is O(W · D_MAX) int8 — the compact-encoding payoff again;
  stacks are NOT saved, they are reconstructed by CONVERTINDEX replay on
  restore.  ``extra`` lets callers (the solver service) ride metadata
  arrays in the same atomic file; non-array host metadata (the service's
  queued-request heap and ticket states) rides as JSON bytes via
  ``pack_json``/``unpack_json``.

* ``restore`` — rebuild ``Lanes`` for an arbitrary new lane count W'
  (elastic shrink/grow).  The first W' active tasks are installed directly;
  any surplus is returned as a host-side *pending pool* the driver feeds to
  idle lanes at round boundaries (``repro.core.distributed.solve`` and
  ``repro.service.driver`` consume it).  Nothing is ever lost or explored
  twice: an installed lane resumes from its exact ``current_idx``
  (delegation marks intact), and pool entries are unmodified lane images —
  each tagged with its instance, so multi-tenant restores keep tenant
  isolation.
"""

from __future__ import annotations

import io
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import UNVISITED, INF_VALUE, BinaryProblem
from repro.core.engine import Lanes, init_lanes, replay_path

_EXTRA_PREFIX = "extra_"


def save(path: str, lanes: Lanes,
         extra: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Atomically persist lane control state + incumbents (not the stacks).

    ``extra`` arrays are stored under an ``extra_`` prefix and returned by
    :func:`read_extra` — the service driver uses this for its slot tables.
    """
    payload_leaves, _ = jax.tree_util.tree_flatten(lanes.best_payload)
    arrays = {
        "idx": np.asarray(lanes.idx, dtype=np.int8),
        "depth": np.asarray(lanes.depth, dtype=np.int32),
        "base": np.asarray(lanes.base, dtype=np.int32),
        "inst": np.asarray(lanes.inst, dtype=np.int32),
        "active": np.asarray(lanes.active),
        "best": np.asarray(lanes.best, dtype=np.int32),
        "nodes": np.asarray(lanes.nodes, dtype=np.int32),
        "t_s": np.asarray(lanes.t_s, dtype=np.int32),
        "t_r": np.asarray(lanes.t_r, dtype=np.int32),
        "donated": np.asarray(lanes.donated, dtype=np.int32),
        "t_c": np.asarray(lanes.t_c, dtype=np.int32),
        "steps": np.asarray(lanes.steps, dtype=np.int32),
    }
    for i, leaf in enumerate(payload_leaves):
        arrays[f"payload_{i}"] = np.asarray(leaf)
    for key, val in (extra or {}).items():
        arrays[_EXTRA_PREFIX + key] = np.asarray(val)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    data = buf.getvalue()
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)          # atomic on POSIX: no torn checkpoints
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def read_extra(path: str) -> Dict[str, np.ndarray]:
    """Read back the ``extra`` arrays stored by :func:`save`."""
    out = {}
    with np.load(path) as z:
        for key in z.files:
            if key.startswith(_EXTRA_PREFIX):
                out[key[len(_EXTRA_PREFIX):]] = z[key]
    return out


def pack_json(obj: Any) -> np.ndarray:
    """Encode a JSON-serializable object as a uint8 array.

    Checkpoints are single ``.npz`` files written without pickling;
    structured host metadata that is not naturally an array (the service's
    queued-request heap and ticket states) rides as UTF-8 JSON bytes in an
    ordinary ``extra`` array instead.  Inverse: :func:`unpack_json`.
    """
    return np.frombuffer(json.dumps(obj).encode("utf-8"), np.uint8).copy()


def unpack_json(arr: np.ndarray) -> Any:
    """Decode an array written by :func:`pack_json`."""
    return json.loads(np.asarray(arr, np.uint8).tobytes().decode("utf-8"))


class PendingTask:
    """A not-yet-installed lane image (elastic surplus), instance-tagged."""

    __slots__ = ("idx", "depth", "base", "inst")

    def __init__(self, idx: np.ndarray, depth: int, base: int, inst: int = 0):
        self.idx, self.depth, self.base, self.inst = idx, depth, base, inst


def restore(path: str, problem: BinaryProblem, num_lanes: int
            ) -> Tuple[Lanes, List[PendingTask]]:
    """Rebuild Lanes for ``num_lanes`` (elastic) + surplus pending pool."""
    with np.load(path) as z:
        idx = z["idx"]
        depth, base, active = z["depth"], z["base"], z["active"]
        inst = (z["inst"] if "inst" in z
                else np.zeros(idx.shape[0], np.int32))
        best = np.atleast_1d(np.asarray(z["best"], np.int32))
        payload_leaves = []
        i = 0
        while f"payload_{i}" in z:
            payload_leaves.append(z[f"payload_{i}"])
            i += 1
        # t_c is absent from pre-telemetry checkpoints: carry what exists.
        stats = {k: z[k] for k in ("nodes", "t_s", "t_r", "donated", "t_c")
                 if k in z}
        steps = int(z["steps"])

    lanes = init_lanes(problem, num_lanes, seed_root=False)
    if best.shape[0] != problem.num_instances:
        raise ValueError(
            f"checkpoint has {best.shape[0]} instance slots, problem has "
            f"{problem.num_instances}; elastic restore varies LANES, not K")
    proto = jax.tree_util.tree_structure(lanes.best_payload)
    payload = (jax.tree_util.tree_unflatten(
        proto, [jnp.asarray(l) for l in payload_leaves])
        if payload_leaves else lanes.best_payload)

    live = [k for k in range(idx.shape[0]) if active[k]]
    installed, pending = live[:num_lanes], live[num_lanes:]

    il = lanes.idx.shape[1]
    new_idx = np.full((num_lanes, il), int(UNVISITED), np.int8)
    new_depth = np.zeros((num_lanes,), np.int32)
    new_base = np.zeros((num_lanes,), np.int32)
    new_inst = np.zeros((num_lanes,), np.int32)
    new_active = np.zeros((num_lanes,), bool)
    for j, k in enumerate(installed):
        w = min(il, idx.shape[1])
        new_idx[j, :w] = idx[k, :w]
        new_depth[j], new_base[j] = depth[k], base[k]
        new_inst[j], new_active[j] = inst[k], True

    lanes = lanes._replace(
        idx=jnp.asarray(new_idx), depth=jnp.asarray(new_depth),
        base=jnp.asarray(new_base), inst=jnp.asarray(new_inst),
        active=jnp.asarray(new_active),
        best=jnp.asarray(best), best_payload=payload,
        steps=jnp.int32(steps))
    lanes = rebuild_stacks(problem, lanes)

    # Aggregate stats are carried on lane 0 so totals survive re-sharding.
    carry = {k: int(v.sum()) for k, v in stats.items()}
    lanes = lanes._replace(
        nodes=lanes.nodes.at[0].add(carry["nodes"]),
        t_s=lanes.t_s.at[0].add(carry["t_s"]),
        t_r=lanes.t_r.at[0].add(carry["t_r"]),
        donated=lanes.donated.at[0].add(carry["donated"]),
        t_c=lanes.t_c.at[0].add(carry.get("t_c", 0)))

    pool = [PendingTask(idx[k].copy(), int(depth[k]), int(base[k]),
                        int(inst[k]))
            for k in pending]
    return lanes, pool


def repartition(problem: BinaryProblem, lanes: Lanes, num_lanes: int
                ) -> Tuple[Lanes, List[PendingTask]]:
    """In-memory elastic W → W' re-layout (the checkpoint/restore cycle
    without the file): the first W' live tasks are installed onto fresh
    lanes, surplus becomes an instance-tagged pending pool, and aggregate
    stats are carried on lane 0 — exactly :func:`restore`'s contract.  The
    service's autoscaling hook uses this to add/remove devices mid-run.

    ``lanes`` must be host-addressable (gather before calling under a
    mesh); unbound idle lanes (inst == NO_INSTANCE) are dropped — idle
    lanes of the new pool start unbound.
    """
    idx = np.asarray(lanes.idx)
    depth = np.asarray(lanes.depth)
    base = np.asarray(lanes.base)
    inst = np.asarray(lanes.inst)
    active = np.asarray(lanes.active)
    stats = {k: int(np.asarray(getattr(lanes, k)).sum())
             for k in ("nodes", "t_s", "t_r", "donated", "t_c")}

    new = init_lanes(problem, num_lanes, seed_root=False)
    new = new._replace(
        inst=jnp.full((num_lanes,), -1, jnp.int32),
        best=jnp.asarray(np.asarray(lanes.best)),
        best_payload=jax.tree_util.tree_map(
            lambda p: jnp.asarray(np.asarray(p)), lanes.best_payload),
        steps=jnp.asarray(np.asarray(lanes.steps)))

    live = [k for k in range(idx.shape[0]) if active[k]]
    installed, pending = live[:num_lanes], live[num_lanes:]

    il = new.idx.shape[1]
    new_idx = np.full((num_lanes, il), int(UNVISITED), np.int8)
    new_depth = np.zeros((num_lanes,), np.int32)
    new_base = np.zeros((num_lanes,), np.int32)
    new_inst = np.full((num_lanes,), -1, np.int32)
    new_active = np.zeros((num_lanes,), bool)
    for j, k in enumerate(installed):
        w = min(il, idx.shape[1])
        new_idx[j, :w] = idx[k, :w]
        new_depth[j], new_base[j] = depth[k], base[k]
        new_inst[j], new_active[j] = inst[k], True
    new = new._replace(
        idx=jnp.asarray(new_idx), depth=jnp.asarray(new_depth),
        base=jnp.asarray(new_base), inst=jnp.asarray(new_inst),
        active=jnp.asarray(new_active))
    new = rebuild_stacks(problem, new)
    new = new._replace(
        nodes=new.nodes.at[0].add(stats["nodes"]),
        t_s=new.t_s.at[0].add(stats["t_s"]),
        t_r=new.t_r.at[0].add(stats["t_r"]),
        donated=new.donated.at[0].add(stats["donated"]),
        t_c=new.t_c.at[0].add(stats["t_c"]))
    pool = [PendingTask(idx[k].copy(), int(depth[k]), int(base[k]),
                        int(inst[k]))
            for k in pending]
    return new, pool


def rebuild_stacks(problem: BinaryProblem, lanes: Lanes) -> Lanes:
    """CONVERTINDEX for every active lane: replay path bits to its node.

    The path to a lane's *current node* is ``idx[0..depth-1]`` with
    delegation marks flattened to the branch actually taken (DELEGATED means
    the donor went left).  Replay starts from the root of the lane's OWN
    instance.  O(W · D_MAX) applies — paid once per restore.
    """
    bits = jnp.where(lanes.idx < 0, jnp.int8(0), lanes.idx)
    k = lanes.best.shape[0]
    safe_inst = jnp.clip(lanes.inst, 0, k - 1)
    stacks = jax.vmap(
        lambda b, d, s, i: replay_path(problem, b, d, s, i)
    )(bits, lanes.depth, lanes.stack, safe_inst)
    keep = lanes.active
    stack = jax.tree_util.tree_map(
        lambda new, old: jnp.where(
            keep.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
        stacks, lanes.stack)
    return lanes._replace(stack=stack)


def install_pending(problem: BinaryProblem, lanes: Lanes,
                    pool: List[PendingTask]) -> Tuple[Lanes, List[PendingTask]]:
    """Feed pending pool entries to idle lanes (driver, round boundaries)."""
    if not pool:
        return lanes, pool
    active = np.asarray(lanes.active)
    idle = [i for i in range(active.shape[0]) if not active[i]]
    n = min(len(idle), len(pool))
    if n == 0:
        return lanes, pool
    il = lanes.idx.shape[1]
    idxs = np.asarray(lanes.idx).copy()
    depth = np.asarray(lanes.depth).copy()
    base = np.asarray(lanes.base).copy()
    inst = np.asarray(lanes.inst).copy()
    act = active.copy()
    t_s = np.asarray(lanes.t_s).copy()
    for lane, task in zip(idle[:n], pool[:n]):
        w = min(il, task.idx.shape[0])
        idxs[lane, :w] = task.idx[:w]
        depth[lane], base[lane], act[lane] = task.depth, task.base, True
        inst[lane] = task.inst
        t_s[lane] += 1
    lanes = lanes._replace(
        idx=jnp.asarray(idxs), depth=jnp.asarray(depth),
        base=jnp.asarray(base), inst=jnp.asarray(inst),
        active=jnp.asarray(act), t_s=jnp.asarray(t_s))
    return rebuild_stacks(problem, lanes), pool[n:]
