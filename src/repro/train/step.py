"""Training step factory: microbatched grad accumulation + AdamW.

``make_train_step(cfg, mesh)`` returns a jit-able
``step(params, opt, batch, stepno) -> (params, opt, metrics)`` where

* the global batch is split into ``cfg.microbatches`` microbatches scanned
  with f32 grad accumulation (sharded like the params — ZeRO);
* each microbatch forward/backward runs under the arch's remat policy;
* params are f32 masters, cast to the declared compute dtype (bf16) at use.

The same factory serves the dry-run (lowered with abstract inputs, explicit
in/out shardings) and the real CPU training example.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.models.params import abstract_params, is_decl
from repro.train.optim import AdamState, adamw_update, cosine_lr

PyTree = Any


def cast_to_compute(cfg: ArchConfig, params: PyTree) -> PyTree:
    """Cast f32 master params to their declared (compute) dtypes."""
    decls = M.param_decls(cfg)
    ab = abstract_params(decls)
    return jax.tree_util.tree_map(
        lambda p, a: p.astype(a.dtype), params, ab)


def master_params(cfg: ArchConfig, params: PyTree) -> PyTree:
    """Promote compute-dtype params to f32 masters (training storage)."""
    return jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32) if jnp.issubdtype(
            p.dtype, jnp.floating) else p, params)


def make_loss(cfg: ArchConfig, sh: M.Shardings,
              skip_masked_blocks: bool = False,
              block_q: int = 256, block_k: int = 256):
    """Loss over COMPUTE-dtype params.  The f32->bf16 master cast happens
    once per step in the caller (outside the microbatch loop): casting
    inside would make the ZeRO all-gathers move f32 masters — 2x the
    collective bytes and an extra f32 weight copy resident per layer."""
    def loss(cparams, batch):
        ctx = M.make_ctx(cfg, "train", sh,
                         skip_masked_blocks=skip_masked_blocks,
                         block_q=block_q, block_k=block_k)
        return M.loss_fn(cfg, cparams, batch, ctx)
    return loss


def make_train_step(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                    lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000,
                    microbatches: Optional[int] = None,
                    skip_masked_blocks: bool = False,
                    block_q: int = 256, block_k: int = 256,
                    seq_shard: bool = False,
                    attn_heads_shard: bool = True):
    sh = M.Shardings(mesh, seq_shard=seq_shard,
                     attn_heads_shard=attn_heads_shard)
    nmb_cfg = microbatches if microbatches is not None else cfg.microbatches
    loss_fn = make_loss(cfg, sh, skip_masked_blocks, block_q, block_k)

    # Cap microbatches so each one still has >= 1 sequence per data shard
    # (a 16-mb config on the 32-way-DP multi-pod mesh would otherwise
    # leave half the devices idle every microbatch).
    dp = 1
    if mesh is not None:
        sizes = M.mesh_axis_sizes(mesh)
        for a in ("pod", "data"):
            dp *= sizes.get(a, 1)

    def split_mb(batch, nmb):
        def r(x):
            b = x.shape[0]
            return x.reshape((nmb, b // nmb) + x.shape[1:])
        return {k: r(v) for k, v in batch.items()}

    def step(params, opt: AdamState, batch, stepno):
        gb = batch["tokens"].shape[0]
        nmb = max(1, min(nmb_cfg, gb // max(dp, 1)))
        while gb % nmb:
            nmb -= 1
        # One bf16 cast of the (sharded) masters per step; the cast is
        # linear, so d loss/d master == f32(d loss/d cast).
        cparams = cast_to_compute(cfg, params)
        if nmb == 1:
            l, grads = jax.value_and_grad(loss_fn)(cparams, batch)
        else:
            mbs = split_mb(batch, nmb)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(cparams, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(mb_body, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, gsum)
            l = lsum / nmb
        lr_t = cosine_lr(stepno, lr, warmup, total_steps)
        new_params, new_opt = adamw_update(params, grads, opt, stepno, lr_t)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree_util.tree_leaves(grads))
        metrics = {"loss": l, "lr": lr_t, "grad_norm": jnp.sqrt(gsq)}
        return new_params, new_opt, metrics

    return step


def shardings_for_step(cfg: ArchConfig, mesh: Mesh,
                       global_batch: int) -> Tuple[PyTree, PyTree, PyTree]:
    """(param_shardings, opt_shardings, batch_shardings) as NamedShardings."""
    pspecs = M.specs(cfg, mesh.axis_names, M.mesh_axis_sizes(mesh))
    to_ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree_util.tree_map(to_ns, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    o_sh = AdamState(m=p_sh, v=p_sh)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = P(fsdp or None)

    def batch_sh(x):
        return NamedSharding(mesh, bspec)

    from repro.data.pipeline import input_abstract
    b_ab = input_abstract(cfg, global_batch, 1)
    b_sh = {k: NamedSharding(mesh, P(fsdp or None)) for k in b_ab}
    return p_sh, o_sh, b_sh
