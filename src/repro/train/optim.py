"""AdamW with f32 state, sharded like the params (ZeRO: state inherits the
2-D param sharding, so optimizer memory scales 1/(data*model)).

Kept dependency-free (no optax in the image); the update is the standard
decoupled-weight-decay Adam.  ``adamw_specs`` mirrors a param spec tree so
the launcher can place optimizer state explicitly.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(m=jax.tree_util.tree_map(zeros, params),
                     v=jax.tree_util.tree_map(zeros, params))


def adamw_update(params: PyTree, grads: PyTree, state: AdamState,
                 step: jnp.ndarray, lr: float, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0
                 ) -> Tuple[PyTree, AdamState]:
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    if grad_clip is not None:
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree_util.tree_leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    else:
        scale = jnp.float32(1.0)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        step_ = mh / (jnp.sqrt(vh) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + decay
                                             * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamState(m=new_m, v=new_v)


def adamw_specs(param_spec_tree: PyTree) -> AdamState:
    return AdamState(m=param_spec_tree, v=param_spec_tree)


def cosine_lr(step: jnp.ndarray, peak: float, warmup: int,
              total: int, floor: float = 0.1) -> jnp.ndarray:
    t = step.astype(jnp.float32)
    warm = peak * t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(t < warmup, warm, cos)
