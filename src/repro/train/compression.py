"""Gradient compression: int8 quantized all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism: each
worker quantizes its gradient shard to int8 (per-tensor absmax scale),
all-reduces the int8 payload (4x fewer bytes on the wire), dequantizes,
and keeps the quantization residual locally, adding it back into the next
step's gradient (error feedback — keeps SGD/Adam convergence).

Implemented as a shard_map over the data axes so the quantize -> psum ->
dequantize pipeline is explicit; composes with the train step by replacing
the plain grad psum.  Tested at small scale in tests/test_train_substrate.py
(math identity: sum of dequantized shards == dequantized sum under a
shared scale).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize(g: jnp.ndarray, scale: jnp.ndarray):
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q


def compressed_psum(grads: PyTree, axis_names: Sequence[str],
                    error: Optional[PyTree] = None
                    ) -> Tuple[PyTree, PyTree]:
    """Inside shard_map: all-reduce grads in int8 with error feedback.

    Returns (mean gradient f32, new error residual).  The scale is the
    psum-max of per-worker absmax so every worker quantizes into the same
    grid (required for exact int8 summation; the summed int32 fits easily:
    127 * n_workers << 2^31).
    """
    from repro.compat import axis_size
    ax = tuple(axis_names)
    n = 1
    for a in ax:
        n = n * axis_size(a)

    def one(g, e):
        g = g.astype(jnp.float32) + (e if e is not None else 0.0)
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), ax)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = quantize(g, scale)
        summed = jax.lax.psum(q.astype(jnp.int32), ax)
        out = summed.astype(jnp.float32) * scale / n
        new_err = g - q.astype(jnp.float32) * scale
        return out, new_err

    if error is None:
        error = jax.tree_util.tree_map(lambda _: None, grads,
                                       is_leaf=lambda x: x is None)
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        outs = [one(g, None) for g in flat_g]
    else:
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(error)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return mean, err


def error_init(grads_like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
