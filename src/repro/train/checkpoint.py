"""Training checkpoints: atomic sharded save/restore with elastic re-shard.

Same fault-tolerance posture as the solver checkpoints (core/checkpoint):
* atomic tmp+rename writes (no torn checkpoints on preemption);
* restore re-places leaves under ANY mesh's shardings (elastic: restart a
  256-chip job on 512 chips or on one CPU for debugging);
* the data pipeline is stateless (step-indexed PRNG), so (params, opt,
  step) is the ENTIRE job state.
"""

from __future__ import annotations

import io
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optim import AdamState

PyTree = Any


def save(path: str, params: PyTree, opt: AdamState, step: int) -> None:
    leaves, _ = jax.tree_util.tree_flatten((params, opt))
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    arrays["step"] = np.asarray(step)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(buf.getvalue())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, params_like: PyTree, opt_like: AdamState,
            shardings: Optional[Tuple[PyTree, PyTree]] = None
            ) -> Tuple[PyTree, AdamState, int]:
    """Restore onto the current mesh (or host) — elastic re-shard."""
    with np.load(path) as z:
        step = int(z["step"])
        leaves = [z[f"leaf_{i}"] for i in range(
            len([k for k in z.files if k.startswith("leaf_")]))]
    treedef = jax.tree_util.tree_structure((params_like, opt_like))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda l, s: jax.device_put(jnp.asarray(l), s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    params, opt = tree
    return params, opt, step
