"""Blocked causal attention (GQA / RoPE / SWA / softcap) in pure lax.

The prefill/train path is a *blocked online-softmax* (flash-style) scan:
outer ``lax.scan`` over query blocks, inner ``lax.scan`` over KV blocks with
f32 running (max, sum, acc).  Peak memory is O(block_q · block_k) scores per
(batch, head) instead of O(S²) — this is what makes 32k-token prefill
lowerable on a 16 GB chip, and it is the jnp oracle for the Pallas kernel in
``repro.kernels.flash_attention``.

``skip_masked_blocks`` gates fully-masked KV blocks behind ``lax.cond`` so
they cost no FLOPs (causal ⇒ ~half the blocks; SWA ⇒ all but O(window)).
It is OFF in the paper-faithful baseline and turned on as a §Perf iteration —
EXPERIMENTS.md records the before/after.

Decode (one query token against a cache) is a single masked softmax.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE (partial-fraction capable, glm4 rotates only half the head dim).
# ---------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, head_dim: int, fraction: float,
                theta: float):
    """cos/sin tables [..., rot/2] for the rotated prefix of the head dim."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., rot/2]
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rot: int) -> jnp.ndarray:
    """x: [B, S, H, hd]; cos/sin: [B, S, rot/2] (broadcast over heads)."""
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c, s = cos[..., None, :], sin[..., None, :]               # head axis
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    # Cast back to the input dtype BEFORE assembling the output so the
    # materialized K/Q buffers are bf16 (XLA otherwise stores the f32
    # intermediates and defers the cast into every consumer).
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention.
# ---------------------------------------------------------------------------


class _Acc(NamedTuple):
    m: jnp.ndarray      # f32 [B, G, R, Q]   running max
    l: jnp.ndarray      # f32 [B, G, R, Q]   running denominator
    o: jnp.ndarray      # f32 [B, G, R, Q, hd] running numerator


def _block_scores(qb, kb, scale, softcap):
    # qb [B, Q, G, R, hd], kb [B, K, G, hd] -> s [B, G, R, Q, K] (f32).
    # bf16 inputs with an f32 accumulator (preferred_element_type): casting
    # operands to f32 first would materialize f32 copies of every KV block
    # in HBM — the MXU takes bf16 in / f32 out natively.
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _mask(qpos, kpos, window: Optional[int]):
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    return ok                                               # [Q, K]


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      window: Optional[int] = None,
                      softcap: float = 0.0,
                      query_scale: Optional[float] = None,
                      q_offset: int = 0,
                      block_q: int = 256,
                      block_k: int = 256,
                      skip_masked_blocks: bool = False) -> jnp.ndarray:
    """Causal attention.  q: [B, S, H, hd]; k, v: [B, S, G, hd]; returns
    [B, S, H, hd].  H = G * R (GQA).  S must divide by the block sizes
    (configs pick divisors; shapes here are powers of two)."""
    b, s_orig, h, hd = q.shape
    g = k.shape[2]
    r = h // g
    scale = query_scale if query_scale is not None else 1.0 / math.sqrt(hd)

    # Pad the sequence to the block grid; padded KV positions sit *beyond*
    # every real query position, so the causal mask removes them.
    blk = block_q * block_k // math.gcd(block_q, block_k)   # lcm
    pad = (-s_orig) % blk
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
    s = s_orig + pad
    nq, nk = s // block_q, s // block_k

    qb = q.reshape(b, nq, block_q, g, r, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, block_k, g, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block_k, g, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        # rematerialized (Rabe–Staats): without this, the scan VJP stacks a
        # [nk, B, G, R, bq, bk] residual per q block — O(S^2) HBM traffic
        # and memory in the backward.  Recomputing the score block in the
        # backward keeps residuals at O(block) (the flash-attention trade).
        @jax.checkpoint
        def kv_step(acc: _Acc, kj_blk):
            kj, kblk, vblk = kj_blk
            k_pos = kj * block_k + jnp.arange(block_k)

            def compute(acc):
                sblk = _block_scores(qblk, kblk, scale, softcap)
                ok = _mask(q_pos, k_pos, window)             # [Q, K]
                sblk = jnp.where(ok[None, None, None], sblk, NEG_INF)
                m_new = jnp.maximum(acc.m, sblk.max(axis=-1))
                p = jnp.exp(sblk - m_new[..., None])
                alpha = jnp.exp(acc.m - m_new)
                l_new = acc.l * alpha + p.sum(axis=-1)
                # p in bf16 for the PV matmul (values <= 1; f32 accumulate)
                # — the flash-kernel convention, and it avoids an f32 copy
                # of the V block.
                pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v.dtype), vblk,
                                preferred_element_type=jnp.float32)
                o_new = acc.o * alpha[..., None] + pv
                return _Acc(m_new, l_new, o_new)

            if skip_masked_blocks:
                # Block is fully masked iff its smallest q position cannot
                # see its smallest k position (causal) or its largest k
                # position is out of the window for every q in the block.
                first_q = q_offset + qi * block_q
                last_q = first_q + block_q - 1
                first_k = kj * block_k
                last_k = first_k + block_k - 1
                live = first_k <= last_q
                if window is not None:
                    live &= (last_k > first_q - window)
                acc = jax.lax.cond(live, compute, lambda a: a, acc)
            else:
                acc = compute(acc)
            return acc, None

        acc0 = _Acc(
            m=jnp.full((b, g, r, block_q), NEG_INF, jnp.float32),
            l=jnp.zeros((b, g, r, block_q), jnp.float32),
            o=jnp.zeros((b, g, r, block_q, hd), jnp.float32),
        )
        acc, _ = jax.lax.scan(
            kv_step, acc0, (jnp.arange(nk), kb, vb))
        out = acc.o / jnp.maximum(acc.l, 1e-30)[..., None]
        # [B, G, R, Q, hd] -> [B, Q, H, hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, block_q, h, hd)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out[:, :s_orig]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray, *,
                     window: Optional[int] = None,
                     softcap: float = 0.0,
                     query_scale: Optional[float] = None,
                     k_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One-token attention against a cache.

    q: [B, 1, H, hd]; caches: [B, S, G, hd]; pos: [] or [B] — the number of
    valid cache entries (the new token's position).  ``k_positions`` gives
    the absolute position held by each cache slot (rolling-window caches);
    defaults to arange(S).  Returns [B, 1, H, hd].
    """
    b, _, h, hd = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    r = h // g
    scale = query_scale if query_scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(b, 1, g, r, hd)
    sc = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k_cache.astype(qh.dtype),
                    preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        sc = softcap * jnp.tanh(sc / softcap)
    kpos = jnp.arange(s) if k_positions is None else k_positions
    posb = jnp.broadcast_to(jnp.asarray(pos), (b,))
    # kpos < 0 marks unwritten rolling-cache slots — always invalid.
    ok = (kpos[None, :] <= posb[:, None]) & (kpos[None, :] >= 0)  # [B, S]
    if window is not None:
        ok &= (posb[:, None] - kpos[None, :]) < window
    sc = jnp.where(ok[:, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(q.dtype),
                     v_cache.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8-quantized KV cache (serving): per-(token, head) absmax scales.
# ---------------------------------------------------------------------------


def quantize_kv(x: jnp.ndarray):
    """x: [..., hd] bf16 -> (int8[..., hd], f32[..., 1] scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q8.astype(jnp.int8), scale


def decode_attention_quant(q: jnp.ndarray, k8: jnp.ndarray, v8: jnp.ndarray,
                           ks: jnp.ndarray, vs: jnp.ndarray,
                           pos: jnp.ndarray, *,
                           window: Optional[int] = None,
                           softcap: float = 0.0,
                           query_scale: Optional[float] = None,
                           k_positions: Optional[jnp.ndarray] = None,
                           block: int = 2048) -> jnp.ndarray:
    """One-token attention over an int8 cache, dequantized block-by-block
    with an online softmax so the full-cache bf16 copy never materializes
    (flash-decoding structure).  q: [B,1,H,hd]; k8/v8: [B,S,G,hd] int8;
    ks/vs: [B,S,G,1] f32."""
    b, _, h, hd = q.shape
    s, g = k8.shape[1], k8.shape[2]
    r = h // g
    scale = query_scale if query_scale is not None else 1.0 / math.sqrt(hd)
    block = min(block, s)
    nb = s // block if s % block == 0 else -(-s // block)
    pad = nb * block - s
    kpos = jnp.arange(s) if k_positions is None else k_positions
    if pad:
        k8 = jnp.pad(k8, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    qh = q.reshape(b, 1, g, r, hd)
    posb = jnp.broadcast_to(jnp.asarray(pos), (b,))

    def body(acc, j):
        m_p, l_p, o_p = acc
        # dynamic_slice (not a reshaped/transposed scan xs): a transposed
        # xs would materialize a full copy of the int8 cache as a temp.
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, j * block, block, 1)
        k8_, v8_, ks_, vs_ = sl(k8), sl(v8), sl(ks), sl(vs)
        kp_ = jax.lax.dynamic_slice_in_dim(kpos, j * block, block, 0)
        kb = (k8_.astype(jnp.bfloat16)
              * ks_.astype(jnp.bfloat16))                  # [B,blk,G,hd]
        sc = jnp.einsum("bqgrd,bkgd->bgrqk", qh, kb,
                        preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            sc = softcap * jnp.tanh(sc / softcap)
        ok = (kp_[None, :] <= posb[:, None]) & (kp_[None, :] >= 0)
        if window is not None:
            ok &= (posb[:, None] - kp_[None, :]) < window
        sc = jnp.where(ok[:, None, None, None, :], sc, NEG_INF)
        m_n = jnp.maximum(m_p, sc.max(axis=-1))
        p = jnp.exp(sc - m_n[..., None])
        alpha = jnp.exp(m_p - m_n)
        l_n = l_p * alpha + p.sum(axis=-1)
        vb = (v8_.astype(jnp.bfloat16) * vs_.astype(jnp.bfloat16))
        pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(jnp.bfloat16), vb,
                        preferred_element_type=jnp.float32)
        o_n = o_p * alpha[..., None] + pv
        return (m_n, l_n, o_n), None

    acc0 = (jnp.full((b, g, r, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, g, r, 1), jnp.float32),
            jnp.zeros((b, g, r, 1, hd), jnp.float32))
    (m, l, o), _ = jax.lax.scan(body, acc0, jnp.arange(nb))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(q.dtype)
