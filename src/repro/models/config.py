"""Architecture configuration schema for the LM substrate.

One :class:`ArchConfig` instance fully determines a model: family
(dense / moe / ssm / hybrid / vlm / audio), dimensions, attention flavor
(GQA, RoPE fraction, sliding window, logit softcaps, QKV bias), MoE routing,
and SSM (Mamba-2 SSD) parameters.  ``src/repro/configs/<id>.py`` holds one
instance per assigned architecture; reduced copies (``smoke()``) drive the
CPU smoke tests.

Dtype policy: params/activations bf16, RMSNorm & softmax statistics f32,
optimizer state f32 (see repro.train.optim).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Per-expert hidden width (== ArchConfig.d_ff for the routed experts).
    d_ff: int
    # Capacity factor for the gather-BMM dispatch; tokens beyond
    # ceil(T*top_k*capacity_factor/E) per expert are dropped (standard TPU
    # MoE practice; tests use a lossless factor).
    capacity_factor: float = 1.25
    # Llama-4 style always-on shared expert (0 = none).
    shared_expert_ff: int = 0
    router_softcap: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer parameters."""
    d_state: int              # N — SSM state size per head
    d_inner: int              # expanded width (usually 2 * d_model)
    head_dim: int = 64        # P — SSD head dim; n_heads = d_inner // P
    n_groups: int = 1         # G — B/C groups
    d_conv: int = 4           # causal depthwise conv width
    chunk: int = 128          # SSD chunk length (perf knob)

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int

    # Attention (unused for family == "ssm").
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rope_fraction: float = 1.0          # glm4 rotates half the head dim
    window: Optional[int] = None        # sliding-window size (SWA)
    # gemma2: alternate local(window)/global attention; period 2 means
    # layer i uses the window iff i % 2 == 0.
    local_global_period: int = 0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)

    # MLP.
    d_ff: int = 0
    mlp_gated: bool = True              # SwiGLU (gated) vs plain GELU

    # Norm/embedding flavor.
    norm_eps: float = 1e-5
    post_norms: bool = False            # gemma2 pre+post sublayer norms
    embed_scale: bool = False           # gemma2 multiplies embeds by sqrt(d)
    tie_embeddings: bool = False

    # Family extensions.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* attention+MLP block applied before every
    # ``hybrid_period``-th mamba layer.
    hybrid_period: int = 0
    # audio (musicgen): parallel codebook streams; input embeddings are
    # summed, output has n_codebooks heads.  The EnCodec frontend is a stub:
    # input_specs() provides token ids per codebook (embedding lookup is the
    # backbone's own) and examples feed random codes.
    n_codebooks: int = 0
    # vlm (internvl2): the InternViT frontend is a stub; input_specs()
    # provides ``vision_tokens`` precomputed patch embeddings that replace
    # the first V positions (early fusion).
    vision_tokens: int = 0

    # Training-time knobs (per-arch defaults; launcher may override).
    remat: str = "full"                 # full | dots | none
    # Microbatch count for grad accumulation at train_4k on the production
    # mesh (global batch 256); must divide the per-device batch.
    microbatches: int = 1

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def quadratic_attention(self) -> bool:
        """True when some layer attends over the full sequence (=> long_500k
        is skipped for this arch, DESIGN.md §Arch-applicability)."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return False   # handled: few attention sites, sequence-sharded
        if self.window is not None and self.local_global_period == 0:
            return False   # pure SWA (mixtral)
        return True

    @property
    def kv_groups(self) -> int:
        return self.n_heads // max(self.n_kv, 1)

    def layer_uses_window(self, layer: int) -> bool:
        if self.window is None:
            return False
        if self.local_global_period == 0:
            return True
        return layer % self.local_global_period == 0

    # ---- parameter counting (used by roofline MODEL_FLOPS = 6·N·D) --------

    def param_count(self) -> int:
        """Exact parameter count (embeddings included)."""
        d = self.d_model
        total = self.vocab * d                       # embedding
        if not self.tie_embeddings and self.n_codebooks == 0:
            total += self.vocab * d                  # lm head
        if self.n_codebooks:
            total += (self.n_codebooks - 1) * self.vocab * d   # extra embeds
            total += self.n_codebooks * self.vocab * d         # heads
        total += d                                   # final norm
        per_layer = self._layer_params()
        total += self.n_layers * per_layer
        if self.hybrid_period:
            total += self._shared_block_params()
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        routed_all = 3 * self.d_model * m.d_ff * m.num_experts
        routed_active = 3 * self.d_model * m.d_ff * m.top_k
        return self.param_count() - self.n_layers * (routed_all - routed_active)

    def _attn_params(self, n_heads: int, n_kv: int, head_dim: int) -> int:
        d = self.d_model
        qo = 2 * d * n_heads * head_dim
        kv = 2 * d * n_kv * head_dim
        bias = (n_heads + 2 * n_kv) * head_dim if self.qkv_bias else 0
        return qo + kv + bias

    def _mlp_params(self, d_ff: int) -> int:
        mults = 3 if self.mlp_gated else 2
        return mults * self.d_model * d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
        in_proj = d * (2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads)
        return (in_proj + conv_dim * s.d_conv + conv_dim   # conv w + bias
                + 3 * s.n_heads + s.d_inner + s.d_inner * d)

    def _layer_params(self) -> int:
        d = self.d_model
        norms = 2 * d * (2 if self.post_norms else 1)
        if self.family == "ssm" or (self.family == "hybrid"):
            return self._ssm_params() + d            # mamba layer + norm
        attn = self._attn_params(self.n_heads, self.n_kv, self.head_dim)
        if self.moe is not None:
            m = self.moe
            mlp = 3 * d * m.d_ff * m.num_experts + d * m.num_experts
            if m.shared_expert_ff:
                mlp += 3 * d * m.shared_expert_ff
        else:
            mlp = self._mlp_params(self.d_ff)
        return attn + mlp + norms

    def _shared_block_params(self) -> int:
        d = self.d_model
        attn = self._attn_params(self.n_heads, self.n_kv, self.head_dim)
        return attn + self._mlp_params(self.d_ff) + 2 * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                       LONG_500K)


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """The runnable shape cells for an arch (skips recorded in DESIGN.md)."""
    if cfg.quadratic_attention:
        return (TRAIN_4K, PREFILL_32K, DECODE_32K)
    return ALL_SHAPES
