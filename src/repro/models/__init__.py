"""LM substrate: model families for the assigned architectures."""
