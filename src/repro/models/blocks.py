"""Layer blocks for every assigned architecture family.

A model is a scan over *layer groups* (repro.models.model).  Grouping keeps
heterogeneous stacks scan-able with exact HLO trip counts — no lax.cond in
the layer path, which keeps the roofline accounting honest:

  dense / moe / vlm / audio : group = 1 transformer layer
  gemma2 (alternating)      : group = (local layer, global layer)
  ssm (mamba2)              : group = 1 mamba layer
  hybrid (zamba2)           : group = shared attn/mlp block + P mamba layers

Each block body supports three modes:
  train / prefill : full-sequence, blocked attention / chunked SSD;
                    prefill additionally emits cache entries;
  decode          : one token against a cache (KV, rolling-window KV, or
                    SSM state + conv tail).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssd
from repro.models.attention import (apply_rope, blocked_attention,
                                    decode_attention,
                                    decode_attention_quant, quantize_kv,
                                    rope_tables)
from repro.models.config import ArchConfig
from repro.models.layers import (apply_mlp, attn_decls, mlp_decls, norm_decl,
                                 rmsnorm)
from repro.models.moe import moe_decls, moe_ffn
from repro.models.params import ParamDecl

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call context threaded through the blocks."""
    cfg: ArchConfig
    mode: str                               # train | prefill | decode
    pos: Optional[jnp.ndarray] = None       # decode: current position []
    shard: Callable[[jnp.ndarray, Tuple], jnp.ndarray] = lambda x, s: x
    block_q: int = 256
    block_k: int = 256
    skip_masked_blocks: bool = False
    moe_shard_map: Optional[Callable] = None   # wraps moe_ffn when sharded
    kv_quant: bool = False                  # int8 KV cache (serving)

    @property
    def decode(self) -> bool:
        return self.mode == "decode"


# ---------------------------------------------------------------------------
# Attention sublayer (shared by dense/moe/vlm/audio/gemma2/zamba2-shared).
# ---------------------------------------------------------------------------


def attention_sublayer(p: Dict[str, jnp.ndarray], h: jnp.ndarray, ctx: Ctx,
                       window: Optional[int],
                       cache: Optional[Dict[str, jnp.ndarray]] = None,
                       ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """h -> (attn_out, new_cache).  Cache dict: {"k","v"} [B, Sc, G, hd]
    (+ implicit rolling layout when Sc < full sequence)."""
    cfg = ctx.cfg
    b, s, _ = h.shape
    hn, g, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim

    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, hn, hd)
    k = k.reshape(b, s, g, hd)
    v = v.reshape(b, s, g, hd)
    q = ctx.shard(q, ("batch", None, "heads", None))
    k = ctx.shard(k, ("batch", None, "kv", None))
    v = ctx.shard(v, ("batch", None, "kv", None))

    if ctx.decode:
        positions = jnp.full((b, 1), ctx.pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    cos, sin, rot = rope_tables(positions, hd, cfg.rope_fraction,
                                cfg.rope_theta)
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)

    new_cache = None
    if ctx.decode:
        assert cache is not None
        sc = cache["k"].shape[1]
        slot = (ctx.pos % sc).astype(jnp.int32)

        def upd(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), slot, axis=1)

        # Rolling layout: slot i holds position pos - ((pos - i) mod Sc).
        # For a full-length cache (pos < Sc) this reduces to kpos = i for
        # i <= pos and a negative (masked-out) value for unwritten slots,
        # so the same formula serves both cache kinds.
        idx = jnp.arange(sc)
        kpos = ctx.pos - ((ctx.pos - idx) % sc)
        if ctx.kv_quant:
            k8, ksc = quantize_kv(k)
            v8, vsc = quantize_kv(v)
            new_cache = {"k": upd(cache["k"], k8), "v": upd(cache["v"], v8),
                         "ks": upd(cache["ks"], ksc),
                         "vs": upd(cache["vs"], vsc)}
            attn = decode_attention_quant(
                q, new_cache["k"], new_cache["v"], new_cache["ks"],
                new_cache["vs"], ctx.pos, window=window,
                softcap=cfg.attn_softcap, query_scale=cfg.query_scale,
                k_positions=kpos)
        else:
            k_cache = upd(cache["k"], k)
            v_cache = upd(cache["v"], v)
            new_cache = {"k": k_cache, "v": v_cache}
            attn = decode_attention(
                q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                ctx.pos, window=window, softcap=cfg.attn_softcap,
                query_scale=cfg.query_scale, k_positions=kpos)
    else:
        attn = blocked_attention(
            q, k, v, window=window, softcap=cfg.attn_softcap,
            query_scale=cfg.query_scale, block_q=min(ctx.block_q, s),
            block_k=min(ctx.block_k, s),
            skip_masked_blocks=ctx.skip_masked_blocks)
        if ctx.mode == "prefill":
            keep = window if (window is not None and window < s) else s
            kk, vv = k[:, -keep:, :, :], v[:, -keep:, :, :]
            if ctx.kv_quant:
                k8, ksc = quantize_kv(kk)
                v8, vsc = quantize_kv(vv)
                new_cache = {"k": k8, "v": v8, "ks": ksc, "vs": vsc}
            else:
                new_cache = {"k": kk, "v": vv}

    attn = attn.reshape(b, s, hn * hd)
    out = attn @ p["wo"]
    return ctx.shard(out, ("batch", "seq_res", "embed_act")), new_cache


# ---------------------------------------------------------------------------
# Transformer layer (attention + MLP/MoE) — dense, moe, vlm, audio, gemma2.
# ---------------------------------------------------------------------------


def transformer_decls(cfg: ArchConfig, use_moe: bool) -> Dict[str, Any]:
    d = cfg.d_model
    gstyle = cfg.post_norms
    decls: Dict[str, Any] = {"attn": attn_decls(cfg)}
    decls["ln1"] = norm_decl(d) if not gstyle else _zero_norm(d)
    decls["ln2"] = norm_decl(d) if not gstyle else _zero_norm(d)
    if gstyle:
        decls["ln1_post"] = _zero_norm(d)
        decls["ln2_post"] = _zero_norm(d)
    if use_moe:
        decls["moe"] = moe_decls(d, cfg.moe)
    else:
        decls["mlp"] = mlp_decls(d, cfg.d_ff, cfg.mlp_gated)
    return decls


def _zero_norm(d: int) -> ParamDecl:
    # gemma-style scale is (1 + w): init w = 0.
    return ParamDecl((d,), ("embed",), init="zeros")


def apply_transformer_layer(p: Dict[str, Any], h: jnp.ndarray, ctx: Ctx,
                            window: Optional[int],
                            cache: Optional[Dict] = None,
                            ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cfg = ctx.cfg
    gstyle = cfg.post_norms
    hn = rmsnorm(h, p["ln1"], cfg.norm_eps, gemma_style=gstyle)
    attn, new_cache = attention_sublayer(p["attn"], hn, ctx, window, cache)
    if gstyle:
        attn = rmsnorm(attn, p["ln1_post"], cfg.norm_eps, gemma_style=True)
    h = h + attn

    hn = rmsnorm(h, p["ln2"], cfg.norm_eps, gemma_style=gstyle)
    if "moe" in p:
        b, s, d = hn.shape
        x2d = hn.reshape(b * s, d)
        fn = ctx.moe_shard_map or (
            lambda x, prm: moe_ffn(x, prm, cfg.moe))
        ff = fn(x2d, p["moe"]).reshape(b, s, d)
    else:
        ff = apply_mlp(p["mlp"], hn, cfg.mlp_gated)
    if gstyle:
        ff = rmsnorm(ff, p["ln2_post"], cfg.norm_eps, gemma_style=True)
    h = h + ff
    return ctx.shard(h, ("batch", "seq_res", "embed_act")), new_cache


# ---------------------------------------------------------------------------
# Mamba-2 layer (ssm family and the hybrid backbone).
# ---------------------------------------------------------------------------


def mamba_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    s = cfg.ssm
    d, din, gn, hh = cfg.d_model, s.d_inner, s.n_groups * s.d_state, s.n_heads
    conv_dim = din + 2 * gn
    return {
        "ln": norm_decl(d),
        "wz": ParamDecl((d, din), ("embed", "mlp")),
        "wx": ParamDecl((d, din), ("embed", "mlp")),
        "wb": ParamDecl((d, gn), ("embed", None)),
        "wc": ParamDecl((d, gn), ("embed", None)),
        "wdt": ParamDecl((d, hh), ("embed", None)),
        "conv_w": ParamDecl((s.d_conv, conv_dim), ("conv", None)),
        "conv_b": ParamDecl((conv_dim,), (None,), init="zeros"),
        "dt_bias": ParamDecl((hh,), (None,), jnp.float32, init="ssm_dt"),
        "a_log": ParamDecl((hh,), (None,), jnp.float32, init="ssm_a"),
        "d_skip": ParamDecl((hh,), (None,), jnp.float32, init="ones"),
        "gnorm": ParamDecl((din,), ("mlp",), init="ones"),
        "out_proj": ParamDecl((din, d), ("mlp", "embed")),
    }


def apply_mamba_layer(p: Dict[str, jnp.ndarray], h: jnp.ndarray, ctx: Ctx,
                      cache: Optional[Dict[str, jnp.ndarray]] = None,
                      ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """cache: {"state": [B,H,N,P], "conv": [B,K-1,conv_dim]}."""
    cfg = ctx.cfg
    s = cfg.ssm
    b, sl, _ = h.shape
    din, gn = s.d_inner, s.n_groups * s.d_state
    hh, pp, nn, gg = s.n_heads, s.head_dim, s.d_state, s.n_groups

    hn = rmsnorm(h, p["ln"], cfg.norm_eps)
    z = hn @ p["wz"]
    xbc_pre = jnp.concatenate(
        [hn @ p["wx"], hn @ p["wb"], hn @ p["wc"]], axis=-1)
    dt_raw = hn @ p["wdt"]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    new_cache = None

    if ctx.decode:
        assert cache is not None
        xbc_t, conv_tail = ssd.causal_conv_step(
            cache["conv"], xbc_pre[:, 0, :], p["conv_w"])
        xbc_t = jax.nn.silu((xbc_t + p["conv_b"]).astype(jnp.float32)
                            ).astype(h.dtype)
        x_t = xbc_t[:, :din].reshape(b, hh, pp)
        b_t = xbc_t[:, din:din + gn].reshape(b, gg, nn)
        c_t = xbc_t[:, din + gn:].reshape(b, gg, nn)
        dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32)
                             + p["dt_bias"])
        y_t, state = ssd.ssd_decode_step(
            cache["state"], x_t, dt, a, b_t, c_t, p["d_skip"])
        y = y_t.reshape(b, 1, din)
        new_cache = {"state": state, "conv": conv_tail}
    else:
        xbc = ssd.causal_conv(xbc_pre, p["conv_w"])
        xbc = jax.nn.silu((xbc + p["conv_b"]).astype(jnp.float32)
                          ).astype(h.dtype)
        x = xbc[..., :din].reshape(b, sl, hh, pp)
        bmat = xbc[..., din:din + gn].reshape(b, sl, gg, nn)
        cmat = xbc[..., din + gn:].reshape(b, sl, gg, nn)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        y, state = ssd.ssd_chunked(x, dt, a, bmat, cmat, p["d_skip"],
                                   chunk=min(s.chunk, sl))
        y = y.reshape(b, sl, din)
        if ctx.mode == "prefill":
            # conv tail = last K-1 *pre-activation* conv inputs.
            k = s.d_conv
            new_cache = {"state": state, "conv": xbc_pre[:, -(k - 1):, :]}

    if ctx.decode:
        zg = z[:, :1, :]
    else:
        zg = z
    y = rmsnorm(y * jax.nn.silu(zg.astype(jnp.float32)).astype(zg.dtype),
                p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return ctx.shard(h + out, ("batch", "seq_res", "embed_act")), new_cache
