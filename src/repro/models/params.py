"""Declarative parameters: one definition -> real init / abstract / shardings.

A module's parameters are declared as a pytree of :class:`ParamDecl` (shape,
dtype, initializer, *logical axes*).  Three materializers consume the tree:

* ``init_params``     — real jnp arrays (smoke tests, examples);
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (dry-run: no
  allocation, the pattern the multi-pod compile check requires);
* ``param_specs``     — ``PartitionSpec`` per leaf, from logical-axis ->
  mesh-axis rules (the framework's sharding-rule table, MaxText-style).

Logical axes used by the LM substrate:

  embed   — d_model dim            -> FSDP axis ("data"[, "pod"])  (ZeRO-3)
  heads   — fused q/o head dim     -> TP axis ("model")
  kv      — fused kv head dim      -> TP axis ("model")
  mlp     — feed-forward hidden    -> TP axis ("model")
  vocab   — vocabulary             -> TP axis ("model")
  experts — MoE expert count       -> replicated (E ∤ 16; F/D carry TP/FSDP)
  layers  — scan-stacked layer dim -> replicated (scan carry)
  None    — replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"        # normal | zeros | ones | ssm_a | ssm_dt
    fan_in: Optional[int] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(decl: ParamDecl, key) -> jnp.ndarray:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, decl.dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, decl.dtype)
    if decl.init == "ssm_a":      # mamba2: A = -exp(uniform log) in [1,16]
        u = jax.random.uniform(key, decl.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(decl.dtype)
    if decl.init == "ssm_dt":     # dt bias: softplus^-1 of U(1e-3, 1e-1)
        u = jax.random.uniform(key, decl.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(decl.dtype)
    fan_in = decl.fan_in or (decl.shape[-2] if len(decl.shape) >= 2
                             else decl.shape[-1])
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, decl.shape, jnp.float32) * std
            ).astype(decl.dtype)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def init_params(decls: PyTree, key) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_init_leaf(d, k) for d, k in zip(leaves, keys)])


def abstract_params(decls: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls,
        is_leaf=is_decl)


#: logical axis -> mesh axes (None = replicated).  ``fsdp`` covers both the
#: single-pod ("data",) and multi-pod ("pod", "data") cases.
def default_rules(mesh_axis_names: Sequence[str]) -> Dict[str, Any]:
    fsdp: Any = tuple(a for a in ("pod", "data") if a in mesh_axis_names)
    if not fsdp:
        fsdp = None
    tp = "model" if "model" in mesh_axis_names else None
    return {
        "embed": fsdp,
        "heads": tp,
        "kv": tp,
        "mlp": tp,
        "vocab": tp,
        "experts": None,
        "layers": None,
        "conv": None,
        "state": None,
    }


def spec_for(decl: ParamDecl, rules: Dict[str, Any],
             axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """PartitionSpec for one param.  A logical->mesh mapping is dropped when
    (a) the mesh axis is already used by another dim of this param, or
    (b) ``axis_sizes`` is given and the dim is not divisible by the mapped
    axes' product (jit in_shardings require exact divisibility)."""
    axes = []
    used = set()

    def flat(x):
        if x is None:
            return ()
        return (x,) if isinstance(x, str) else tuple(x)

    for dim, name in zip(decl.shape, decl.logical):
        mapped = rules.get(name) if name else None
        ok = mapped is not None
        if ok:
            group = flat(mapped)
            if any(g in used for g in group):
                ok = False
            elif axis_sizes is not None:
                prod = 1
                for g in group:
                    prod *= axis_sizes.get(g, 1)
                if prod == 0 or dim % prod != 0:
                    ok = False
        if ok:
            axes.append(mapped)
            used.update(flat(mapped))
        else:
            axes.append(None)
    return P(*axes)


def param_specs(decls: PyTree, rules: Dict[str, Any],
                axis_sizes: Optional[Dict[str, int]] = None) -> PyTree:
    return jax.tree_util.tree_map(
        lambda d: spec_for(d, rules, axis_sizes), decls, is_leaf=is_decl)
