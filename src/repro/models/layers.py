"""Shared primitive layers: RMSNorm, gated MLP, embeddings.

All matmuls run in bf16 with f32 accumulation where it matters (norms,
softmax, losses are f32).  Parameter declarations carry logical axes
consumed by repro.models.params.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamDecl


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float,
            gemma_style: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    norm = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma_style \
        else w.astype(jnp.float32)
    return (norm * scale).astype(x.dtype)


def mlp_decls(d_model: int, d_ff: int, gated: bool) -> Dict[str, ParamDecl]:
    if gated:
        return {
            "w1": ParamDecl((d_model, d_ff), ("embed", "mlp")),
            "w3": ParamDecl((d_model, d_ff), ("embed", "mlp")),
            "w2": ParamDecl((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "w1": ParamDecl((d_model, d_ff), ("embed", "mlp")),
        "w2": ParamDecl((d_ff, d_model), ("mlp", "embed")),
    }


def apply_mlp(p: Dict[str, jnp.ndarray], x: jnp.ndarray,
              gated: bool) -> jnp.ndarray:
    if gated:
        h = (jax.nn.silu((x @ p["w1"]).astype(jnp.float32)).astype(x.dtype)
             * (x @ p["w3"]))
    else:
        h = jax.nn.gelu((x @ p["w1"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w2"]


def attn_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    decls = {
        "wq": ParamDecl((d, h * hd), ("embed", "heads")),
        "wk": ParamDecl((d, g * hd), ("embed", "kv")),
        "wv": ParamDecl((d, g * hd), ("embed", "kv")),
        "wo": ParamDecl((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        decls["bq"] = ParamDecl((h * hd,), ("heads",), init="zeros")
        decls["bk"] = ParamDecl((g * hd,), ("kv",), init="zeros")
        decls["bv"] = ParamDecl((g * hd,), ("kv",), init="zeros")
    return decls


def norm_decl(d: int) -> ParamDecl:
    return ParamDecl((d,), ("embed",), init="ones")
