"""Model assembly: params, forward, loss, prefill, decode — all families.

The layer stack is a ``lax.scan`` over *layer groups* with stacked params
(leading ``layers`` dim).  Grouping (see repro.models.blocks) encodes
heterogeneous stacks without lax.cond:

  dense/moe/vlm/audio  group = {"blk": layer}          n_groups = L
  gemma2               group = {"sub0": local, "sub1": global}  L/2
  ssm                  group = {"blk": mamba}          L
  hybrid (zamba2)      group = {"mamba": [P x mamba]} + closure-shared
                       transformer block applied once per group

Modes: train (loss), prefill (emit cache), decode (one token vs cache).
Caches mirror the group tree; sliding-window sites allocate only
min(S, window) slots (rolling layout).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import blocks
from repro.models.blocks import Ctx
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.layers import norm_decl, rmsnorm
from repro.models.moe import moe_ffn
from repro.models.params import (ParamDecl, abstract_params, default_rules,
                                 init_params, is_decl, param_specs)

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter declarations.
# ---------------------------------------------------------------------------


def _stack(decls: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda d: ParamDecl((n,) + d.shape, ("layers",) + d.logical,
                            d.dtype, d.init,
                            d.fan_in or (d.shape[-2] if len(d.shape) >= 2
                                         else d.shape[-1])),
        decls, is_leaf=is_decl)


def n_groups(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_period
    if cfg.local_global_period == 2:
        return cfg.n_layers // 2
    return cfg.n_layers


def group_decls(cfg: ArchConfig) -> PyTree:
    if cfg.family == "ssm":
        per = {"blk": blocks.mamba_decls(cfg)}
    elif cfg.family == "hybrid":
        per = {"mamba": _stack(blocks.mamba_decls(cfg), cfg.hybrid_period)}
    elif cfg.local_global_period == 2:
        per = {"sub0": blocks.transformer_decls(cfg, cfg.moe is not None),
               "sub1": blocks.transformer_decls(cfg, cfg.moe is not None)}
    else:
        per = {"blk": blocks.transformer_decls(cfg, cfg.moe is not None)}
    return _stack(per, n_groups(cfg))


def param_decls(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    decls: Dict[str, Any] = {}
    if cfg.n_codebooks:
        decls["embed"] = ParamDecl((cfg.n_codebooks, cfg.vocab, d),
                                   (None, "vocab", "embed"))
        decls["out_heads"] = ParamDecl((cfg.n_codebooks, d, cfg.vocab),
                                       (None, "embed", "vocab"))
    else:
        decls["embed"] = ParamDecl((cfg.vocab, d), ("vocab", "embed"))
        if not cfg.tie_embeddings:
            decls["lm_head"] = ParamDecl((d, cfg.vocab), ("embed", "vocab"))
    decls["final_norm"] = (ParamDecl((d,), ("embed",), init="zeros")
                           if cfg.post_norms else norm_decl(d))
    decls["layers"] = group_decls(cfg)
    if cfg.family == "hybrid":
        decls["shared"] = blocks.transformer_decls(cfg, use_moe=False)
    return decls


def init(cfg: ArchConfig, key) -> PyTree:
    return init_params(param_decls(cfg), key)


def abstract(cfg: ArchConfig) -> PyTree:
    return abstract_params(param_decls(cfg))


def specs(cfg: ArchConfig, mesh_axis_names, axis_sizes=None) -> PyTree:
    return param_specs(param_decls(cfg), default_rules(mesh_axis_names),
                       axis_sizes)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# Sharding helper threaded through blocks via Ctx.shard.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Shardings:
    mesh: Optional[Mesh] = None
    #: sequence parallelism for the residual stream: shard the seq dim of
    #: [B, S, D] activations over the TP axis between blocks, turning the
    #: per-layer TP all-reduces into reduce-scatter + all-gather pairs
    #: (half the bytes) — a §Perf hillclimb knob.
    seq_shard: bool = False
    #: shard attention heads over the TP axis.  With head counts that do
    #: not divide 16 (qwen2: H=28, kv=4) the padded uneven sharding makes
    #: GSPMD re-gather score-shaped f32 blocks in the attention backward
    #: (~1.3 TB/step measured) — turning this OFF replicates the (cheap)
    #: attention math over TP and deletes those collectives.
    attn_heads_shard: bool = True

    def act_rules(self) -> Dict[str, Any]:
        if self.mesh is None:
            return {}
        names = self.mesh.axis_names
        fsdp = tuple(a for a in ("pod", "data") if a in names) or None
        tp = "model" if "model" in names else None
        htp = tp if self.attn_heads_shard else None
        return {"batch": fsdp, "heads": htp, "kv": htp, "vocab": tp,
                "mlp_act": tp, "embed_act": None, "seq": fsdp,
                "seq_res": tp if self.seq_shard else None}

    def shard(self, x: jnp.ndarray, logical: Tuple) -> jnp.ndarray:
        if self.mesh is None:
            return x
        rules = self.act_rules()
        used: set = set()
        axes = []
        for name in logical:
            mapped = rules.get(name) if name else None
            if mapped is not None:
                flat = (mapped,) if isinstance(mapped, str) else tuple(mapped)
                if any(a in used for a in flat):
                    mapped = None
                else:
                    used.update(flat)
            axes.append(mapped)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*axes)))

    def moe_wrapper(self, cfg: ArchConfig) -> Optional[Callable]:
        """shard_map'd MoE so dispatch stays local per data shard."""
        if self.mesh is None or cfg.moe is None:
            return None
        names = self.mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        tp = "model" if "model" in names else None
        # ZeRO gather must cover EVERY axis the embed (D) dim is stored
        # over — ("pod", "data") on the multi-pod mesh.
        zero = dp or None
        rules = default_rules(names)
        from repro.models.moe import moe_decls as _md
        sizes = mesh_axis_sizes(self.mesh)
        pspecs = param_specs(_md(cfg.d_model, cfg.moe), rules, sizes)

        # checkpoint INSIDE the shard_map: outer remat does not reach
        # through shard_map, so without this the f32 combine output is
        # saved per layer (5+ GB/device at 56 layers).
        @jax.checkpoint
        def body(x2d, prm):
            return moe_ffn(x2d, prm, cfg.moe, tp_axis=tp, zero_axes=zero)

        dp_n = 1
        sizes_ = mesh_axis_sizes(self.mesh)
        for a in dp:
            dp_n *= sizes_[a]

        def build(token_spec):
            from repro.compat import shard_map
            return shard_map(
                body, mesh=self.mesh,
                in_specs=(token_spec, pspecs),
                out_specs=token_spec, check=False)

        sharded = build(P(dp if dp else None, None))
        replicated = build(P(None, None))

        def fn(x2d, prm):
            # decode at global_batch < dp (long_500k): tokens cannot split
            # over the data axes — run the (tiny) batch replicated.
            if x2d.shape[0] % max(dp_n, 1) == 0 and x2d.shape[0] >= dp_n:
                return sharded(x2d, prm)
            return replicated(x2d, prm)

        return fn


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------


def _embed(cfg: ArchConfig, params: PyTree, batch: Dict[str, jnp.ndarray],
           ctx: Ctx) -> jnp.ndarray:
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # tokens [B, S, CB]: summed codebook embeddings (EnCodec stub).
        h = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), jnp.bfloat16)
        for cb in range(cfg.n_codebooks):
            h = h + params["embed"][cb][tokens[..., cb]]
    else:
        h = params["embed"][tokens]
    if cfg.vision_tokens and not ctx.decode and "vision" in batch:
        v = batch["vision"].astype(h.dtype)          # [B, V, D] (stub)
        h = jnp.concatenate([v, h[:, v.shape[1]:, :]], axis=1)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return ctx.shard(h, ("batch", "seq_res", "embed_act"))


def _group_body(cfg: ArchConfig, ctx: Ctx, shared_params):
    """Returns body(h, (gparams, gcache)) -> (h, new_gcache)."""

    def body(h, xs):
        gp, gcache = xs

        def site(name, fn, *args):
            c = None if gcache is None else gcache[name]
            out, nc = fn(*args, cache=c)
            return out, nc

        ncache = {}
        if cfg.family == "ssm":
            h, nc = site("blk", lambda cache: blocks.apply_mamba_layer(
                gp["blk"], h, ctx, cache=cache))
            ncache["blk"] = nc
        elif cfg.family == "hybrid":
            h, nc = site("shared", lambda cache: blocks.apply_transformer_layer(
                shared_params, h, ctx, window=None, cache=cache))
            ncache["shared"] = nc

            def inner(hc, ixs):
                ip, icache = ixs
                hh, inc = blocks.apply_mamba_layer(ip, hc, ctx, cache=icache)
                return hh, inc

            inner_cache = None if gcache is None else gcache["mamba"]
            h, mcaches = jax.lax.scan(
                inner, h, (gp["mamba"], inner_cache))
            ncache["mamba"] = mcaches
        elif cfg.local_global_period == 2:
            h, nc0 = site("sub0", lambda cache: blocks.apply_transformer_layer(
                gp["sub0"], h, ctx, window=cfg.window, cache=cache))
            h, nc1 = site("sub1", lambda cache: blocks.apply_transformer_layer(
                gp["sub1"], h, ctx, window=None, cache=cache))
            ncache["sub0"], ncache["sub1"] = nc0, nc1
        else:
            h, nc = site("blk", lambda cache: blocks.apply_transformer_layer(
                gp["blk"], h, ctx, window=cfg.window, cache=cache))
            ncache["blk"] = nc
        if ctx.mode == "train":
            return h, None
        return h, ncache

    return body


def _remat_wrap(cfg: ArchConfig, body):
    if cfg.remat == "none":
        return body
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)        # "full": save only the carry


def run_layers(cfg: ArchConfig, params: PyTree, h: jnp.ndarray, ctx: Ctx,
               cache: Optional[PyTree] = None
               ) -> Tuple[jnp.ndarray, Optional[PyTree]]:
    shared = params.get("shared")
    body = _group_body(cfg, ctx, shared)
    if ctx.mode == "train":
        # checkpoint the EXACT callable handed to scan — jax's
        # remat-in-scan handling keys on the scan body itself; a thin
        # lambda around a checkpointed inner function left extra f32
        # residuals stacked per layer.
        def scan_body(c, gp):
            return body(c, (gp, None))
        h, _ = jax.lax.scan(_remat_wrap(cfg, scan_body), h,
                            params["layers"])
        return h, None
    if ctx.mode == "decode":
        # Thread the cache through the scan CARRY with per-layer dynamic
        # read/write: while-loop carries update in place (the donated
        # input buffer is reused), whereas a cache passed as xs -> ys
        # made XLA materialize a second full cache as a temp.
        def dec_body(carry, gp):
            h_c, cache_c, i = carry
            gcache = jax.tree_util.tree_map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, i, 0, keepdims=False), cache_c)
            h_c, ncache = body(h_c, (gp, gcache))
            cache_c = jax.tree_util.tree_map(
                lambda buf, nc: jax.lax.dynamic_update_index_in_dim(
                    buf, nc.astype(buf.dtype), i, 0), cache_c, ncache)
            return (h_c, cache_c, i + 1), None

        (h, cache, _), _ = jax.lax.scan(
            dec_body, (h, cache, jnp.int32(0)), params["layers"])
        return h, cache
    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    return h, new_cache


def logits_fn(cfg: ArchConfig, params: PyTree, h: jnp.ndarray,
              ctx: Ctx) -> jnp.ndarray:
    hn = rmsnorm(h, params["final_norm"], cfg.norm_eps,
                 gemma_style=cfg.post_norms)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", hn, params["out_heads"])
        logical = ("batch", None, None, "vocab")
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hn, params["embed"])
        logical = ("batch", None, "vocab")
    else:
        logits = hn @ params["lm_head"]
        logical = ("batch", None, "vocab")
    if cfg.final_softcap > 0.0:
        logits = (cfg.final_softcap
                  * jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  ).astype(logits.dtype)
    return ctx.shard(logits, logical)


def make_ctx(cfg: ArchConfig, mode: str, sh: Shardings,
             pos: Optional[jnp.ndarray] = None,
             skip_masked_blocks: bool = False,
             block_q: int = 256, block_k: int = 256,
             kv_quant: bool = False) -> Ctx:
    return Ctx(cfg=cfg, mode=mode, pos=pos, shard=sh.shard,
               block_q=block_q, block_k=block_k,
               skip_masked_blocks=skip_masked_blocks,
               moe_shard_map=sh.moe_wrapper(cfg), kv_quant=kv_quant)


def forward(cfg: ArchConfig, params: PyTree, batch: Dict[str, jnp.ndarray],
            ctx: Ctx) -> jnp.ndarray:
    h = _embed(cfg, params, batch, ctx)
    h, _ = run_layers(cfg, params, h, ctx)
    return logits_fn(cfg, params, h, ctx)


# ---------------------------------------------------------------------------
# Loss (causal LM; labels provided shifted by the data pipeline).
# ---------------------------------------------------------------------------


def xent(logits: jnp.ndarray, labels: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Sharded-vocab-safe cross entropy: one-hot dot, no gather."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, vocab, dtype=jnp.float32)
    ll = jnp.sum(onehot * lf, axis=-1)
    return (lse - ll).mean()


def loss_fn(cfg: ArchConfig, params: PyTree, batch: Dict[str, jnp.ndarray],
            ctx: Ctx) -> jnp.ndarray:
    logits = forward(cfg, params, batch, ctx)
    return xent(logits, batch["labels"], cfg.vocab)


# ---------------------------------------------------------------------------
# Serving: cache construction, prefill, decode.
# ---------------------------------------------------------------------------


def _site_cache_shape(cfg: ArchConfig, batch: int, seq: int,
                      window: Optional[int],
                      quant: bool = False) -> Dict[str, Tuple]:
    keep = min(seq, window) if window else seq
    kv = (batch, keep, cfg.n_kv, cfg.head_dim)
    if quant:
        sc = (batch, keep, cfg.n_kv, 1)
        return {"k": kv, "v": kv, "ks": sc, "vs": sc}
    return {"k": kv, "v": kv}


def _mamba_cache_shape(cfg: ArchConfig, batch: int) -> Dict[str, Tuple]:
    s = cfg.ssm
    conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
    return {"state": (batch, s.n_heads, s.d_state, s.head_dim),
            "conv": (batch, s.d_conv - 1, conv_dim)}


def cache_struct(cfg: ArchConfig, batch: int, seq: int,
                 dtype=jnp.bfloat16, quant: bool = False) -> PyTree:
    """Shape tree of the decode cache (leading dim = n_groups).

    quant=True stores KV in int8 with f32 per-(token, head) scales —
    halves (vs bf16) the dominant serving buffer; required to fit the MHA
    (kv=40) 32k x 128 cache on a single pod."""
    g = n_groups(cfg)
    f32 = jnp.float32
    kv_dt = jnp.int8 if quant else dtype

    def kv_site(sh: Dict[str, Tuple]) -> Dict[str, Tuple]:
        return {k: ((g,) + v, f32 if k in ("ks", "vs") else kv_dt)
                for k, v in sh.items()}

    if cfg.family == "ssm":
        sh = _mamba_cache_shape(cfg, batch)
        tree = {"blk": {"state": ((g,) + sh["state"], f32),
                        "conv": ((g,) + sh["conv"], dtype)}}
    elif cfg.family == "hybrid":
        p = cfg.hybrid_period
        msh = _mamba_cache_shape(cfg, batch)
        tree = {"shared": kv_site(_site_cache_shape(cfg, batch, seq, None,
                                                    quant)),
                "mamba": {"state": ((g, p) + msh["state"], f32),
                          "conv": ((g, p) + msh["conv"], dtype)}}
    elif cfg.local_global_period == 2:
        tree = {"sub0": kv_site(_site_cache_shape(cfg, batch, seq,
                                                  cfg.window, quant)),
                "sub1": kv_site(_site_cache_shape(cfg, batch, seq, None,
                                                  quant))}
    else:
        tree = {"blk": kv_site(_site_cache_shape(cfg, batch, seq,
                                                 cfg.window, quant))}
    return tree


def _cache_leaf(x) -> bool:
    return isinstance(x, tuple) and isinstance(x[0], tuple)


def cache_abstract(cfg: ArchConfig, batch: int, seq: int,
                   dtype=jnp.bfloat16, quant: bool = False) -> PyTree:
    return jax.tree_util.tree_map(
        lambda sd: jax.ShapeDtypeStruct(*sd),
        cache_struct(cfg, batch, seq, dtype, quant), is_leaf=_cache_leaf)


def cache_init(cfg: ArchConfig, batch: int, seq: int,
               dtype=jnp.bfloat16, quant: bool = False) -> PyTree:
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(*sd),
        cache_struct(cfg, batch, seq, dtype, quant), is_leaf=_cache_leaf)


def cache_specs(cfg: ArchConfig, mesh: Mesh, global_batch: int,
                seq_len: int, quant: bool = False) -> PyTree:
    """PartitionSpecs for the decode cache.

    Batch shards over the fsdp axes when divisible; otherwise (long_500k,
    global_batch=1) the KV *sequence* dim carries the fsdp shard (SP).
    KV heads shard on the TP axis when divisible; when NOT divisible
    (GQA kv=2..8 < 16-way TP) the *sequence* dim takes the model axis
    instead — flash-decoding style sequence-parallel attention, where
    GSPMD turns the softmax statistics and the p@V contraction into small
    per-layer all-reduces.  jit in_shardings require exact divisibility,
    so every mapping is divisibility-checked here."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in fsdp:
        dp *= sizes[a]
    batch_ok = bool(fsdp) and dp > 1 and global_batch % dp == 0
    bax = fsdp if batch_ok else None
    tpn = sizes.get("model", 1)
    has_tp = "model" in names

    def tp_if(div: int):
        return "model" if (has_tp and div % tpn == 0 and div > 0) else None

    def kv_spec(site_window) -> Dict[str, P]:      # [g, B, S, G, hd]
        keep = min(seq_len, site_window) if site_window else seq_len
        kvp = tp_if(cfg.n_kv)
        seq_parts = [] if batch_ok else list(fsdp)
        if kvp is None and has_tp:
            seq_parts.append("model")              # flash-decode SP
        prod = 1
        for a in seq_parts:
            prod *= sizes[a]
        seq_ax = tuple(seq_parts) if (seq_parts and keep % prod == 0) \
            else None
        spec = P(None, bax, seq_ax, kvp, None)
        out = {"k": spec, "v": spec}
        if quant:
            out["ks"] = spec
            out["vs"] = spec
        return out

    def mamba_spec(lead: int):         # state [*,B,H,N,P]; conv [*,B,K-1,C]
        s = cfg.ssm
        conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
        pre = (None,) * lead
        return {"state": P(*pre, bax, tp_if(s.n_heads), None, None),
                "conv": P(*pre, bax, None, tp_if(conv_dim))}

    if cfg.family == "ssm":
        return {"blk": mamba_spec(1)}
    if cfg.family == "hybrid":
        return {"shared": kv_spec(None), "mamba": mamba_spec(2)}
    if cfg.local_global_period == 2:
        return {"sub0": kv_spec(cfg.window), "sub1": kv_spec(None)}
    return {"blk": kv_spec(cfg.window)}


def pad_cache(cfg: ArchConfig, cache: PyTree, max_seq: int) -> PyTree:
    """Grow a prefill cache to ``max_seq`` serving slots.

    KV sites pad the sequence dim (dim 2 of [g, B, S, G, hd]) up to
    min(max_seq, site window); appended slots are unwritten and the rolling
    position formula masks them until the stream reaches them.  SSM state /
    conv tails are length-independent and pass through.  No-op when the
    prefill already filled a window-limited site."""

    def pad_site(site: Dict[str, jnp.ndarray], window) -> Dict[str, jnp.ndarray]:
        out = {}
        for name, kv in site.items():
            target = min(max_seq, window) if window else max_seq
            padlen = target - kv.shape[2]
            if padlen > 0:
                pad = [(0, 0)] * kv.ndim
                pad[2] = (0, padlen)
                kv = jnp.pad(kv, pad)
            out[name] = kv
        return out

    if cfg.family == "ssm":
        return cache
    if cfg.family == "hybrid":
        return {"shared": pad_site(cache["shared"], None),
                "mamba": cache["mamba"]}
    if cfg.local_global_period == 2:
        return {"sub0": pad_site(cache["sub0"], cfg.window),
                "sub1": pad_site(cache["sub1"], None)}
    return {"blk": pad_site(cache["blk"], cfg.window)}


def prefill(cfg: ArchConfig, params: PyTree, batch: Dict[str, jnp.ndarray],
            ctx: Ctx) -> Tuple[jnp.ndarray, PyTree]:
    """Returns (last-position logits [B, V...], cache)."""
    h = _embed(cfg, params, batch, ctx)
    h, cache = run_layers(cfg, params, h, ctx)
    logits = logits_fn(cfg, params, h[:, -1:, :], ctx)
    return logits[:, 0], cache


def decode_step(cfg: ArchConfig, params: PyTree, cache: PyTree,
                tokens: jnp.ndarray, pos: jnp.ndarray, ctx: Ctx,
                vision: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step.  tokens [B, 1] (audio: [B, 1, CB]); pos scalar."""
    batch = {"tokens": tokens}
    h = _embed(cfg, params, batch, ctx)
    h, new_cache = run_layers(cfg, params, h, ctx, cache=cache)
    logits = logits_fn(cfg, params, h, ctx)
    return logits[:, 0], new_cache
