"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060) in pure lax.

Training/prefill uses the paper's *chunked* algorithm: split the sequence
into chunks of length Q; compute intra-chunk outputs as a masked
attention-like quadratic form over decay factors, and pass inter-chunk
state [H, N, P] through a ``lax.scan`` (linear in sequence length — this is
what makes ``long_500k`` runnable for the ssm/hybrid architectures).  The
chunk body is the jnp oracle for ``repro.kernels.ssd_scan``.

Decode carries the state explicitly: O(1) per token, no KV cache.

All SSD internals run in f32; block I/O is bf16.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import SSMConfig


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray,
                chunk: int,
                state_in: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a full sequence.

    x:  [B, S, H, P]  (bf16 ok)       dt: [B, S, H]   (f32, post-softplus)
    a:  [H]           (f32, negative) b/c: [B, S, G, N] (bf16 ok)
    d:  [H]           (f32 skip gain)
    Returns (y [B, S, H, P], final state [B, H, N, P]).
    """
    B, S_orig, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    f32 = jnp.float32

    # Zero-pad to the chunk grid — exact: dt=0 gives decay exp(0)=1 and a
    # zero state update, C=0 gives zero output at pad positions.
    pad = (-S_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = S_orig + pad
    nc = S // chunk

    xc = x.reshape(B, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    bc = b.reshape(B, nc, chunk, G, N).astype(f32)
    cc = c.reshape(B, nc, chunk, G, N).astype(f32)

    # Broadcast groups -> heads.
    bh = jnp.repeat(bc, rep, axis=3)                    # [B,nc,Q,H,N]
    ch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a[None, None, None, :]                   # [B,nc,Q,H]
    cum = jnp.cumsum(da, axis=2)
    total = cum[:, :, -1:, :]                           # [B,nc,1,H]

    # Intra-chunk (masked quadratic form).  The mask must sit INSIDE the
    # exponent: for i < j the raw difference is positive and can overflow
    # to inf, and inf * 0 would poison the whole chunk with NaNs.
    ii = jnp.arange(chunk)
    mask = (ii[:, None] >= ii[None, :])                 # [i, j]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,i,j,H]
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    seg = jnp.exp(diff)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", ch, bh)   # [B,nc,i,j,H]
    w = scores * seg
    w = w * dtc[:, :, None, :, :]                       # dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # Per-chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j x_j^T.
    decay_to_end = jnp.exp(total - cum)                 # [B,nc,Q,H]
    sb = bh * (decay_to_end * dtc)[..., None]           # [B,nc,Q,H,N]
    chunk_states = jnp.einsum("bcjhn,bcjhp->bchnp", sb, xc)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(total[:, :, 0, :])            # [B,nc,H]
    s0 = (jnp.zeros((B, H, N, P), f32) if state_in is None
          else state_in.astype(f32))

    def step(state, inp):
        dec, s_c = inp                                  # [B,H], [B,H,N,P]
        new = state * dec[..., None, None] + s_c
        return new, state                               # emit state *before*

    final, prevs = jax.lax.scan(
        step, s0,
        (chunk_decay.transpose(1, 0, 2), chunk_states.transpose(1, 0, 2, 3, 4)))
    prev_states = prevs.transpose(1, 0, 2, 3, 4)        # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         ch * jnp.exp(cum)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xc.reshape(B, S, H, P) * d[None, None, :, None]
    return y[:, :S_orig].astype(x.dtype), final


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                    d: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token SSD update.

    state: [B, H, N, P]; x: [B, H, P]; dt: [B, H]; b/c: [B, G, N].
    Returns (y [B, H, P], new state).
    """
    B, H, _, _ = state.shape
    G = b.shape[1]
    rep = H // G
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    bh = jnp.repeat(b.astype(f32), rep, axis=1)         # [B,H,N]
    ch = jnp.repeat(c.astype(f32), rep, axis=1)
    dec = jnp.exp(dtf * a[None, :])                     # [B,H]
    upd = (dtf[..., None] * bh)[..., None] * xf[:, :, None, :]   # [B,H,N,P]
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    y = y + xf * d[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv (the short conv in the mamba2 block).
# ---------------------------------------------------------------------------


def causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, C]; w: [K, C] depthwise taps.  Causal (left) padding."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):                                   # K is 4: unrolled
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i][None, None, :].astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv_step(cache: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cache: [B, K-1, C] (previous inputs); xt: [B, C].  Returns
    (yt [B, C], new cache)."""
    k = w.shape[0]
    window = jnp.concatenate([cache, xt[:, None, :]], axis=1)   # [B,K,C]
    yt = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                    w.astype(jnp.float32))
    return yt.astype(xt.dtype), window[:, 1:, :]
