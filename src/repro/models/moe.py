"""Mixture-of-Experts FFN: top-k routing, sort-free capacity dispatch.

Design (TPU production pattern, not the GShard one-hot einsum — that one is
O(T²) in dispatch FLOPs at 32k context):

  1. router logits (f32) -> top_k experts + softmax-over-selected weights;
  2. *sort-free* slotting: a token's slot inside its expert buffer is the
     running count of earlier (token, choice) pairs that picked the same
     expert — one cumsum over a [T*k, E] one-hot, no argsort;
  3. gather tokens into [E, C, D] buffers (capacity C, first-come priority,
     overflow dropped — tests use a lossless capacity factor);
  4. two batched GEMMs over the expert dim (gated SwiGLU);
  5. combine: gather each (token, choice) result and weighted-sum.

Distribution: the surrounding model wraps :func:`moe_ffn` in ``shard_map``
(see repro.models.model) so dispatch indices stay *local* to each data
shard — the cross-device semantics of GSPMD scatter/gather never trigger.
Expert weights are stored [E, D, F] sharded D->data (ZeRO-3) and F->model
(TP); the body all-gathers D (ZeRO gather), computes with local F, and
psums the output over the model axis.  MoE's data-dependent load imbalance
is the same disease the paper's framework treats for search trees — noted
in DESIGN.md §Arch-applicability; capacity + first-come dropping is the
static-shape answer here.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.params import ParamDecl


def moe_decls(d_model: int, cfg: MoEConfig) -> Dict[str, ParamDecl]:
    e, f = cfg.num_experts, cfg.d_ff
    decls = {
        "router": ParamDecl((d_model, e), ("embed", None), jnp.float32),
        "w1": ParamDecl((e, d_model, f), ("experts", "embed", "mlp")),
        "w3": ParamDecl((e, d_model, f), ("experts", "embed", "mlp")),
        "w2": ParamDecl((e, f, d_model), ("experts", "mlp", "embed")),
    }
    if cfg.shared_expert_ff:
        s = cfg.shared_expert_ff
        decls["ws1"] = ParamDecl((d_model, s), ("embed", "mlp"))
        decls["ws3"] = ParamDecl((d_model, s), ("embed", "mlp"))
        decls["ws2"] = ParamDecl((s, d_model), ("mlp", "embed"))
    return decls


def route(x2d: jnp.ndarray, router: jnp.ndarray, cfg: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x2d: [T, D] -> (experts [T, k] int32, weights [T, k] f32)."""
    logits = x2d.astype(jnp.float32) @ router          # [T, E]
    if cfg.router_softcap > 0.0:
        logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
    top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top_vals, axis=-1)
    return top_idx.astype(jnp.int32), weights


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(num_tokens * cfg.top_k * cfg.capacity_factor
                  / cfg.num_experts)
    return max(8, -(-c // 8) * 8)                      # round up to 8


def moe_ffn(x2d: jnp.ndarray, params: Dict[str, jnp.ndarray],
            cfg: MoEConfig, *, tp_axis: Optional[str] = None,
            zero_axes: Optional[Tuple[str, ...]] = None) -> jnp.ndarray:
    """Apply the MoE FFN to [T, D] tokens (local shard inside shard_map,
    or the whole batch when unsharded).

    tp_axis:   mesh axis name the F dim of w1/w3/w2 is sharded over (psum
               the output over it); None = no TP.
    zero_axes: mesh axes the D dim is stored-sharded over (ZeRO-3);
               all-gathered here before compute.  None = already full.
    """
    t, d_local = x2d.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(t, cfg)

    w1, w3, w2 = params["w1"], params["w3"], params["w2"]
    router = params["router"]
    if zero_axes:
        # ZeRO-3 gather of the D (contraction) dim; grads reduce-scatter back.
        for ax in zero_axes:
            w1 = _allgather_dim(w1, 1, ax)
            w3 = _allgather_dim(w3, 1, ax)
            w2 = _allgather_dim(w2, 2, ax)
            router = _allgather_dim(router, 0, ax)

    experts, weights = route(x2d, router, cfg)          # [T,k], [T,k]

    # --- sort-free slotting -------------------------------------------------
    flat_e = experts.reshape(t * k)                     # token-major order
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # [T*k, E]
    slot = (jnp.cumsum(onehot, axis=0) - onehot)        # prior same-expert
    flat_slot = jnp.take_along_axis(slot, flat_e[:, None], axis=1)[:, 0]
    keep = flat_slot < c

    # --- dispatch gather indices: buffer[e, s] = token id (or T = pad row) --
    tok_of_pair = jnp.arange(t * k, dtype=jnp.int32) // k
    write_pos = flat_e * (c + 1) + jnp.where(keep, flat_slot, c)
    buf_tok = jnp.full((e * (c + 1),), t, jnp.int32)
    buf_tok = buf_tok.at[write_pos].set(tok_of_pair, mode="drop")
    buf_tok = buf_tok.reshape(e, c + 1)[:, :c]          # [E, C]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d_local), x2d.dtype)], 0)
    xe = x_pad[buf_tok]                                 # [E, C, D]

    # --- expert GEMMs (batched over E; F possibly TP-sharded) --------------
    h1 = jnp.einsum("ecd,edf->ecf", xe, w1)
    h3 = jnp.einsum("ecd,edf->ecf", xe, w3)
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(h3.dtype) * h3
    # [E, C, D], PARTIAL over the F (tp) shards.  The psum happens after
    # the combine: combine is linear, and reducing [T, D] moves
    # top_k*capacity_factor (2.5x) fewer bytes than reducing [E, C, D]
    # (§Perf iteration, EXPERIMENTS.md).
    ye = jnp.einsum("ecf,efd->ecd", h, w2)

    # --- combine ------------------------------------------------------------
    read_pos = flat_e * c + jnp.clip(flat_slot, 0, c - 1)
    y_flat = ye.reshape(e * c, d_local)
    y_pairs = y_flat[read_pos]                          # [T*k, D]
    y_pairs = jnp.where(keep[:, None], y_pairs, 0).reshape(t, k, d_local)
    out = jnp.einsum("tkd,tk->td", y_pairs.astype(jnp.float32), weights)

    # --- shared (always-on) expert ------------------------------------------
    if cfg.shared_expert_ff:
        ws1, ws3, ws2 = params["ws1"], params["ws3"], params["ws2"]
        if zero_axes:
            for ax in zero_axes:
                ws1 = _allgather_dim(ws1, 0, ax)
                ws3 = _allgather_dim(ws3, 0, ax)
                ws2 = _allgather_dim(ws2, 1, ax)
        hs = (jax.nn.silu((x2d @ ws1).astype(jnp.float32)).astype(x2d.dtype)
              * (x2d @ ws3))
        ys = hs @ ws2                       # partial over tp (F shards)
        out = out + ys.astype(jnp.float32)

    if tp_axis is not None:
        # single bf16 all-reduce of [T, D] (routed + shared partials).
        out = jax.lax.psum(out.astype(jnp.bfloat16), tp_axis)
    return out.astype(x2d.dtype)


def _allgather_dim(x: jnp.ndarray, dim: int, axis_name: str) -> jnp.ndarray:
    return jax.lax.all_gather(x, axis_name, axis=dim, tiled=True)


def moe_ffn_dense_reference(x2d: jnp.ndarray, params: Dict[str, jnp.ndarray],
                            cfg: MoEConfig) -> jnp.ndarray:
    """Oracle: run EVERY expert densely on every token, then mix by router
    weight.  Exponentially wasteful but unambiguous — tests compare moe_ffn
    (lossless capacity) against this."""
    experts, weights = route(x2d, params["router"], cfg)
    h1 = jnp.einsum("td,edf->tef", x2d, params["w1"])
    h3 = jnp.einsum("td,edf->tef", x2d, params["w3"])
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(h3.dtype) * h3
    y = jnp.einsum("tef,efd->ted", h, params["w2"])     # [T, E, D]
    t = x2d.shape[0]
    out = jnp.zeros((t, x2d.shape[1]), jnp.float32)
    for j in range(cfg.top_k):
        sel = y[jnp.arange(t), experts[:, j]]           # [T, D]
        out = out + weights[:, j:j + 1] * sel.astype(jnp.float32)
    if cfg.shared_expert_ff:
        hs = (jax.nn.silu((x2d @ params["ws1"]).astype(jnp.float32))
              .astype(x2d.dtype) * (x2d @ params["ws3"]))
        out = out + (hs @ params["ws2"]).astype(jnp.float32)
    return out.astype(x2d.dtype)
