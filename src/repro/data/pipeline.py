"""Deterministic synthetic token pipeline (stateless, step-indexed PRNG).

Fault-tolerance posture: batch(step) is a pure function of (seed, step), so
a restarted job resumes mid-run with byte-identical data — no iterator
state to checkpoint, no skew between re-joined workers.  This is the same
discipline the solver applies to its search tree (deterministic child
generation, paper §II).

The generator is a shifted-window LM task over a synthetic Zipf-ish
distribution (so losses are learnable — examples train a ~100M model on
it); tokens and labels are emitted pre-shifted.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def batch_keys(seed: int, step: jnp.ndarray):
    base = jax.random.PRNGKey(seed)
    return jax.random.fold_in(base, step)


def _zipfish(key, shape, vocab: int) -> jnp.ndarray:
    """Zipf-flavored token draw: u^4 concentrates mass on small ids."""
    u = jax.random.uniform(key, shape)
    toks = (u ** 4 * (vocab - 3)).astype(jnp.int32) + 2
    return toks


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, seed: int,
                    step: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """One (tokens, labels) batch; labels are next-token shifted.

    Learnable structure: with probability ~1/2 a token repeats a lagged
    token, so a model can beat the unigram entropy — enough signal for the
    end-to-end training example to show a falling loss curve.
    """
    key = batch_keys(seed, step)
    k1, k2 = jax.random.split(key)
    shape = ((batch, seq + 1, cfg.n_codebooks) if cfg.n_codebooks
             else (batch, seq + 1))
    raw = _zipfish(k1, shape, cfg.vocab)
    # Inject copy structure: token[t] = token[t-4] on even positions.
    t = jnp.arange(seq + 1)
    lag = jnp.roll(raw, 4, axis=1)
    mask = (t % 2 == 0)
    mask = mask[None, :, None] if cfg.n_codebooks else mask[None, :]
    toks = jnp.where(mask, lag, raw)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.vision_tokens:
        out["vision"] = (jax.random.normal(
            k2, (batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
            * 0.02)
    return out


def input_abstract(cfg: ArchConfig, batch: int, seq: int
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run)."""
    i32 = jnp.int32
    shape = ((batch, seq, cfg.n_codebooks) if cfg.n_codebooks
             else (batch, seq))
    out = {"tokens": jax.ShapeDtypeStruct(shape, i32),
           "labels": jax.ShapeDtypeStruct(shape, i32)}
    if cfg.vision_tokens:
        out["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return out
