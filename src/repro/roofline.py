"""Three-term roofline analysis from compiled HLO (dry-run artifacts).

``compiled.cost_analysis()`` counts each ``while`` body ONCE (verified
empirically — a scan of 10 matmuls reports the FLOPs of one), so a model
scanned over layers would under-report by ~n_layers.  This module therefore
walks the *scheduled HLO text* itself:

  * builds a per-computation symbol table (%var -> shape),
  * multiplies every op's cost by the product of enclosing loop trip counts
    (XLA annotates ``backend_config={"known_trip_count":{"n":...}}`` on
    ``while`` ops lowered from lax.scan/fori_loop),
  * FLOPs: ``dot`` ops as 2 * prod(output) * prod(contracting dims)
    (+ convolutions, negligible here),
  * HBM bytes: for each top-level fusion/op, operands + outputs (a fusion's
    parameters/results are exactly its HBM traffic on TPU),
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (+ their async -start
    forms, counted once).

Terms (TPU v5e): compute = FLOPs / peak, memory = bytes / HBM_bw,
collective = bytes / ICI_bw — all per device, seconds.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
    "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(
    r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str           # full RHS text (operands + attrs)
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]          # %var -> result type string


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry = ""
    for line in hlo.splitlines():
        if line.startswith(("HloModule", "//")) or not line.strip():
            continue
        if not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                current = Computation(m.group(1), [], {})
                comps[current.name] = current
                if line.strip().startswith("ENTRY"):
                    entry = current.name
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        is_root = line.lstrip().startswith("ROOT ")
        var, rhs = m.group(1), m.group(2)
        # rhs = "TYPE opcode(...)..." ; type may be a tuple "(a, b)".
        rhs = rhs.strip()
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
        else:
            sp = rhs.index(" ")
            type_str, rest = rhs[:sp], rhs[sp + 1:].strip()
        opm = re.match(r"([\w\-]+)", rest)
        opcode = opm.group(1) if opm else ""
        current.symtab[var] = type_str
        current.ops.append(Op(var, type_str, opcode, rest, is_root))
    return comps, entry


def _multipliers(comps: Dict[str, Computation], entry: str
                 ) -> Dict[str, float]:
    """Computation -> product of enclosing trip counts (entry = 1)."""
    mult: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            trip = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.rest)
                trip = float(tm.group(1)) if tm else 1.0
            callees: List[Tuple[str, float]] = []
            for rex, w in ((_BODY_RE, trip), (_COND_RE, trip + 1),
                           (_CALL_RE, 1.0)):
                for name in rex.findall(op.rest):
                    callees.append((name, w))
            bm = _BRANCH_RE.search(op.rest)
            if bm:
                branches = _OPERAND_RE.findall(bm.group(1))
                for bname in branches:
                    callees.append((bname, 1.0 / max(len(branches), 1)))
            for name in _TRUE_FALSE_RE.findall(op.rest):
                callees.append((name, 0.5))
            for name, w in callees:
                nm = m * w
                if mult.get(name, 0.0) < nm:
                    mult[name] = nm
                    stack.append(name)
    return mult


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "copy-start", "copy-done", "partition-id", "replica-id", "iota",
}

#: Ops a TPU compiler fuses into their producers/consumers — counting their
#: operands as HBM traffic would model a machine with no fusion at all
#: (the CPU backend's HLO is barely fused, so the raw per-op sum grossly
#: overestimates TPU HBM bytes).  Elementwise/shape ops are therefore
#: skipped; dots, reductions, scatters/gathers, data movement and
#: while-carried tensors remain counted (write + read ≈ 2x each tensor,
#: which is the correct steady-state traffic model).
_FUSED_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "convert", "compare", "select",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clamp", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "is-finite", "popcnt",
    "broadcast", "reshape", "slice", "rev", "map", "reduce-precision",
    "bitcast-convert", "stochastic-convert", "cosine", "sine", "erf",
    "logistic", "cbrt", "atan2", "remainder", "expm1", "log1p", "copy",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    dots: int = 0
    #: bytes of score-block-shaped tensors (two adjacent equal dims >= 128):
    #: the attention/SSD quadratic intermediates.  Pure-XLA blocked
    #: attention streams them through HBM; the Pallas flash/SSD kernels
    #: (repro.kernels) keep them in VMEM, so the *kernel-adjusted* memory
    #: term subtracts them.  Both are reported.
    score_bytes: float = 0.0

    def terms(self, peak_flops: float, hbm_bw: float, ici_bw: float
              ) -> Dict[str, float]:
        return {
            "compute_s": self.flops / peak_flops,
            "memory_s": self.hbm_bytes / hbm_bw,
            "memory_kernel_adj_s": max(self.hbm_bytes - self.score_bytes, 0.0)
            / hbm_bw,
            "collective_s": self.collective_bytes / ici_bw,
        }


def _operand_bytes(op: Op, symtab: Dict[str, str]) -> int:
    # Operands live inside the first (...) group of rest.
    lp = op.rest.find("(")
    if lp < 0:
        return 0
    depth, rp = 0, len(op.rest)
    for i in range(lp, len(op.rest)):
        depth += op.rest[i] == "("
        depth -= op.rest[i] == ")"
        if depth == 0:
            rp = i
            break
    total = 0
    for name in _OPERAND_RE.findall(op.rest[lp:rp + 1]):
        t = symtab.get(name)
        if t:
            total += _shape_bytes(t)
    return total


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.result_type):
        out_elems *= d
    cm = _CONTRACT_RE.search(op.rest)
    contracting = 1
    if cm:
        lp = op.rest.find("(")
        operands = _OPERAND_RE.findall(op.rest[lp:]) if lp >= 0 else []
        lhs_t = symtab.get(operands[0]) if operands else None
        dims = _shape_dims(lhs_t) if lhs_t else []
        for idx in (cm.group(1).split(",") if cm.group(1) else []):
            i = int(idx)
            if i < len(dims):
                contracting *= dims[i]
    return 2.0 * out_elems * contracting


def _operand_types(op: Op, symtab: Dict[str, str]) -> List[str]:
    lp = op.rest.find("(")
    if lp < 0:
        return []
    depth, rp = 0, len(op.rest)
    for i in range(lp, len(op.rest)):
        depth += op.rest[i] == "("
        depth -= op.rest[i] == ")"
        if depth == 0:
            rp = i
            break
    return [symtab[n] for n in _OPERAND_RE.findall(op.rest[lp:rp + 1])
            if n in symtab]


def _root_of(comp: Computation) -> Optional[Op]:
    for op in comp.ops:
        if op.is_root:
            return op
    return comp.ops[-1] if comp.ops else None


def _op_hbm_bytes(op: Op, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """HBM traffic model for one op.

    In-place accumulator updates (dynamic-update-slice — lax.scan stacking
    its ys, gradient accumulation) touch only the UPDATE slice, not the
    carried buffer: counting the buffer per iteration would charge a scan
    O(n^2) traffic.  The same applies to fusions whose root is a DUS (the
    usual compiled form): the buffer-sized parameter is aliased, so it is
    subtracted and the update counted instead."""
    out_b = _shape_bytes(op.result_type)
    if op.opcode == "dynamic-update-slice":
        ops_t = _operand_types(op, comp.symtab)
        upd = _shape_bytes(ops_t[1]) if len(ops_t) > 1 else 0
        return 2.0 * upd                       # write + later read
    if op.opcode == "dynamic-slice":
        return 2.0 * out_b
    if op.opcode == "fusion":
        cm = _CALL_RE.search(op.rest)
        callee = comps.get(cm.group(1)) if cm else None
        if callee is None:
            return _operand_bytes(op, comp.symtab) + out_b
        operand_b = _fusion_param_bytes(callee)
        root = _root_of(callee)
        if root is not None and root.opcode == "dynamic-update-slice":
            rt = _operand_types(root, callee.symtab)
            upd = _shape_bytes(rt[1]) if len(rt) > 1 else 0
            # the buffer param is aliased in-place: subtract it
            buf = max((_shape_bytes(o.result_type) for o in callee.ops
                       if o.opcode == "parameter"
                       and _shape_bytes(o.result_type) == out_b), default=0)
            return max(operand_b - buf, 0) + 2.0 * upd
        return operand_b + out_b
    return _operand_bytes(op, comp.symtab) + out_b


def _fusion_param_bytes(callee: Computation) -> float:
    """Bytes a fusion actually READS: a parameter consumed only through
    dynamic-slice ops is charged the slice sizes, not the full buffer —
    the compiled form of lax.scan streaming blocks out of a stacked xs
    (charging the stack per iteration would be O(n^2))."""
    params = {o.name: _shape_bytes(o.result_type) for o in callee.ops
              if o.opcode == "parameter"}
    sliced: Dict[str, float] = {}
    other_use = set()
    for o in callee.ops:
        if o.opcode == "parameter":
            continue
        lp = o.rest.find("(")
        if lp < 0:
            continue
        depth, rp = 0, len(o.rest)
        for i in range(lp, len(o.rest)):
            depth += o.rest[i] == "("
            depth -= o.rest[i] == ")"
            if depth == 0:
                rp = i
                break
        names = _OPERAND_RE.findall(o.rest[lp:rp + 1])
        for i, nm in enumerate(names):
            if nm not in params:
                continue
            if o.opcode in ("dynamic-slice", "slice") and i == 0:
                sliced[nm] = sliced.get(nm, 0.0) \
                    + _shape_bytes(o.result_type)
            else:
                other_use.add(nm)
    total = 0.0
    for nm, full in params.items():
        if nm in sliced and nm not in other_use:
            total += min(sliced[nm], full)
        else:
            total += full
    return total


def analyze_hlo(hlo: str) -> RooflineCounts:
    comps, entry = parse_computations(hlo)
    mult = _multipliers(comps, entry)
    # Ops inside fusion callees count FLOPs (a dot fused with its epilogue
    # is still a dot) but not HBM bytes (intermediate values stay on-chip).
    fusion_callees = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                cm = _CALL_RE.search(op.rest)
                if cm:
                    fusion_callees.add(cm.group(1))
    counts = RooflineCounts()
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue                        # unreachable (dead) computation
        in_fusion = cname in fusion_callees
        for op in comp.ops:
            if op.opcode == "dot":
                counts.flops += m * _dot_flops(op, comp.symtab)
                counts.dots += 1
            is_coll = any(op.opcode.startswith(c) for c in _COLLECTIVES)
            if is_coll and not op.opcode.endswith("-done"):
                b = _operand_bytes(op, comp.symtab)
                counts.collective_bytes += m * b
                kind = op.opcode.replace("-start", "")
                counts.per_collective[kind] = (
                    counts.per_collective.get(kind, 0.0) + m * b)
            if (in_fusion or op.opcode in _SKIP_BYTES_OPS or is_coll
                    or op.opcode in _FUSED_ELEMENTWISE):
                continue
            b = m * _op_hbm_bytes(op, comp, comps)
            counts.hbm_bytes += b
            if _in_kernel_region(op, comps):
                counts.score_bytes += b
    return counts


#: einsum labels unique to the attention / SSD inner blocks (the regions a
#: Pallas kernel replaces); ops whose metadata op_name descends from them
#: are intra-kernel traffic.
_KERNEL_MARKERS = ("bqgrd", "bgrqk", "bcihn", "bcijh", "bcjhp", "bchnp")


def _in_kernel_region(op: Op, comps: Dict[str, Computation]) -> bool:
    if any(k in op.rest for k in _KERNEL_MARKERS):
        return True
    if op.opcode == "fusion":
        cm = _CALL_RE.search(op.rest)
        callee = comps.get(cm.group(1)) if cm else None
        if callee and any(any(k in o.rest for k in _KERNEL_MARKERS)
                          for o in callee.ops):
            return True
    dims = _shape_dims(op.result_type)
    return any(dims[i] == dims[i + 1] and dims[i] >= 128
               for i in range(len(dims) - 1))


def model_flops(cfg, tokens: int, is_train: bool) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (2 fwd + 4 bwd per param-token);
    serving counts 2·N_active·D."""
    n = cfg.active_param_count()
    return (6.0 if is_train else 2.0) * n * tokens
