"""Version shims for the jax APIs that moved between 0.4.x and >= 0.5.

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map``;
* its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.

Everything in-repo imports ``shard_map`` from here and passes the check
flag via ``check=``.
"""

from __future__ import annotations

import inspect

import jax

try:
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def axis_size(name: str):
    """``lax.axis_size`` (jax >= 0.5) with the 0.4.x psum fallback; only
    valid inside a collective context (shard_map / pmap / vmap axis)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    import jax.numpy as jnp
    return jax.lax.psum(jnp.int32(1), name)
