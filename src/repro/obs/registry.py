"""Lightweight in-process metrics registry (DESIGN.md §8).

Three instrument types over one namespace:

* :class:`Counter` — monotone totals (``inc(amount, **labels)``);
* :class:`Gauge` — last-write-wins level (``set(value, **labels)``);
* :class:`Histogram` — bucketed distribution (``observe(value, **labels)``)
  with fixed upper bounds plus a ``+Inf`` overflow bucket, carrying
  count and sum like a Prometheus histogram.

Labels are keyword arguments; each distinct label set is an independent
series under the instrument's name.  Instrument creation is idempotent —
asking for an existing name returns the same instrument (a type mismatch
raises) — so collectors can declare their instruments unconditionally.

Zero-cost when disabled: ``MetricsRegistry(enabled=False)`` hands every
request the shared no-op instrument of the right type, so instrumented
code paths pay one attribute call and nothing else.  ``snapshot()``
returns a :class:`MetricsSnapshot` — an immutable deep copy safe to hold
across further updates (it is what ``Solver.metrics()`` /
``SolverService.metrics()`` and ``ProgressEvent.metrics`` expose).

Everything here is plain host-side Python — no jax imports, nothing on
the device path.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsSnapshot",
]

#: Default histogram bucket upper bounds (powers of two suit depths/sizes).
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter; one value per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0)


class Gauge:
    """Last-write-wins level; one value per label set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = value

    def value(self, **labels) -> Optional[float]:
        return self._values.get(_label_key(labels))


class Histogram:
    """Bucketed distribution with count/sum, per label set.

    ``buckets`` are inclusive upper bounds; observations above the last
    bound land in the implicit ``+Inf`` bucket.  Bucket counts are
    NON-cumulative (each observation increments exactly one bucket).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(
                f"histogram {name} needs ascending buckets, got {buckets}")
        self.name, self.help = name, help
        self.buckets = tuple(buckets)
        # key -> [bucket counts..., +Inf count, total count, total sum]
        self._series: Dict[LabelKey, list] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        row = self._series.get(key)
        if row is None:
            row = self._series[key] = [0] * (len(self.buckets) + 1) + [0, 0.0]
        for i, bound in enumerate(self.buckets):
            if value <= bound:
                row[i] += 1
                break
        else:
            row[len(self.buckets)] += 1
        row[-2] += 1
        row[-1] += value

    def value(self, **labels) -> Optional[dict]:
        row = self._series.get(_label_key(labels))
        if row is None:
            return None
        return {
            "count": row[-2],
            "sum": row[-1],
            "buckets": dict(zip([*map(str, self.buckets), "+Inf"],
                                row[:len(self.buckets) + 1])),
        }


class _NullInstrument:
    """Shared do-nothing instrument handed out by a disabled registry."""

    name, help, kind = "<disabled>", "", "null"

    def inc(self, amount: float = 1, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, **labels) -> None:
        return None


_NULL = _NullInstrument()


class MetricsSnapshot:
    """Immutable point-in-time copy of a registry's series.

    ``value(name, **labels)`` returns the series value (0 for a counter
    that never incremented, None for an unknown gauge/histogram series);
    ``to_dict()`` renders everything as plain JSON-able data.
    """

    def __init__(self, data: Dict[str, dict]):
        self._data = data

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._data))

    def value(self, name: str, **labels):
        entry = self._data.get(name)
        if entry is None:
            return 0
        got = entry["series"].get(_label_key(labels))
        if got is None:
            return 0 if entry["kind"] == "counter" else None
        return got

    def to_dict(self) -> dict:
        out = {}
        for name, entry in sorted(self._data.items()):
            out[name] = {
                "kind": entry["kind"],
                "series": [
                    {"labels": dict(key), "value": val}
                    for key, val in sorted(entry["series"].items())
                ],
            }
        return out


class MetricsRegistry:
    """One namespace of instruments; disabled registries are no-ops."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._instruments: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        if not self.enabled:
            return _NULL
        inst = self._instruments.get(name)
        if inst is not None:
            if not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}, "
                    f"requested {cls.kind}")
            return inst
        inst = cls(name, help, **kw)
        self._instruments[name] = inst
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def snapshot(self) -> MetricsSnapshot:
        data: Dict[str, dict] = {}
        for name, inst in self._instruments.items():
            if isinstance(inst, Histogram):
                series = {key: inst.value(**dict(key))
                          for key in inst._series}
            else:
                series = dict(inst._values)
            data[name] = {"kind": inst.kind, "series": series}
        return MetricsSnapshot(data)
