"""Search telemetry for the solver (DESIGN.md §8).

Three small host-side layers, wired through ``repro.solver.Solver`` and
``repro.service.SolverService`` behind ``SolverConfig.metrics`` /
``SolverConfig.trace_path``:

* :mod:`repro.obs.registry` — a lightweight metrics registry
  (counters / gauges / histograms with labels) whose disabled form hands
  out shared no-op instruments, so instrumentation is zero-cost when
  telemetry is off;
* :mod:`repro.obs.trace` — the JSONL trace writer and the per-kind record
  schema it validates against (``tools/trace_report.py`` consumes these
  traces and re-validates with the same tables);
* :mod:`repro.obs.collect` — the per-round collector both drivers call at
  round boundaries.  Every number it reports is derived on the host from
  arrays the round loop already materializes (lane counters, the
  open-work vector, the incumbent table), so collection adds no device
  syncs to the hot path and the search tree is bit-identical with
  telemetry on or off (asserted in ``tests/test_obs.py``).
"""

from repro.obs.collect import RoundCollector
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                MetricsSnapshot)
from repro.obs.trace import (TRACE_KINDS, TRACE_SCHEMA_VERSION, TraceError,
                             TraceWriter, read_trace, validate_record)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsSnapshot",
    "RoundCollector",
    "TRACE_KINDS",
    "TRACE_SCHEMA_VERSION",
    "TraceError",
    "TraceWriter",
    "read_trace",
    "validate_record",
]
