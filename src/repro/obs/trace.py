"""JSONL solve-trace schema + writer (DESIGN.md §8).

One trace is a sequence of JSON records, one per line, each carrying its
kind under ``"t"``.  :data:`TRACE_KINDS` is the single source of truth
for the schema — the writer validates every record at write time and
``tools/trace_report.py`` re-validates with the same tables when it
reads, so a malformed trace fails loudly at BOTH ends (the CI
trace-smoke step gates on the reader's exit status).

Record kinds (``[]`` marks fields the emitters always include but the
schema treats as optional, for forward compatibility):

  meta       schema, mode ("solve"|"service"), lanes, slots
             [steps_per_round, fused_steps, backend, config]
  round      round, open, active, nodes, steal_req, steal_recv,
             donated, inst_nodes
             [steal_recv_cross, steps, dispatches, ship_depths, best,
             queue_depth]  — every count is a DELTA over the jitted
             round (host-side installs are excluded from steal counts)
  incumbent  round, inst, best        [rid]
  admit      round, rid               [slot, waited]
  retire     round, rid               [best, waited, ran]
  expire     round, rid               [best, waited, ran]
  cancel     round, rid               [best, waited, ran]
  reject     round, rid               [reason]
  resize     round, lanes, devices    — the service re-laid its pool onto
             a different mesh / lane count (per-lane totals collapse onto
             lane 0, mirroring the engine's carried counters, so summary
             ledgers stay reconcilable across elastic events)
  summary    rounds, nodes, lane_nodes, inst_nodes
             [round, best, lane_recv, lane_req, lane_donated,
             lane_cross, steps, dispatches]  — per-lane/-instance totals
             accumulated from the round deltas (a drain-again service
             appends a fresh summary; readers use the LAST one)

Unknown kinds and missing required fields raise :class:`TraceError`;
unknown EXTRA fields are allowed so the schema can grow without breaking
old readers.
"""

from __future__ import annotations

import json
from typing import Dict, FrozenSet, List

__all__ = [
    "TRACE_KINDS",
    "TRACE_SCHEMA_VERSION",
    "TraceError",
    "TraceWriter",
    "read_trace",
    "validate_record",
]

TRACE_SCHEMA_VERSION = 1

_LIFECYCLE = frozenset({"round", "rid"})

#: kind -> required fields (beyond the ``"t"`` discriminator itself).
TRACE_KINDS: Dict[str, FrozenSet[str]] = {
    "meta": frozenset({"schema", "mode", "lanes", "slots"}),
    "round": frozenset({"round", "open", "active", "nodes", "steal_req",
                        "steal_recv", "donated", "inst_nodes"}),
    "incumbent": frozenset({"round", "inst", "best"}),
    "admit": _LIFECYCLE,
    "retire": _LIFECYCLE,
    "expire": _LIFECYCLE,
    "cancel": _LIFECYCLE,
    "reject": _LIFECYCLE,
    "resize": frozenset({"round", "lanes", "devices"}),
    "summary": frozenset({"rounds", "nodes", "lane_nodes", "inst_nodes"}),
}


class TraceError(ValueError):
    """A record violating :data:`TRACE_KINDS`, or an unreadable trace."""


def validate_record(record: dict) -> None:
    """Raise :class:`TraceError` unless ``record`` satisfies the schema."""
    kind = record.get("t")
    if kind is None:
        raise TraceError(f"record has no 't' kind field: {record!r}")
    required = TRACE_KINDS.get(kind)
    if required is None:
        raise TraceError(
            f"unknown trace record kind {kind!r} (known: "
            f"{', '.join(sorted(TRACE_KINDS))})")
    missing = [f for f in sorted(required) if f not in record]
    if missing:
        raise TraceError(
            f"{kind!r} record missing required fields {missing}: {record!r}")


class TraceWriter:
    """Append-only JSONL writer, schema-validated per record.

    Every write flushes, so a crash mid-run leaves a readable prefix and
    long-lived services never need an explicit close to be inspectable.
    ``None``-valued fields are dropped from the record.
    """

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def write(self, kind: str, **fields) -> None:
        record = {"t": kind}
        record.update((k, v) for k, v in fields.items() if v is not None)
        validate_record(record)
        self._f.write(json.dumps(record, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_trace(path: str) -> List[dict]:
    """Parse and validate a whole trace; raises :class:`TraceError` with
    the 1-based line number on the first bad line."""
    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                record = json.loads(line)
            except ValueError as e:
                raise TraceError(f"{path}:{lineno}: not JSON: {e}") from e
            if not isinstance(record, dict):
                raise TraceError(
                    f"{path}:{lineno}: record is not an object")
            try:
                validate_record(record)
            except TraceError as e:
                raise TraceError(f"{path}:{lineno}: {e}") from None
            records.append(record)
    return records
