"""Per-round telemetry collector shared by both drivers (DESIGN.md §8).

One :class:`RoundCollector` instance rides along a ``Solver.solve`` run
or a ``SolverService``; the driver calls it at round boundaries:

  start(lanes)                  once, after init/restore (baseline)
  before_round(lanes, dirty)    after host-side lane surgery (admission,
                                pending-pool installs) — refreshes the
                                baseline when ``dirty`` so steal counts
                                measure the jitted round ONLY
  after_round(round, lanes, …)  after the jitted round — computes deltas,
                                updates the metrics registry, appends
                                trace records; returns the per-instance
                                node delta (the service reuses it for
                                node-budget accounting)
  lifecycle(kind, …)            admit/retire/expire/cancel/reject hooks
  finish(rounds, best)          writes the trace ``summary`` record

Collection cost model: everything is derived from the per-lane counters
the engine already maintains on device (``nodes``/``t_s``/``t_r``/
``donated``/``t_c``, the ``active``/``inst``/``base`` control arrays and
the incumbent table).  Those are O(W) int32 arrays pulled to host once
per round — after the round's own open-work sync, so no NEW device syncs
land on the hot path, and nothing here feeds back into device state: the
search tree is bit-identical with telemetry on or off.

Shipped-subtree depth: a lane whose ``t_s`` rose this round received a
stolen task, and ``base`` is exactly the installed task's depth — so the
ship-size histogram (subtree depth ≈ log-size proxy) costs nothing
extra.  Kernel dispatches are ``ceil(steps / fused_steps)`` per round —
the expand loop launches one fused group per iteration (DESIGN.md §5.5).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.api import INF_VALUE
from repro.obs.registry import MetricsRegistry, MetricsSnapshot
from repro.obs.trace import TRACE_SCHEMA_VERSION, TraceWriter

__all__ = ["RoundCollector"]

# The incumbent watermark starts at the engine's "no solution" sentinel so
# a slot still at INF_VALUE never registers as an improvement.
_INF = int(INF_VALUE)

#: Subtree-depth buckets for the shipped-task histogram.
_SHIP_BUCKETS = (1, 2, 4, 8, 16, 32, 64)
#: Round-count buckets for scheduler wait/run histograms.
_ROUND_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


class RoundCollector:
    """Host-side per-round metrics + trace collection for one run."""

    def __init__(self, *, mode: str, lanes: int, slots: int,
                 steps_per_round: int, fused_steps: int = 1,
                 backend: str = "jnp", devices: int = 1,
                 registry: Optional[MetricsRegistry] = None,
                 trace: Optional[TraceWriter] = None):
        if mode not in ("solve", "service"):
            raise ValueError(f"mode must be 'solve' or 'service', got {mode!r}")
        self.mode = mode
        self.num_lanes = int(lanes)
        self.slots = int(slots)
        self.devices = max(1, int(devices))   # lane pool partitions (mesh)
        self.fused_steps = max(1, int(fused_steps))
        self.registry = registry if registry is not None else MetricsRegistry()
        self.trace = trace

        r = self.registry
        self.c_rounds = r.counter("engine_rounds", "service/solve rounds run")
        self.c_nodes = r.counter("engine_nodes", "search nodes expanded")
        self.c_steps = r.counter("engine_steps", "engine steps executed")
        self.c_dispatches = r.counter(
            "engine_dispatches",
            "fused step-group launches (ceil(steps/fused_steps) per round)")
        self.c_steal_req = r.counter("steal_requests",
                                     "task requests made (paper T_R)")
        self.c_steal_recv = r.counter(
            "steal_received",
            "tasks received via stealing (paper T_S), by scope label")
        self.c_donated = r.counter("steal_donated", "tasks donated")
        self.c_incumbent = r.counter("incumbent_improvements",
                                     "per-instance incumbent improvements")
        self.g_util = r.gauge("lane_utilization",
                              "active-lane fraction at the last round end")
        self.g_open = r.gauge("open_work", "total open work at last round end")
        self.h_ship = r.histogram("steal_ship_depth",
                                  "depth of shipped subtree roots",
                                  buckets=_SHIP_BUCKETS)
        self.g_dev_nodes = r.gauge(
            "device_nodes", "nodes expanded last round, per device shard")
        self.g_dev_active = r.gauge(
            "device_active_lanes", "active lanes at round end, per device")
        if mode == "service":
            self.g_queue = r.gauge("service_queue_depth",
                                   "queued (unadmitted) requests")
            self.h_wait = r.histogram("service_wait_rounds",
                                      "rounds queued before admission",
                                      buckets=_ROUND_BUCKETS)
            self.h_run = r.histogram("service_run_rounds",
                                     "rounds from admission to resolution",
                                     buckets=_ROUND_BUCKETS)

        self._base: Optional[Dict[str, np.ndarray]] = None
        self._best_seen = np.full((self.slots,), _INF, np.int64)
        self._inst_nodes = np.zeros((self.slots,), np.int64)
        self._lane = {k: np.zeros((self.num_lanes,), np.int64)
                      for k in ("nodes", "recv", "req", "donated", "cross")}
        self._steps = 0
        self._dispatches = 0
        self._rounds_seen = 0
        if trace is not None:
            trace.write("meta", schema=TRACE_SCHEMA_VERSION, mode=mode,
                        lanes=self.num_lanes, slots=self.slots,
                        steps_per_round=int(steps_per_round),
                        fused_steps=self.fused_steps, backend=backend,
                        devices=self.devices)

    # -- round boundaries ---------------------------------------------------

    def _read(self, lanes) -> Dict[str, np.ndarray]:
        return {
            "nodes": np.asarray(lanes.nodes, np.int64),
            "t_s": np.asarray(lanes.t_s, np.int64),
            "t_r": np.asarray(lanes.t_r, np.int64),
            "donated": np.asarray(lanes.donated, np.int64),
            "t_c": np.asarray(lanes.t_c, np.int64),
            "steps": np.asarray(lanes.steps, np.int64).reshape(()),
        }

    def start(self, lanes) -> None:
        """Capture the delta baseline (call after init or restore, so a
        restored checkpoint's carried totals never count as this run's)."""
        self._base = self._read(lanes)

    def before_round(self, lanes, dirty: bool) -> None:
        """Refresh the baseline iff host-side surgery touched the lanes
        since ``after_round`` (admissions and pool installs bump ``t_s``;
        without the refresh they would masquerade as steals)."""
        if dirty or self._base is None:
            self._base = self._read(lanes)

    def after_round(self, round_no: int, lanes, open_total: int, *,
                    queue_depth: int = 0,
                    slot_rids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Ingest one finished jitted round; returns int64[K] node deltas."""
        cur = self._read(lanes)
        base = self._base if self._base is not None else {
            k: np.zeros_like(v) for k, v in cur.items()}
        d_nodes = cur["nodes"] - base["nodes"]
        d_recv = cur["t_s"] - base["t_s"]
        d_req = cur["t_r"] - base["t_r"]
        d_don = cur["donated"] - base["donated"]
        d_cross = cur["t_c"] - base["t_c"]
        d_steps = int(cur["steps"] - base["steps"])
        self._base = cur

        inst = np.asarray(lanes.inst)
        active = np.asarray(lanes.active)
        lane_base = np.asarray(lanes.base)
        best = np.asarray(lanes.best)

        inst_delta = np.zeros((self.slots,), np.int64)
        bound = inst >= 0
        np.add.at(inst_delta, inst[bound], d_nodes[bound])
        self._inst_nodes += inst_delta
        for key, d in (("nodes", d_nodes), ("recv", d_recv), ("req", d_req),
                       ("donated", d_don), ("cross", d_cross)):
            self._lane[key] += d
        dispatches = -(-d_steps // self.fused_steps) if d_steps > 0 else 0
        self._steps += d_steps
        self._dispatches += dispatches
        self._rounds_seen += 1
        ship_depths = [int(d) for d in lane_base[d_recv > 0]]

        self.c_rounds.inc()
        self.c_nodes.inc(int(d_nodes.sum()))
        self.c_steps.inc(d_steps)
        self.c_dispatches.inc(dispatches)
        self.c_steal_req.inc(int(d_req.sum()))
        n_cross = int(d_cross.sum())
        self.c_steal_recv.inc(int(d_recv.sum()) - n_cross, scope="intra")
        self.c_steal_recv.inc(n_cross, scope="cross")
        self.c_donated.inc(int(d_don.sum()))
        self.g_util.set(float(active.mean()) if active.size else 0.0)
        self.g_open.set(int(open_total))
        for depth in ship_depths:
            self.h_ship.observe(depth)
        if self.mode == "service":
            self.g_queue.set(int(queue_depth))

        # Per-device lane metrics: the pool shards its leading dim evenly
        # over the mesh, so device d owns lanes [d*W/D, (d+1)*W/D).
        dev_nodes = dev_active = None
        if self.devices > 1 and self.num_lanes % self.devices == 0:
            dev_nodes = d_nodes.reshape(self.devices, -1).sum(axis=1)
            dev_active = active.reshape(self.devices, -1).sum(axis=1)
            for d in range(self.devices):
                self.g_dev_nodes.set(int(dev_nodes[d]), device=d)
                self.g_dev_active.set(int(dev_active[d]), device=d)

        improved = []
        for slot in range(self.slots):
            b = int(best[slot])
            if b < self._best_seen[slot]:
                self._best_seen[slot] = b
                rid = None
                if slot_rids is not None and int(slot_rids[slot]) >= 0:
                    rid = int(slot_rids[slot])
                self.c_incumbent.inc()
                improved.append((slot, b, rid))

        if self.trace is not None:
            self.trace.write(
                "round", round=int(round_no), open=int(open_total),
                active=int(active.sum()), nodes=int(d_nodes.sum()),
                steal_req=int(d_req.sum()), steal_recv=int(d_recv.sum()),
                steal_recv_cross=n_cross, donated=int(d_don.sum()),
                steps=d_steps, dispatches=dispatches,
                inst_nodes=[int(x) for x in inst_delta],
                ship_depths=ship_depths, best=[int(b) for b in best],
                queue_depth=int(queue_depth),
                dev_nodes=(None if dev_nodes is None
                           else [int(x) for x in dev_nodes]),
                dev_active=(None if dev_active is None
                            else [int(x) for x in dev_active]))
            for slot, b, rid in improved:
                self.trace.write("incumbent", round=int(round_no), inst=slot,
                                 best=b, rid=rid)
        return inst_delta

    # -- elastic events -----------------------------------------------------

    def resize(self, num_lanes: int, *, devices: int,
               round_no: int) -> None:
        """Re-shape the per-lane accounting after an elastic pool resize.

        Mirrors the engine's carried-counter convention (checkpoint
        restore / ``repartition`` sum each counter onto lane 0): the
        accumulated per-lane totals collapse onto lane 0 of the new
        layout, so the summary ledger — sum(lane_nodes) == nodes ==
        sum(inst_nodes) — stays exact across any number of resizes.  The
        delta baseline is dropped; the driver re-baselines via
        ``before_round(dirty=True)`` on the rebuilt lanes.
        """
        self.num_lanes = int(num_lanes)
        self.devices = max(1, int(devices))
        for key, old in self._lane.items():
            carried = np.zeros((self.num_lanes,), np.int64)
            carried[0] = old.sum()
            self._lane[key] = carried
        self._base = None
        if self.trace is not None:
            self.trace.write("resize", round=int(round_no),
                             lanes=self.num_lanes, devices=self.devices)

    # -- request lifecycle (service) ----------------------------------------

    def lifecycle(self, kind: str, *, round_no: int, rid: int,
                  slot: Optional[int] = None, best: Optional[int] = None,
                  waited: Optional[int] = None, ran: Optional[int] = None,
                  reason: Optional[str] = None) -> None:
        """One request transition: histogram wait/run rounds and append the
        trace record.  An admitted slot's incumbent watermark resets so the
        next tenant's improvements are reported from scratch."""
        if kind == "admit":
            if slot is not None:
                self._best_seen[slot] = _INF
            if waited is not None and self.mode == "service":
                self.h_wait.observe(int(waited))
        elif kind in ("retire", "expire", "cancel"):
            if ran is not None and self.mode == "service":
                self.h_run.observe(int(ran))
        if self.trace is not None:
            self.trace.write(kind, round=int(round_no), rid=int(rid),
                             slot=slot, best=best, waited=waited, ran=ran,
                             reason=reason)

    # -- wrap-up ------------------------------------------------------------

    def finish(self, *, rounds: int,
               best: Optional[List[int]] = None) -> None:
        """Append the trace ``summary`` (per-lane/-instance totals this run).
        Callable repeatedly — a service summarizes after every drain and
        readers take the last summary."""
        if self.trace is not None:
            self.trace.write(
                "summary", round=int(rounds), rounds=self._rounds_seen,
                nodes=int(self._lane["nodes"].sum()),
                best=best,
                lane_nodes=[int(x) for x in self._lane["nodes"]],
                lane_recv=[int(x) for x in self._lane["recv"]],
                lane_req=[int(x) for x in self._lane["req"]],
                lane_donated=[int(x) for x in self._lane["donated"]],
                lane_cross=[int(x) for x in self._lane["cross"]],
                inst_nodes=[int(x) for x in self._inst_nodes],
                steps=self._steps, dispatches=self._dispatches)

    def close(self) -> None:
        if self.trace is not None:
            self.trace.close()

    def snapshot(self) -> MetricsSnapshot:
        return self.registry.snapshot()
