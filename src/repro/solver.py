"""The Solver session API — one front door for serial, distributed and
service solves.

The paper's framework has three execution paths (a serial oracle, the
distributed BSP engine, and the multi-tenant solver service) which used to
be driven by three divergent call surfaces: a 12-kwarg
``core.distributed.solve``, a ``SolverService.__init__`` with its own
kwargs, and hand-rolled ``serial_rb`` calls.  This module replaces all
three with one session object (DESIGN.md §6)::

    cfg = SolverConfig(lanes=64, steps_per_round=64, backend="pallas")
    solver = Solver(cfg)

    res = solver.solve(registry.problem("vc", "reg:48:4:1"))   # distributed
    ref = solver.oracle(registry.problem("vc", "reg:48:4:1"))  # serial
    svc = solver.serve(max_n=32, slots=4)                      # service
    assert res.stats.best == ref.best

``SolverConfig`` is frozen and validated at construction; problem-dependent
checks (kernel-backend capabilities, checkpoint compatibility) happen when
the config first meets a problem.  Progress reporting is a typed
:class:`ProgressEvent` stream (``on_event``) shared by the distributed
driver and the service driver — the generalization of the old ``on_round``
callback.

The legacy entry points (``repro.core.distributed.solve(...)`` kwargs and
direct ``SolverService(...)`` construction) remain as thin shims over this
module and emit ``DeprecationWarning``; results are bitwise-identical
because both run the exact same round loop below.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import registry as _registry
from repro.core.api import BinaryProblem
from repro.core.distributed import (SolveStats, _gather_lanes, _shard_lanes,
                                    make_distributed_round, make_round)
from repro.core.engine import Lanes, init_lanes
from repro.core.serial import serial_rb

__all__ = [
    "ConfigError",
    "EVENT_KINDS",
    "OracleResult",
    "ProgressEvent",
    "SolveResult",
    "Solver",
    "SolverConfig",
    "SolveStats",
    "emit",
]


class ConfigError(ValueError):
    """An invalid :class:`SolverConfig`, or one incompatible with the
    problem it is being applied to."""


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Frozen execution policy for a solver session.

    Attributes:
      lanes: engine lanes per device (total lanes = lanes × #devices).
      steps_per_round: engine steps between steal/collective phases (R).
      max_rounds: hard round budget before the drive aborts.
      mesh: device mesh, or None (single device) — honored by both
        :meth:`Solver.solve` and the sharded service (:meth:`Solver.serve`).
      max_ship: cross-device tasks shipped per device per round.
      bootstrap_rounds / bootstrap_steps: short ramp-up rounds that flood
        initial tasks (the paper's GETPARENT topology analogue).
      backend: node-evaluation kernel backend ("jnp" | "pallas"), validated
        against the problem family's registered capabilities at build time.
      checkpoint_every / checkpoint_path: periodic checkpointing policy
        (``checkpoint_every > 0`` requires a path).
      resume_from: checkpoint to restore before solving (elastic: any lane
        count; the instance-slot count must match the problem).
      scheduler: service admission policy name ("priority" | "sjf" |
        "fifo" — ``repro.service.scheduler.SCHEDULERS``), validated
        against the registered policies when the config meets
        :meth:`Solver.serve`.
      fused_steps: engine steps fused per expand-loop iteration (S; the
        multi-step round kernel of DESIGN.md §5.5).  Tree-identical for
        any S — it only amortizes per-step dispatch — so it is a pure
        execution knob like ``backend``.
      trace_path: write a JSONL telemetry trace here (``repro.obs.trace``
        schema; render with ``tools/trace_report.py``).  Collection is
        host-side from values the round loop already materializes, so the
        search tree is bit-identical with tracing on or off (DESIGN.md
        §8).
      metrics: collect an in-process metrics registry, queryable as a
        ``MetricsSnapshot`` via ``Solver.metrics()`` /
        ``SolverService.metrics()`` and attached to "round"/"done"
        :class:`ProgressEvent`\\ s.  Same host-side-only guarantee as
        ``trace_path``.
      autoscale: an ``repro.service.scheduler.AutoscalePolicy`` (or None)
        — service mode only.  Each round the driver asks the policy for a
        target device count keyed on the admission queue depth and
        resizes the mesh elastically (``SolverService.resize``, an
        in-memory W' ≠ W checkpoint/restore).  Ignored by
        :meth:`Solver.solve`, whose device count is fixed by ``mesh``.
    """

    lanes: int = 32
    steps_per_round: int = 64
    max_rounds: int = 100000
    mesh: Optional[Mesh] = None
    max_ship: int = 16
    bootstrap_rounds: int = 0
    bootstrap_steps: int = 8
    backend: str = "jnp"
    checkpoint_every: int = 0
    checkpoint_path: Optional[str] = None
    resume_from: Optional[str] = None
    scheduler: str = "priority"
    fused_steps: int = 1
    trace_path: Optional[str] = None
    metrics: bool = False
    autoscale: Optional[Any] = None

    def __post_init__(self):
        if self.lanes < 1:
            raise ConfigError(f"lanes must be >= 1, got {self.lanes}")
        if self.steps_per_round < 1:
            raise ConfigError(
                f"steps_per_round must be >= 1, got {self.steps_per_round}")
        if self.max_ship < 1:
            raise ConfigError(f"max_ship must be >= 1, got {self.max_ship}")
        if self.bootstrap_rounds < 0 or self.bootstrap_steps < 1:
            raise ConfigError(
                f"bad bootstrap policy: rounds={self.bootstrap_rounds} "
                f"steps={self.bootstrap_steps}")
        if self.checkpoint_every < 0:
            raise ConfigError(
                f"checkpoint_every must be >= 0, got {self.checkpoint_every}")
        if self.checkpoint_every and not self.checkpoint_path:
            raise ConfigError(
                "checkpoint_every > 0 requires checkpoint_path")
        if not isinstance(self.backend, str) or not self.backend:
            raise ConfigError(f"backend must be a name, got {self.backend!r}")
        if not isinstance(self.scheduler, str) or not self.scheduler:
            raise ConfigError(
                f"scheduler must be a policy name, got {self.scheduler!r}")
        if self.fused_steps < 1:
            raise ConfigError(
                f"fused_steps must be >= 1, got {self.fused_steps}")
        if self.trace_path is not None and (
                not isinstance(self.trace_path, str) or not self.trace_path):
            raise ConfigError(
                f"trace_path must be a path, got {self.trace_path!r}")


#: Every ProgressEvent kind either driver may emit.  Frozen on purpose:
#: constructing an event with any other kind raises, so a typo'd kind
#: fails at the emitter instead of flowing silently past consumers.
EVENT_KINDS = frozenset({
    "round", "checkpoint", "admit", "incumbent", "retire", "reject",
    "cancel", "expire", "resize", "done",
})


@dataclasses.dataclass(frozen=True)
class ProgressEvent:
    """One typed progress notification from either driver.

    ``kind`` is one of:
      "round"      — a solve/service round finished (``round``, ``open_work``,
                     ``best``; solve rounds also carry ``lanes``);
      "checkpoint" — a checkpoint was written (``path``);
      "admit"      — the service admitted request ``rid`` into a slot;
      "incumbent"  — request ``rid``'s anytime incumbent improved to
                     ``best`` (the service's per-request progress stream);
      "retire"     — the service retired request ``rid`` (``best`` is its
                     optimum);
      "reject"     — ``submit()`` refused request ``rid`` (``reason``;
                     emitted just before the AdmissionError is raised);
      "cancel"     — request ``rid`` was cancelled (``best`` is the anytime
                     incumbent if it ever ran);
      "expire"     — request ``rid`` hit its deadline or node budget and
                     was evicted with ``best`` as its anytime result;
      "resize"     — the service re-laid its lane pool onto a different
                     mesh / lane count (``reason`` describes the change);
      "done"       — the solve drained (``best`` is the global optimum).

    ``metrics`` carries a ``repro.obs.MetricsSnapshot`` on "round"/"done"
    events when ``SolverConfig.metrics`` is set (None otherwise).
    """

    kind: str
    round: int
    open_work: int = 0
    best: Optional[int] = None
    rid: Optional[int] = None
    path: Optional[str] = None
    reason: Optional[str] = None
    lanes: Optional[Lanes] = None
    metrics: Optional[Any] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown ProgressEvent kind {self.kind!r} (known: "
                f"{', '.join(sorted(EVENT_KINDS))})")


#: Event-consumer signature shared by both drivers.
EventCallback = Callable[[ProgressEvent], None]


def emit(on_event: Optional[EventCallback], kind: str, **fields) -> None:
    """The ONE ProgressEvent emission path for both drivers.

    Validates ``kind`` against :data:`EVENT_KINDS` unconditionally (a
    typo'd kind raises even with nobody listening), then constructs and
    delivers the event only when a listener is attached — emission stays
    free on the hot path when ``on_event`` is None.
    """
    if kind not in EVENT_KINDS:
        raise ValueError(
            f"unknown ProgressEvent kind {kind!r} (known: "
            f"{', '.join(sorted(EVENT_KINDS))})")
    if on_event is not None:
        on_event(ProgressEvent(kind=kind, **fields))


class SolveResult(NamedTuple):
    """Outcome of :meth:`Solver.solve` (payload squeezed for K = 1)."""

    payload: Any
    stats: SolveStats
    lanes: Lanes


class OracleResult(NamedTuple):
    """Outcome of :meth:`Solver.oracle` (SERIAL-RB ground truth)."""

    best: int
    nodes: int


class Solver:
    """A solver session: one config, three execution paths.

    ``on_event`` (optional) receives :class:`ProgressEvent` records from
    whichever driver runs — the typed successor of the old ``on_round``
    callback, shared by :meth:`solve` and the service returned by
    :meth:`serve`.
    """

    def __init__(self, config: Optional[SolverConfig] = None,
                 on_event: Optional[EventCallback] = None):
        self.config = config or SolverConfig()
        self.on_event = on_event
        self._obs = None          # RoundCollector of the most recent solve

    def metrics(self):
        """``repro.obs.MetricsSnapshot`` of the most recent (or running)
        :meth:`solve`, or None when telemetry was off (enable with
        ``SolverConfig(metrics=True)`` or ``trace_path=...``)."""
        return self._obs.snapshot() if self._obs is not None else None

    # -- problem resolution -------------------------------------------------

    def _resolve(self, problem) -> BinaryProblem:
        """ProblemHandle -> BinaryProblem under the config's backend (with
        capability validation); a raw BinaryProblem passes through."""
        if isinstance(problem, _registry.ProblemHandle):
            try:
                # ProblemSpec.build owns the capability check; surface its
                # refusal as a config error (the backend came from config).
                return problem.build(backend=self.config.backend)
            except ValueError as e:
                raise ConfigError(str(e)) from e
        if isinstance(problem, BinaryProblem):
            return problem
        raise TypeError(
            f"expected a registry.ProblemHandle or BinaryProblem, got "
            f"{type(problem).__name__}")

    # -- serial reference ---------------------------------------------------

    def oracle(self, problem) -> OracleResult:
        """SERIAL-RB on the family's registered scalar oracle."""
        if isinstance(problem, _registry.ProblemHandle):
            py = problem.oracle()
        else:
            py = problem                   # an already-built PyProblem
        best, nodes, _ = serial_rb(py)
        return OracleResult(best=best, nodes=nodes)

    # -- the distributed / single-device drive ------------------------------

    def solve(self, problem) -> SolveResult:
        """Run rounds until global termination (the paper's PARALLEL-RB).

        ``problem`` is a :class:`repro.registry.ProblemHandle` (built under
        the config's backend) or an already-built ``BinaryProblem``.
        ``config.lanes`` is the per-device lane count; with ``mesh=None``
        the solve is single-device, otherwise rounds are the shard_map'd
        collective version over every mesh axis.

        ``resume_from`` restores a checkpoint written by any earlier run at
        ANY lane/device count (elastic restart, paper §VII): surplus tasks
        beyond the new lane count wait in a host-side pool and are
        installed into idle lanes at round boundaries.
        """
        from repro.core import checkpoint as ckpt

        cfg = self.config
        problem = self._resolve(problem)
        mesh = cfg.mesh
        bootstrap_rounds = cfg.bootstrap_rounds

        if mesh is None:
            round_fn = jax.jit(make_round(problem, cfg.steps_per_round,
                                          fused_steps=cfg.fused_steps))
            boot_fn = (jax.jit(make_round(problem, cfg.bootstrap_steps,
                                          fused_steps=cfg.fused_steps))
                       if bootstrap_rounds else None)
            total_lanes = cfg.lanes
        else:
            n_dev = int(np.prod(mesh.devices.shape))
            round_fn = make_distributed_round(
                problem, mesh, cfg.steps_per_round, cfg.max_ship,
                fused_steps=cfg.fused_steps)
            boot_fn = (make_distributed_round(
                problem, mesh, cfg.bootstrap_steps, cfg.max_ship,
                fused_steps=cfg.fused_steps)
                if bootstrap_rounds else None)
            total_lanes = cfg.lanes * n_dev

        pool: list = []
        if cfg.resume_from is not None:
            if not os.path.exists(cfg.resume_from):
                raise ConfigError(
                    f"resume_from checkpoint not found: {cfg.resume_from}")
            try:
                lanes, pool = ckpt.restore(cfg.resume_from, problem,
                                           total_lanes)
            except ValueError as e:        # e.g. instance-slot mismatch
                raise ConfigError(
                    f"resume_from {cfg.resume_from!r} is incompatible with "
                    f"this problem/config: {e}") from e
            bootstrap_rounds = max(bootstrap_rounds, 1)  # respread work
        else:
            lanes = init_lanes(problem, total_lanes)
        if mesh is not None:
            lanes = _shard_lanes(lanes, mesh)

        collector = None
        if cfg.metrics or cfg.trace_path is not None:
            from repro import obs
            collector = obs.RoundCollector(
                mode="solve", lanes=total_lanes,
                slots=problem.num_instances,
                steps_per_round=cfg.steps_per_round,
                fused_steps=cfg.fused_steps, backend=cfg.backend,
                trace=(obs.TraceWriter(cfg.trace_path)
                       if cfg.trace_path else None))
            collector.start(lanes)      # after restore: deltas = this run
        self._obs = collector

        def feed_pool(lanes):
            nonlocal pool
            if pool:
                lanes = _gather_lanes(lanes)
                lanes, pool = ckpt.install_pending(problem, lanes, pool)
                if mesh is not None:
                    lanes = _shard_lanes(lanes, mesh)
            return lanes

        def snap():
            return (collector.snapshot()
                    if collector is not None and cfg.metrics else None)

        rounds, done = 0, False
        for _ in range(bootstrap_rounds):
            fed = bool(pool)
            lanes = feed_pool(lanes)
            if collector is not None:
                collector.before_round(lanes, dirty=fed)
            lanes, open_work = boot_fn(lanes) if boot_fn else round_fn(lanes)
            rounds += 1
            open_now = int(jnp.sum(open_work))
            if collector is not None:
                collector.after_round(rounds, lanes, open_now)
            if open_now == 0 and not pool:
                done = True
                break
        while not done and rounds < cfg.max_rounds:
            fed = bool(pool)
            lanes = feed_pool(lanes)
            if collector is not None:
                collector.before_round(lanes, dirty=fed)
            lanes, open_work = round_fn(lanes)
            rounds += 1
            open_now = int(jnp.sum(open_work))
            if collector is not None:
                collector.after_round(rounds, lanes, open_now)
            if self.on_event is not None:
                # The incumbent readback costs a device sync — only pay it
                # when someone is listening.
                emit(self.on_event, "round", round=rounds,
                     open_work=open_now, best=int(jnp.min(lanes.best)),
                     lanes=lanes, metrics=snap())
            if (cfg.checkpoint_every and cfg.checkpoint_path
                    and rounds % cfg.checkpoint_every == 0):
                ckpt.save(cfg.checkpoint_path, _gather_lanes(lanes))
                emit(self.on_event, "checkpoint", round=rounds,
                     path=cfg.checkpoint_path)
            if open_now == 0 and not pool:
                done = True

        stats = SolveStats(
            best=int(jnp.min(lanes.best)),
            rounds=rounds,
            nodes=int(jnp.sum(lanes.nodes)),
            t_s=int(jnp.sum(lanes.t_s)),
            t_r=int(jnp.sum(lanes.t_r)),
            donated=int(jnp.sum(lanes.donated)),
            lanes=int(lanes.active.shape[0]),
            t_c=int(jnp.sum(lanes.t_c)),
        )
        if collector is not None:
            collector.finish(rounds=rounds,
                             best=[int(b) for b in np.asarray(lanes.best)])
            collector.close()
        emit(self.on_event, "done", round=rounds, open_work=0,
             best=stats.best, metrics=snap())
        best_payload = jax.tree_util.tree_map(np.asarray, lanes.best_payload)
        if problem.num_instances == 1:
            # Single-instance API: drop the K=1 incumbent-table dim.
            best_payload = jax.tree_util.tree_map(lambda p: p[0],
                                                  best_payload)
        return SolveResult(payload=best_payload, stats=stats, lanes=lanes)

    # -- the multi-tenant service -------------------------------------------

    def serve(self, *, max_n: int, slots: int):
        """The session-flavored :class:`repro.service.SolverService` under
        this config (lanes, steps_per_round, backend, scheduler) and event
        stream.

        Its ``submit()`` returns a :class:`repro.service.Ticket` — the
        future-like request handle with ``status`` / ``result(timeout=)``
        / ``cancel()`` (DESIGN.md §7); requests carry ``priority``,
        ``deadline_rounds`` and ``node_budget``, and admission order is
        the config's ``scheduler`` policy.  Any registered *servable*
        family (``ProblemSpec.servable``) can be submitted; admission is
        validated at ``submit()`` time (typed
        :class:`repro.service.AdmissionError`, after a ``reject`` event).

        With ``mesh`` set the service runs SHARDED (DESIGN.md §9): the
        lane pool is partitioned over the mesh (``lanes`` per device), the
        stacked tables and per-instance incumbents are replicated, rounds
        run under shard_map with instance-scoped cross-device stealing,
        and per-instance open-work/node accounting reduces across the mesh
        each round.  Admission stays a host-side table write either way.

        The service driver has its own checkpoint surface
        (``SolverService.save`` / ``.restore``), so a config carrying
        ``checkpoint_every`` or ``resume_from`` is rejected here rather
        than silently ignored.
        """
        from repro.service.batch_problem import STACKED_BACKENDS
        from repro.service.driver import SolverService
        from repro.service.scheduler import SCHEDULERS

        if self.config.backend not in STACKED_BACKENDS:
            raise ConfigError(
                f"backend {self.config.backend!r} is not supported by the "
                f"stacked service (supports: {', '.join(STACKED_BACKENDS)})")
        if self.config.scheduler not in SCHEDULERS:
            raise ConfigError(
                f"unknown scheduler {self.config.scheduler!r} (registered "
                f"policies: {', '.join(sorted(SCHEDULERS))})")
        unsupported = [
            name for name, is_set in (
                ("checkpoint_every", bool(self.config.checkpoint_every)),
                ("resume_from", self.config.resume_from is not None),
            ) if is_set]
        if unsupported:
            raise ConfigError(
                f"SolverConfig fields not honored by the service driver: "
                f"{', '.join(unsupported)} — use SolverService.save/restore "
                f"for service checkpoints")
        return SolverService.from_config(self.config, max_n=max_n,
                                         slots=slots, on_event=self.on_event)
